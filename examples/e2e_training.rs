//! END-TO-END VALIDATION DRIVER (DESIGN.md E7).
//!
//! Exercises all three layers on a real workload:
//!   L1  Pallas tiled-matmul + fused-SGD kernels (inside the HLO)
//!   L2  JAX MiniCNN train_step / sgd_update / predict (AOT, HLO text)
//!   L3  this rust binary: PJRT execution, synthetic sharded data,
//!       REAL ring all-reduce of gradients across 4 data-parallel
//!       workers, fabric-simulated communication time
//!
//! Trains for a few hundred steps, logs the loss curve, reports held-out
//! accuracy, wall-clock images/s, and the simulated all-reduce cost on
//! both paper fabrics. Requires `make artifacts` to have run.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_training
//! ```

use fabricbench::config::presets::paper_fabrics;
use fabricbench::runtime::engine::Engine;
use fabricbench::trainer::real::RealTrainer;

fn main() -> anyhow::Result<()> {
    let steps = if std::env::args().any(|a| a == "--quick") { 40 } else { 300 };
    let workers = 4;
    let lr = 0.1;

    let [eth, opa] = paper_fabrics();
    println!("=== fabricbench end-to-end validation ===");

    // Train on the Ethernet fabric simulation.
    let engine = Engine::load_default()?;
    println!(
        "PJRT platform: {} | model: {} ({} parameters)\n",
        engine.platform(),
        engine.manifest.model,
        engine.manifest.param_count
    );
    let mut trainer = RealTrainer::new(engine)?;
    println!("training: {workers} workers x {steps} steps, lr={lr}, fabric={}", eth.name);
    let report = trainer.train(workers, steps, lr, &eth, Some(25))?;

    println!("\nloss curve (every 25 steps):");
    for (i, l) in report.losses.iter().enumerate() {
        if i % 25 == 0 || i + 1 == report.losses.len() {
            let bars = ((l / report.losses[0]) * 40.0) as usize;
            println!("  step {i:4}  {l:7.4}  {}", "#".repeat(bars.min(60)));
        }
    }
    println!(
        "\nfinal loss: {:.4} (from {:.4})  held-out accuracy: {:.1}%",
        report.losses.last().unwrap(),
        report.losses[0],
        100.0 * report.final_accuracy
    );
    println!(
        "wall-clock: {:.0} images/s real compute | {}: {:.1} ms simulated all-reduce total",
        report.images_per_sec_wall,
        eth.name,
        report.virtual_comm_time * 1e3
    );

    // Second short run on OPA for the fabric-time comparison.
    let engine2 = Engine::load_default()?;
    let mut trainer2 = RealTrainer::new(engine2)?;
    let quick = trainer2.train(workers, 20, lr, &opa, None)?;
    println!(
        "{}: {:.1} ms simulated all-reduce over 20 steps (vs {:.1} ms on {} for same steps)",
        opa.name,
        quick.virtual_comm_time * 1e3,
        report.virtual_comm_time * 1e3 * 20.0 / steps as f64,
        eth.name,
    );

    anyhow::ensure!(
        *report.losses.last().unwrap() < report.losses[0],
        "training did not converge"
    );
    anyhow::ensure!(report.final_accuracy > 0.5, "accuracy too low");
    println!("\nE2E validation PASSED: all three layers compose.");
    Ok(())
}
