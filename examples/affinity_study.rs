//! §IV.B affinity study as a standalone example: three PCIe socket
//! placements, repeated measurements, Welch's t-test — prints the same
//! conclusion the paper reached ("no statistically significant
//! difference", deploy config 1).
//!
//! ```bash
//! cargo run --release --example affinity_study
//! ```

use fabricbench::experiments::affinity;

fn main() {
    let (table, results) = affinity::run(false);
    println!("{}", table.to_markdown());
    for r in &results {
        println!("fabric {}:", r.fabric);
        for &((i, j), p) in &r.p_values {
            println!(
                "  config {} vs {}: p = {:.3} -> {}",
                i + 1,
                j + 1,
                p,
                if p > 0.05 { "not significant" } else { "SIGNIFICANT" }
            );
        }
    }
    println!("\npaper conclusion: no statistically significant difference; TX-GAIA\nwas deployed with configuration 1 (GPUs + Ethernet NIC on CPU1).");
}
