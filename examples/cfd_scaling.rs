//! CartDG strong scaling (Fig 3): runs the real mini DG kernel to ground
//! the per-element cost, then sweeps core counts on both fabrics.
//!
//! ```bash
//! cargo run --release --example cfd_scaling
//! ```

use fabricbench::cfd::dg::DgKernel;
use fabricbench::cfd::solver::StrongScaling;
use fabricbench::config::presets::paper_fabrics;

fn main() -> anyhow::Result<()> {
    // Ground truth from the real kernel on this machine.
    let kernel = DgKernel::new();
    let measured = kernel.measure_per_elem_seconds(64, 3);
    println!(
        "real DG kernel on this host: {:.2} us/elem ({:.2} GFLOP/s/core)\n",
        measured * 1e6,
        DgKernel::flops_per_elem() / measured / 1e9
    );

    let scaling = StrongScaling::paper();
    println!(
        "paper model per-element cost: {:.2} us (Xeon 6248 @ {}% peak, NS physics)\n",
        scaling.per_elem_seconds * 1e6,
        (fabricbench::cfd::solver::CARTDG_EFFICIENCY * 100.0) as u32
    );

    println!(
        "{:>7} {:>12} | {:>22} | {:>22}",
        "cores", "elems/rank", "25GbE (comp/comm ms)", "OPA (comp/comm ms)"
    );
    let fabrics = paper_fabrics();
    for cores in StrongScaling::paper_core_counts() {
        let e = scaling.run_point(&fabrics[0], cores)?;
        let o = scaling.run_point(&fabrics[1], cores)?;
        println!(
            "{:>7} {:>12} | {:>10.2} / {:>9.3} | {:>10.2} / {:>9.3}{}",
            cores,
            e.elems_per_rank,
            e.compute_time * 1e3,
            e.comm_time * 1e3,
            o.compute_time * 1e3,
            o.comm_time * 1e3,
            if e.inter_rack_messages > 0 { "   <- crosses racks" } else { "" }
        );
    }
    println!("\ncomm is near-identical across fabrics (paper Fig 3); the rack\nboundary between 1,280 and 2,560 cores is visible in the comm column.");
    Ok(())
}
