//! Fabric comparison for every paper model: reproduces the Fig 4 sweep
//! plus the TCP/no-GPUDirect ablation rows, printing per-model Ethernet
//! deficits.
//!
//! ```bash
//! cargo run --release --example fabric_comparison [-- --quick]
//! ```

use fabricbench::collectives::RingAllreduce;
use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{ClusterSpec, FabricKind, RunSpec, TransportOptions};
use fabricbench::experiments::batch_for;
use fabricbench::models::perf::Precision;
use fabricbench::models::zoo::paper_models;
use fabricbench::trainer::TrainerSim;
use fabricbench::util::units::MIB;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let gpus = if quick { 32 } else { 128 };
    let spec = RunSpec { measure_steps: 10, ..Default::default() };

    println!("Per-model fabric comparison at {gpus} GPUs (images/s)\n");
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>9}",
        "model", "OPA-100", "25GbE-RoCE", "25GbE-TCP", "deficit"
    );
    for arch in paper_models() {
        let run_on = |kind: FabricKind, use_rdma: bool| -> anyhow::Result<f64> {
            let trainer = TrainerSim {
                arch: arch.clone(),
                fabric: fabric(kind),
                cluster: ClusterSpec::txgaia(),
                opts: TransportOptions { gpudirect: use_rdma, use_rdma, ..Default::default() },
                strategy: Box::new(RingAllreduce),
                per_gpu_batch: batch_for(&arch.name),
                precision: Precision::Fp32,
                fusion_bytes: 64.0 * MIB,
                overlap: true,
                step_overhead: 0.0,
                coordination_overhead:
                    fabricbench::trainer::coordinator::DEFAULT_COORDINATION_OVERHEAD,
                tenancy: fabricbench::config::TenancySpec::default(),
                workload: fabricbench::config::WorkloadSpec::default(),
            };
            Ok(trainer.run(gpus, &spec)?.images_per_sec)
        };
        let opa = run_on(FabricKind::OmniPath100, true)?;
        let roce = run_on(FabricKind::EthernetRoce25, true)?;
        let tcp = run_on(FabricKind::EthernetTcp25, false)?;
        println!(
            "{:<14} {:>12.0} {:>12.0} {:>12.0} {:>8.1}%",
            arch.name,
            opa,
            roce,
            tcp,
            100.0 * (1.0 - roce / opa)
        );
    }
    println!("\n(deficit = RoCE vs OPA; paper reports a 12.78% average)");
    Ok(())
}
