//! Quickstart: simulate data-parallel ResNet50 training on TX-GAIA over
//! both of the paper's fabrics and print throughput + scaling efficiency.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fabricbench::collectives::RingAllreduce;
use fabricbench::config::presets::paper_fabrics;
use fabricbench::config::spec::{ClusterSpec, RunSpec, TransportOptions};
use fabricbench::models::perf::Precision;
use fabricbench::models::zoo::resnet50;
use fabricbench::trainer::TrainerSim;
use fabricbench::util::units::MIB;

fn main() -> anyhow::Result<()> {
    println!("fabricbench quickstart: ResNet50, Horovod-style ring allreduce\n");
    for fabric in paper_fabrics() {
        println!("fabric: {}", fabric.name);
        let trainer = TrainerSim {
            arch: resnet50(),
            fabric,
            cluster: ClusterSpec::txgaia(),
            opts: TransportOptions::default(),
            strategy: Box::new(RingAllreduce),
            per_gpu_batch: 64,
            precision: Precision::Fp32,
            fusion_bytes: 64.0 * MIB,
            overlap: true,
            step_overhead: 0.0,
            coordination_overhead:
                fabricbench::trainer::coordinator::DEFAULT_COORDINATION_OVERHEAD,
            tenancy: fabricbench::config::TenancySpec::default(),
            workload: fabricbench::config::WorkloadSpec::default(),
        };
        let spec = RunSpec::default();
        for gpus in [1, 8, 64, 256] {
            let r = trainer.run(gpus, &spec)?;
            println!(
                "  {:>4} GPUs: {:>10.1} img/s  (scaling eff {:.2}, comm {:.1}%)",
                gpus,
                r.images_per_sec,
                r.scaling_efficiency(),
                100.0 * r.comm_fraction
            );
        }
        println!();
    }
    Ok(())
}
