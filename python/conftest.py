# Pytest bootstrap for the python/ tree.
#
# * Puts python/ on sys.path so tests import `compile.*` without an
#   editable install (the tree is not a distributable package).
# * Degrades gracefully on machines missing optional heavyweight deps:
#   without jax the whole suite is skipped (every module imports it);
#   without hypothesis only the property-sweep kernel tests are skipped.
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

collect_ignore_glob = []
try:
    import jax  # noqa: F401
except Exception:
    collect_ignore_glob.append("tests/test_*.py")
else:
    try:
        import hypothesis  # noqa: F401
    except Exception:
        collect_ignore_glob.extend(
            ["tests/test_matmul_kernel.py", "tests/test_sgd_kernel.py"]
        )
