# AOT pipeline tests: HLO text is parseable-looking, manifest matches the
# model contract, init_params.bin has the exact byte length.
import json
import os
import struct

import jax.numpy as jnp

from compile import aot, model


def test_manifest_contract():
    man = aot.build_manifest()
    assert man["batch"] == model.BATCH
    assert man["param_count"] == model.PARAM_COUNT
    pnames = [p["name"] for p in man["params"]]
    assert pnames == [n for n, _ in model.PARAM_SPECS]
    ts = man["artifacts"]["train_step"]
    assert ts["inputs"] == pnames + ["x", "y"]
    assert ts["outputs"][0] == "loss"
    assert len(ts["outputs"]) == 1 + len(pnames)
    sg = man["artifacts"]["sgd_update"]
    assert len(sg["inputs"]) == 2 * len(pnames) + 1
    assert sg["outputs"] == pnames
    assert json.dumps(man)  # serializable


def test_init_params_bin_roundtrip(tmp_path):
    path = tmp_path / "init_params.bin"
    aot.write_init_params(str(path), seed=0)
    data = path.read_bytes()
    assert len(data) == 4 * model.PARAM_COUNT
    # First tensor must match init_params(0) bit-for-bit.
    p0 = jnp.asarray(model.init_params(0)[0]).reshape(-1)
    got = struct.unpack(f"<{p0.size}f", data[: 4 * p0.size])
    for a, b in zip(got, map(float, p0)):
        assert abs(a - b) < 1e-7


def test_lowered_hlo_text_structure():
    # Lower only predict (cheapest) in-process; the full set is covered by
    # `make artifacts` + the rust runtime integration tests.
    x_spec = aot._spec((model.BATCH,) + model.IMAGE)
    import jax

    lowered = jax.jit(model.predict).lower(*aot.param_specs(), x_spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[" in text
    # return_tuple=True: the root computation returns a tuple.
    assert "tuple(" in text or ") tuple" in text or "(f32[" in text


def test_artifacts_on_disk_if_built():
    # When `make artifacts` has run, validate the files agree with the
    # manifest (skip silently in a clean tree).
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        return
    man = json.load(open(man_path))
    for name, spec in man["artifacts"].items():
        path = os.path.join(art, spec["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        assert "ENTRY" in open(path).read()
    bin_path = os.path.join(art, "init_params.bin")
    assert os.path.getsize(bin_path) == 4 * man["param_count"]
