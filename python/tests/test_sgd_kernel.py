# Hypothesis sweep of the fused SGD Pallas kernels against the jnp oracle.
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import sgd_update, sgd_momentum_update
from compile.kernels.ref import sgd_ref, sgd_momentum_ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n), jnp.float32)


@given(
    n=st.integers(1, 200_000),
    lr=st.floats(0.0, 1.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_sgd_matches_ref(n, lr, seed):
    p = _rand(n, seed)
    g = _rand(n, seed + 1)
    np.testing.assert_allclose(
        np.asarray(sgd_update(p, g, lr)), np.asarray(sgd_ref(p, g, lr)),
        rtol=1e-6, atol=1e-6,
    )


@given(
    n=st.integers(1, 50_000),
    tile=st.sampled_from([1, 7, 64, 4096, 65536]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_sgd_tile_is_not_a_correctness_knob(n, tile, seed):
    p = _rand(n, seed)
    g = _rand(n, seed + 1)
    np.testing.assert_allclose(
        np.asarray(sgd_update(p, g, 0.1, tile=tile)),
        np.asarray(sgd_ref(p, g, 0.1)), rtol=1e-6, atol=1e-6,
    )


@given(
    n=st.integers(1, 100_000),
    lr=st.floats(0.0, 1.0, allow_nan=False),
    mu=st.floats(0.0, 0.999, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_sgd_momentum_matches_ref(n, lr, mu, seed):
    p = _rand(n, seed)
    g = _rand(n, seed + 1)
    m = _rand(n, seed + 2)
    got_p, got_m = sgd_momentum_update(p, g, m, lr, mu)
    want_p, want_m = sgd_momentum_ref(p, g, m, lr, mu)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m), rtol=1e-5, atol=1e-5)


def test_sgd_zero_lr_is_identity():
    p = _rand(1001, 3)
    g = _rand(1001, 4)
    np.testing.assert_array_equal(np.asarray(sgd_update(p, g, 0.0)), np.asarray(p))


def test_sgd_rejects_mismatched_shapes():
    with pytest.raises(ValueError):
        sgd_update(jnp.zeros(3), jnp.zeros(4), 0.1)
    with pytest.raises(ValueError):
        sgd_momentum_update(jnp.zeros(3), jnp.zeros(3), jnp.zeros(2), 0.1, 0.9)
