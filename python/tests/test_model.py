# L2 model tests: shapes, gradient correctness (finite differences through
# the custom-VJP Pallas dense layers), and that SGD actually learns the
# synthetic task.
import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def test_param_specs_consistent():
    params = model.init_params(0)
    assert len(params) == len(model.PARAM_SPECS)
    for p, (_, shape) in zip(params, model.PARAM_SPECS):
        assert p.shape == shape
    total = sum(int(np.prod(s)) for _, s in model.PARAM_SPECS)
    assert total == model.PARAM_COUNT


def test_forward_shapes():
    params = model.init_params(0)
    x, y = model.synthetic_batch(0)
    assert x.shape == (model.BATCH,) + model.IMAGE
    assert y.shape == (model.BATCH,)
    logits = model.forward(params, x)
    assert logits.shape == (model.BATCH, model.CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_step_outputs():
    params = model.init_params(0)
    x, y = model.synthetic_batch(0)
    out = model.train_step(*params, x, y)
    assert len(out) == 1 + len(model.PARAM_SPECS)
    loss = out[0]
    assert loss.shape == ()
    assert float(loss) > 0.0
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_gradients_match_finite_differences():
    # Spot-check grads through the Pallas custom-VJP path on a few
    # coordinates of fc2_w and conv1_w.
    params = list(model.init_params(0))
    x, y = model.synthetic_batch(0, batch=8)
    x, y = x[:8], y[:8]

    def loss_of(params_list):
        return model.loss_fn(tuple(params_list), x, y)

    grads = jax.grad(lambda pl: loss_of(pl))(params)
    eps = 1e-3
    for pi, coord in [(6, (3, 2)), (6, (0, 0)), (0, (1, 1, 1, 4)), (4, (10, 5))]:
        def perturbed(delta, pi=pi, coord=coord):
            ps = [p for p in params]
            ps[pi] = ps[pi].at[coord].add(delta)
            return float(loss_of(ps))

        fd = (perturbed(eps) - perturbed(-eps)) / (2 * eps)
        an = float(grads[pi][coord])
        assert abs(fd - an) < 5e-3, f"param {pi} coord {coord}: fd={fd} an={an}"


def test_sgd_update_moves_params_toward_lower_loss():
    params = model.init_params(0)
    x, y = model.synthetic_batch(0)
    out = model.train_step(*params, x, y)
    loss0 = float(out[0])
    newp = model.sgd_update(*params, *out[1:], jnp.float32(0.05))
    loss1 = float(model.train_step(*newp, x, y)[0])
    assert loss1 < loss0


def test_training_learns_synthetic_task():
    # 100 steps of SGD reach ~100% on the synthetic task (measured 1.0).
    params = model.init_params(0)
    lr = jnp.float32(0.1)
    for step in range(100):
        x, y = model.synthetic_batch(step)
        out = model.train_step(*params, x, y)
        params = model.sgd_update(*params, *out[1:], lr)
    x, y = model.synthetic_batch(997)
    logits = model.predict(*params, x)[0]
    acc = float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))
    assert acc > 0.7, f"accuracy {acc} too low"


def test_predict_matches_forward():
    params = model.init_params(1)
    x, _ = model.synthetic_batch(3)
    np.testing.assert_allclose(
        np.asarray(model.predict(*params, x)[0]),
        np.asarray(model.forward(params, x)),
        rtol=1e-6,
    )


def test_synthetic_batch_deterministic():
    x1, y1 = model.synthetic_batch(42)
    x2, y2 = model.synthetic_batch(42)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    x3, _ = model.synthetic_batch(43)
    assert not np.array_equal(np.asarray(x1), np.asarray(x3))
