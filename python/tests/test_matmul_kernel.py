# Hypothesis sweep of the Pallas tiled matmul kernel against the pure-jnp
# oracle — shapes, dtypes, and block configurations.
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul
from compile.kernels.ref import matmul_ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_matmul_matches_ref_f32(m, k, n, seed):
    x = _rand((m, k), jnp.float32, seed)
    w = _rand((k, n), jnp.float32, seed + 1)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w)), np.asarray(matmul_ref(x, w)),
        rtol=1e-5, atol=1e-5,
    )


@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_matmul_matches_ref_bf16_inputs(m, k, n, seed):
    # bf16 inputs are promoted to f32 accumulation (MXU semantics).
    x = _rand((m, k), jnp.bfloat16, seed)
    w = _rand((k, n), jnp.bfloat16, seed + 1)
    got = np.asarray(matmul(x, w))
    want = np.asarray(matmul_ref(x, w))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@given(
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_matmul_block_configs_equivalent(bm, bn, bk, seed):
    # The tile shape is a performance knob, never a correctness knob.
    x = _rand((40, 56), jnp.float32, seed)
    w = _rand((56, 24), jnp.float32, seed + 1)
    got = np.asarray(matmul(x, w, block=(bm, bn, bk)))
    want = np.asarray(matmul_ref(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_identity():
    x = jnp.eye(17, dtype=jnp.float32)
    w = _rand((17, 17), jnp.float32, 0)
    np.testing.assert_allclose(np.asarray(matmul(x, w)), np.asarray(w), rtol=1e-6)


def test_matmul_zero():
    x = jnp.zeros((5, 9), jnp.float32)
    w = _rand((9, 3), jnp.float32, 0)
    assert np.all(np.asarray(matmul(x, w)) == 0.0)


def test_matmul_tile_larger_than_operand():
    # Tiles shrink to the operand; no padding blow-up.
    x = _rand((2, 3), jnp.float32, 1)
    w = _rand((3, 2), jnp.float32, 2)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w, block=(128, 128, 128))),
        np.asarray(matmul_ref(x, w)), rtol=1e-5, atol=1e-6,
    )


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((2, 3), jnp.float32)
    with pytest.raises(ValueError):
        matmul(x, jnp.zeros((4, 2), jnp.float32))
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2,), jnp.float32), jnp.zeros((2, 2), jnp.float32))


def test_matmul_large_rectangular():
    # Exercises multi-block grids on every axis.
    x = _rand((130, 260), jnp.float32, 7)
    w = _rand((260, 140), jnp.float32, 8)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w, block=(64, 64, 64))),
        np.asarray(matmul_ref(x, w)), rtol=1e-4, atol=1e-4,
    )
