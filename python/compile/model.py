"""Layer-2 JAX model: the end-to-end validation workload.

A small CNN image classifier ("MiniCNN") in the spirit of the paper's
tf_cnn_benchmarks workloads (conv -> pool -> conv -> pool -> dense -> dense,
softmax cross-entropy), sized to train in seconds on the CPU PJRT backend
while still exercising every layer type whose *cost model* drives the
fabric benchmarks (rust/src/models/).

The dense layers run on the Layer-1 Pallas tiled-matmul kernel in both the
forward and backward pass (pallas_call has no automatic VJP, so the layer
is wrapped in a custom_vjp whose cotangents are themselves Pallas matmuls).
The SGD update is the Layer-1 fused update kernel.

Exported entry points (AOT-lowered by aot.py; argument order is the
manifest contract with rust/src/runtime/):

  train_step(*params, x, y) -> (loss, *grads)
  sgd_update(*params, *grads, lr) -> (*new_params,)
  predict(*params, x) -> (logits,)
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import matmul, sgd_update as sgd_kernel

# ---------------------------------------------------------------------------
# Shapes (the manifest contract).

BATCH = 32
IMAGE = (16, 16, 3)
CLASSES = 10
HIDDEN = 128

# (name, shape) in the flat argument order used by every entry point.
PARAM_SPECS = [
    ("conv1_w", (3, 3, 3, 8)),
    ("conv1_b", (8,)),
    ("conv2_w", (3, 3, 8, 16)),
    ("conv2_b", (16,)),
    ("fc1_w", (4 * 4 * 16, HIDDEN)),
    ("fc1_b", (HIDDEN,)),
    ("fc2_w", (HIDDEN, CLASSES)),
    ("fc2_b", (CLASSES,)),
]

PARAM_COUNT = sum(int(jnp.prod(jnp.array(s))) for _, s in PARAM_SPECS)


# ---------------------------------------------------------------------------
# Pallas-backed dense layer with custom VJP.


@jax.custom_vjp
def dense_matmul(x, w):
    """x @ w on the Pallas MXU kernel (fwd and bwd)."""
    return matmul(x, w)


def _dense_fwd(x, w):
    return matmul(x, w), (x, w)


def _dense_bwd(res, dy):
    x, w = res
    # dx = dy @ w^T ; dw = x^T @ dy — both on the Pallas kernel.
    return matmul(dy, w.T), matmul(x.T, dy)


dense_matmul.defvjp(_dense_fwd, _dense_bwd)


# ---------------------------------------------------------------------------
# Model.


def _avg_pool2(x):
    """2x2 average pooling via reshape (exact, layout-friendly)."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))


def _conv(x, w, b):
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def forward(params, x):
    """Logits for a batch of NHWC images in [0, 1]."""
    (c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b) = params
    h = jax.nn.relu(_conv(x, c1w, c1b))
    h = _avg_pool2(h)
    h = jax.nn.relu(_conv(h, c2w, c2b))
    h = _avg_pool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(dense_matmul(h, f1w) + f1b)
    return dense_matmul(h, f2w) + f2b


def loss_fn(params, x, y):
    """Mean softmax cross-entropy with integer labels."""
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, CLASSES, dtype=logp.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


# ---------------------------------------------------------------------------
# Exported entry points (flat-argument signatures for the HLO/manifest).


def train_step(*args):
    """(*params, x, y) -> (loss, *grads)."""
    params = args[: len(PARAM_SPECS)]
    x, y = args[len(PARAM_SPECS):]
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    return (loss,) + tuple(grads)


def sgd_update(*args):
    """(*params, *grads, lr) -> (*new_params,).

    Every tensor is flattened through the Layer-1 fused SGD kernel and
    reshaped back — the same flat-buffer view the coordinator's fusion
    buffer uses for the all-reduce.
    """
    n = len(PARAM_SPECS)
    params = args[:n]
    grads = args[n: 2 * n]
    lr = args[2 * n]
    new = []
    for p, g in zip(params, grads):
        flat = sgd_kernel(p.reshape(-1), g.reshape(-1), lr)
        new.append(flat.reshape(p.shape))
    return tuple(new)


def predict(*args):
    """(*params, x) -> (logits,)."""
    params = args[: len(PARAM_SPECS)]
    (x,) = args[len(PARAM_SPECS):]
    return (forward(params, x),)


# ---------------------------------------------------------------------------
# Initialization (compile-time only; exported as init_params.bin).


def init_params(seed=0):
    """He-initialized parameters as a tuple in PARAM_SPECS order."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            scale = jnp.sqrt(2.0 / fan_in)
            out.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return tuple(out)


def synthetic_batch(seed=0, batch=BATCH):
    """Deterministic labeled batch matching rust/src/trainer/data.rs.

    Class k's images are a fixed random template + noise; mirrors the rust
    generator closely enough for loss-decreases tests on the python side.
    """
    key = jax.random.PRNGKey(1234)
    templates = jax.random.uniform(key, (CLASSES,) + IMAGE)
    key = jax.random.PRNGKey(seed + 5678)
    ky, kn = jax.random.split(key)
    y = jax.random.randint(ky, (batch,), 0, CLASSES)
    noise = 0.25 * jax.random.normal(kn, (batch,) + IMAGE)
    x = jnp.clip(templates[y] + noise, 0.0, 1.0)
    return x.astype(jnp.float32), y.astype(jnp.int32)
