"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels are authored for the TPU MXU/VMEM model but lowered with
``interpret=True`` so the resulting HLO runs on any PJRT backend (the rust
CPU client in this repo). See DESIGN.md §Hardware-Adaptation.
"""

from .matmul import matmul, DEFAULT_BLOCK
from .sgd import sgd_update, sgd_momentum_update

__all__ = ["matmul", "sgd_update", "sgd_momentum_update", "DEFAULT_BLOCK"]
