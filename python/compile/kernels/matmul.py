"""Tiled matmul Pallas kernel (the paper's dense-layer hot spot, re-thought
for the TPU MXU instead of V100 tensor cores).

CUDA tf_cnn_benchmarks feeds dense/conv-as-GEMM work to tensor cores via
warp-level WMMA tiles staged through shared memory. The TPU analogue is the
128x128 MXU systolic array fed from VMEM: we tile the GEMM into
(bm, bk) x (bk, bn) blocks, express the HBM->VMEM schedule with BlockSpecs
(what CUDA does with threadblocks + cp.async), and accumulate over the K
grid dimension, which Pallas executes sequentially ("arbitrary" semantics)
so the output block stays resident in VMEM.

VMEM budget per grid step (f32): (bm*bk + bk*bn + bm*bn) * 4 bytes.
The default 128x128x128 tile uses 192 KiB out of ~16 MiB VMEM, leaving room
for double-buffered prefetch of the next x/w blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tile. 128 matches the systolic array edge; see module
# docstring for the VMEM arithmetic.
DEFAULT_BLOCK = (128, 128, 128)


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ w[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a, m0, m1):
    """Zero-pad 2-D ``a`` so both dims are multiples of (m0, m1)."""
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return a
    return jnp.pad(a, ((0, p0), (0, p1)))


@functools.partial(jax.jit, static_argnames=("block",))
def matmul(x, w, *, block=None):
    """``x @ w`` via the tiled Pallas kernel.

    Arbitrary (m, k) x (k, n) shapes are supported by zero-padding up to the
    tile size (zero padding is exact for matmul) and slicing the result.
    Inputs are promoted to f32; accumulation is always f32 (MXU-style).
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    n = w.shape[1]
    bm, bn, bk = block or DEFAULT_BLOCK
    # Shrink tiles for small operands so the grid is never empty and we do
    # not waste VMEM on padding: a tile never exceeds the (padded) operand.
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)

    xp = _pad_to(x.astype(jnp.float32), bm, bk)
    wp = _pad_to(w.astype(jnp.float32), bk, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU-PJRT execution; real-TPU lowering is compile-only here
    )(xp, wp)
    return out[:m, :n]
