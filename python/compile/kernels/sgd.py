"""Fused SGD parameter-update Pallas kernels.

The CUDA equivalent in tf_cnn_benchmarks/Horovod is a fused elementwise
apply-gradients kernel launched over a flat grid. On TPU we block the flat
parameter vector into VMEM-sized 1-D tiles; each grid step streams one tile
of (param, grad[, momentum]) through the VPU and writes the update back.

Both kernels operate on *flat f32 vectors*; the L2 model flattens each
parameter tensor (the coordinator's fusion buffer does the same thing with
gradient tensors, so the kernel shape mirrors the system design).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 64 Ki f32 per tile = 256 KiB VMEM per operand stream.
DEFAULT_TILE = 65536


def _sgd_kernel(lr_ref, p_ref, g_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


def _sgd_momentum_kernel(lr_ref, mu_ref, p_ref, g_ref, m_ref, op_ref, om_ref):
    m_new = mu_ref[0] * m_ref[...] + g_ref[...]
    om_ref[...] = m_new
    op_ref[...] = p_ref[...] - lr_ref[0] * m_new


def _pad1(a, tile):
    pad = (-a.shape[0]) % tile
    if pad == 0:
        return a
    return jnp.pad(a, (0, pad))


@functools.partial(jax.jit, static_argnames=("tile",))
def sgd_update(param, grad, lr, *, tile=None):
    """``param - lr * grad`` over a flat f32 vector, VMEM-tiled."""
    if param.ndim != 1 or grad.ndim != 1:
        raise ValueError("sgd_update expects flat vectors")
    if param.shape != grad.shape:
        raise ValueError(f"shape mismatch {param.shape} vs {grad.shape}")
    n = param.shape[0]
    t = min(tile or DEFAULT_TILE, n)
    p = _pad1(param.astype(jnp.float32), t)
    g = _pad1(grad.astype(jnp.float32), t)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape((1,))
    grid = (p.shape[0] // t,)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            # lr broadcast to every grid step (block index 0).
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p.shape[0],), jnp.float32),
        interpret=True,
    )(lr_arr, p, g)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("tile",))
def sgd_momentum_update(param, grad, momentum, lr, mu, *, tile=None):
    """Heavy-ball SGD: returns (new_param, new_momentum)."""
    if not (param.shape == grad.shape == momentum.shape) or param.ndim != 1:
        raise ValueError("sgd_momentum_update expects matching flat vectors")
    n = param.shape[0]
    t = min(tile or DEFAULT_TILE, n)
    p = _pad1(param.astype(jnp.float32), t)
    g = _pad1(grad.astype(jnp.float32), t)
    m = _pad1(momentum.astype(jnp.float32), t)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape((1,))
    mu_arr = jnp.asarray(mu, jnp.float32).reshape((1,))
    grid = (p.shape[0] // t,)
    op, om = pl.pallas_call(
        _sgd_momentum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((p.shape[0],), jnp.float32),
        ],
        interpret=True,
    )(lr_arr, mu_arr, p, g, m)
    return op[:n], om[:n]
