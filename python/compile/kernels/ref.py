"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its oracle to allclose tolerance
across the hypothesis shape/dtype sweep in python/tests/.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def sgd_ref(param, grad, lr):
    return param.astype(jnp.float32) - jnp.float32(lr) * grad.astype(jnp.float32)


def sgd_momentum_ref(param, grad, momentum, lr, mu):
    m_new = jnp.float32(mu) * momentum.astype(jnp.float32) + grad.astype(jnp.float32)
    return param.astype(jnp.float32) - jnp.float32(lr) * m_new, m_new
