"""AOT compile path: lower the L2 model to HLO *text* artifacts.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects with
``proto.id() <= INT_MAX``; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Outputs (into --outdir, default ../artifacts):
  train_step.hlo.txt   (*params, x, y) -> tuple(loss, *grads)
  sgd_update.hlo.txt   (*params, *grads, lr) -> tuple(*new_params)
  predict.hlo.txt      (*params, x) -> tuple(logits)
  init_params.bin      f32 little-endian, PARAM_SPECS order, concatenated
  manifest.json        shapes + argument order contract for rust/src/runtime/

Python runs ONCE at build time (`make artifacts`); the rust binary is
self-contained afterwards.
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs():
    return [_spec(s) for _, s in model.PARAM_SPECS]


def lower_all():
    """Lower every entry point; returns {name: hlo_text}."""
    x_spec = _spec((model.BATCH,) + model.IMAGE)
    y_spec = _spec((model.BATCH,), jnp.int32)
    lr_spec = _spec(())

    out = {}
    out["train_step"] = to_hlo_text(
        jax.jit(model.train_step).lower(*param_specs(), x_spec, y_spec)
    )
    out["sgd_update"] = to_hlo_text(
        jax.jit(model.sgd_update).lower(*param_specs(), *param_specs(), lr_spec)
    )
    out["predict"] = to_hlo_text(
        jax.jit(model.predict).lower(*param_specs(), x_spec)
    )
    return out


def build_manifest():
    pnames = [n for n, _ in model.PARAM_SPECS]
    return {
        "model": "minicnn",
        "batch": model.BATCH,
        "image": list(model.IMAGE),
        "classes": model.CLASSES,
        "param_count": int(model.PARAM_COUNT),
        "params": [
            {"name": n, "shape": list(s)} for n, s in model.PARAM_SPECS
        ],
        "artifacts": {
            "train_step": {
                "file": "train_step.hlo.txt",
                "inputs": pnames + ["x", "y"],
                "outputs": ["loss"] + [f"grad_{n}" for n in pnames],
            },
            "sgd_update": {
                "file": "sgd_update.hlo.txt",
                "inputs": pnames + [f"grad_{n}" for n in pnames] + ["lr"],
                "outputs": pnames,
            },
            "predict": {
                "file": "predict.hlo.txt",
                "inputs": pnames + ["x"],
                "outputs": ["logits"],
            },
        },
    }


def write_init_params(path, seed=0):
    params = model.init_params(seed)
    with open(path, "wb") as f:
        for p in params:
            flat = jnp.asarray(p, jnp.float32).reshape(-1)
            f.write(struct.pack(f"<{flat.size}f", *map(float, flat)))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: stamp file path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    outdir = args.outdir
    if args.out is not None:
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    for name, text in lower_all().items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    write_init_params(os.path.join(outdir, "init_params.bin"), args.seed)
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(build_manifest(), f, indent=2)
    print(f"wrote {outdir}/init_params.bin and {outdir}/manifest.json")

    if args.out is not None:
        # Makefile stamp compatibility.
        with open(args.out, "w") as f:
            f.write("ok\n")


if __name__ == "__main__":
    main()
