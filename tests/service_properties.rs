//! End-to-end properties of the what-if HTTP service: a real server on
//! an ephemeral port, a raw `std::net` test client (no HTTP crates),
//! and the contract the service advertises — responses byte-identical
//! to the CLI path, shared-cache hit/miss/coalesce accounting, NDJSON
//! batch streaming, loud errors on bad requests.

use fabricbench::service::whatif::Scenario;
use fabricbench::service::ServerHandle;
use fabricbench::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

const CFG: &str = r#"
[fabric]
kind = "25gbe-roce"

[train]
model = "resnet50"
gpus = 8
per_gpu_batch = 32

[run]
seed = 7
warmup_steps = 1
measure_steps = 3
"#;

/// One `Connection: close` HTTP exchange; returns (status, body) with
/// chunked transfer-encoding decoded.
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8(raw).expect("utf-8 response");
    let (head, payload) = text.split_once("\r\n\r\n").expect("header/body split");
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    let chunked = head
        .to_ascii_lowercase()
        .contains("transfer-encoding: chunked");
    let body = if chunked { dechunk(payload) } else { payload.to_string() };
    (status, body)
}

/// Decode a chunked body: hex-length line, `len` bytes, CRLF, repeat
/// until the zero-length terminator.
fn dechunk(s: &str) -> String {
    let mut out = String::new();
    let mut rest = s;
    loop {
        let (len_line, tail) = rest.split_once("\r\n").expect("chunk length line");
        let len = usize::from_str_radix(len_line.trim(), 16).expect("hex chunk length");
        if len == 0 {
            return out;
        }
        out.push_str(&tail[..len]);
        rest = tail[len..].strip_prefix("\r\n").expect("chunk CRLF");
    }
}

fn whatif_body(cfg: &str) -> String {
    format!("{}", fabricbench::util::json::obj(vec![("config", fabricbench::util::json::s(cfg))]))
}

#[test]
fn whatif_response_matches_cli_bytes_cold_and_warm() {
    let server = ServerHandle::start(0, 2, 8).unwrap();
    let addr = server.addr();
    // The exact bytes `run --config <file> --json` prints.
    let expected = Scenario::from_toml_text(CFG).unwrap().response_body().unwrap();

    let (status, cold) = http(addr, "POST", "/v1/whatif", &whatif_body(CFG));
    assert_eq!(status, 200, "{cold}");
    assert_eq!(cold, expected, "cold-cache response must equal the CLI output");

    let (status, warm) = http(addr, "POST", "/v1/whatif", &whatif_body(CFG));
    assert_eq!(status, 200);
    assert_eq!(warm, expected, "warm-cache response must equal the CLI output");

    let (status, stats) = http(addr, "GET", "/v1/cache/stats", "");
    assert_eq!(status, 200);
    let j = Json::parse(stats.trim_end()).unwrap();
    assert_eq!(j.get("misses").unwrap().as_usize(), Some(1), "{stats}");
    assert_eq!(j.get("hits").unwrap().as_usize(), Some(1), "{stats}");
    assert_eq!(j.get("entries").unwrap().as_usize(), Some(1), "{stats}");
}

#[test]
fn concurrent_identical_queries_hammer_one_cache_slot() {
    let server = ServerHandle::start(0, 4, 8).unwrap();
    let addr = server.addr();
    let n = 6;
    let bodies: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|_| scope.spawn(move || http(addr, "POST", "/v1/whatif", &whatif_body(CFG))))
            .collect();
        handles
            .into_iter()
            .map(|h| {
                let (status, body) = h.join().unwrap();
                assert_eq!(status, 200, "{body}");
                body
            })
            .collect()
    });
    for b in &bodies[1..] {
        assert_eq!(b, &bodies[0], "every concurrent response must be bit-identical");
    }
    let s = server.state.cache.stats();
    assert_eq!(s.misses, 1, "identical queries must run one simulation: {s:?}");
    assert_eq!(s.hits + s.coalesced, (n - 1) as u64, "{s:?}");
    assert_eq!(s.entries, 1);
    assert!(s.entries <= s.capacity);
}

#[test]
fn batch_streams_ndjson_in_cell_order_through_the_shared_cache() {
    let server = ServerHandle::start(0, 2, 8).unwrap();
    let addr = server.addr();
    let other = CFG.replace("seed = 7", "seed = 8");
    // Cells 0 and 2 are the same scenario; 1 differs by seed only.
    let req = format!(
        "{}",
        fabricbench::util::json::obj(vec![(
            "cells",
            fabricbench::util::json::arr(vec![
                fabricbench::util::json::s(CFG),
                fabricbench::util::json::s(&other),
                fabricbench::util::json::s(CFG),
            ]),
        )])
    );
    let (status, body) = http(addr, "POST", "/v1/batch", &req);
    assert_eq!(status, 200, "{body}");
    let lines: Vec<&str> = body.lines().collect();
    assert_eq!(lines.len(), 3, "{body}");
    assert_eq!(lines[0], lines[2], "identical cells must serialize identically");
    assert_ne!(lines[0], lines[1], "a different seed is a different cell");
    let expected = Scenario::from_toml_text(CFG).unwrap().response_body().unwrap();
    assert_eq!(format!("{}\n", lines[0]), expected, "batch cells equal single what-ifs");
    for line in &lines {
        let j = Json::parse(line).unwrap();
        assert!(j.get("result").is_some(), "{line}");
    }
    // Two unique scenarios across three cells: 2 misses, 1 hit-or-coalesce.
    let s = server.state.cache.stats();
    assert_eq!(s.misses, 2, "{s:?}");
    assert_eq!(s.hits + s.coalesced, 1, "{s:?}");
}

#[test]
fn health_answers_and_bad_requests_are_loud() {
    let server = ServerHandle::start(0, 2, 8).unwrap();
    let addr = server.addr();

    let (status, body) = http(addr, "GET", "/v1/health", "");
    assert_eq!(status, 200);
    let j = Json::parse(body.trim_end()).unwrap();
    assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));

    let (status, _) = http(addr, "GET", "/v1/nope", "");
    assert_eq!(status, 404);
    let (status, _) = http(addr, "PUT", "/v1/whatif", "");
    assert_eq!(status, 405);
    let (status, body) = http(addr, "POST", "/v1/whatif", "this is not json");
    assert_eq!(status, 400, "{body}");
    let (status, body) = http(addr, "POST", "/v1/whatif", &whatif_body("[fleet]\njobs = 2\n"));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("fleet"), "fleet rejection must say why: {body}");
    // A batch with one bad cell fails whole, naming the cell, before
    // any stream output.
    let req = format!(
        "{}",
        fabricbench::util::json::obj(vec![(
            "cells",
            fabricbench::util::json::arr(vec![
                fabricbench::util::json::s(CFG),
                fabricbench::util::json::s("[train]\nmodel = \"resnet50\"\n"),
            ]),
        )])
    );
    let (status, body) = http(addr, "POST", "/v1/batch", &req);
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("cell 1"), "{body}");
}
