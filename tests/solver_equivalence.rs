//! Solver equivalence property suite (PR 4).
//!
//! The engine's hot path runs the allocation-free incremental solver
//! (`MaxMinScratch`); the original allocating `max_min_rates` is retained
//! as the reference oracle. These properties pin the two **bit-for-bit**
//! over randomized flow/resource grids — including real dragonfly routes,
//! which exercise the maximum 6-resource flow footprint — so the solver
//! swap can never move a golden byte.

use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{ClusterSpec, FabricKind, TopologyKind, TopologySpec};
use fabricbench::fabric::contention::{
    max_min_rates, FlowResources, MaxMinScratch, MAX_FLOW_RESOURCES,
};
use fabricbench::fabric::Topology;
use fabricbench::util::rng::Rng;

fn assert_bits_equal(want: &[f64], got: &[f64], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length mismatch");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{ctx}: flow {i} reference {a} vs incremental {b}"
        );
    }
}

fn random_grid(
    rng: &mut Rng,
    n_res_max: u64,
    n_flows_max: u64,
) -> (Vec<f64>, Vec<f64>, Vec<FlowResources>) {
    let n_res = 1 + rng.below(n_res_max) as usize;
    let caps: Vec<f64> = (0..n_res).map(|_| rng.uniform_in(0.1, 40.0)).collect();
    let n_flows = 1 + rng.below(n_flows_max) as usize;
    let mut flow_caps = Vec::new();
    let mut flow_res = Vec::new();
    for _ in 0..n_flows {
        flow_caps.push(rng.uniform_in(0.05, 60.0));
        let k = 1 + rng.below(MAX_FLOW_RESOURCES as u64) as usize;
        let mut f = FlowResources::new();
        let mut used = Vec::new();
        for _ in 0..k.min(n_res) {
            let r = rng.below(n_res as u64) as usize;
            if !used.contains(&r) {
                f.push(r);
                used.push(r);
            }
        }
        flow_res.push(f);
    }
    (caps, flow_caps, flow_res)
}

#[test]
fn randomized_grids_bit_identical() {
    let mut rng = Rng::new(0x50_1_7E9);
    let mut scratch = MaxMinScratch::new();
    let mut rates = Vec::new();
    for trial in 0..2000 {
        let (caps, flow_caps, flow_res) = random_grid(&mut rng, 12, 40);
        let want = max_min_rates(&caps, &flow_caps, &flow_res);
        scratch.solve_all(&caps, &flow_caps, &flow_res, &mut rates);
        assert_bits_equal(&want, &rates, &format!("trial {trial}"));
    }
}

#[test]
fn degenerate_grids_bit_identical() {
    // Zero flow caps, equal caps (mass ties), single shared resource,
    // heavily oversubscribed bottlenecks — the epsilon/stall paths.
    let mut scratch = MaxMinScratch::new();
    let mut rates = Vec::new();
    let fr = |ids: &[usize]| {
        let mut f = FlowResources::new();
        for &i in ids {
            f.push(i);
        }
        f
    };
    let cases: Vec<(Vec<f64>, Vec<f64>, Vec<FlowResources>)> = vec![
        (vec![10.0], vec![0.0, 5.0], vec![fr(&[0]), fr(&[0])]),
        (vec![10.0], vec![5.0; 8], (0..8).map(|_| fr(&[0])).collect()),
        (vec![1e-9, 1e9], vec![1e9, 1e9], vec![fr(&[0, 1]), fr(&[1])]),
        (vec![7.0, 7.0, 7.0], vec![7.0; 6], (0..6).map(|i| fr(&[i % 3])).collect()),
        (vec![5.0], vec![f64::MAX / 4.0, 1.0], vec![fr(&[0]), fr(&[0])]),
    ];
    for (i, (caps, flow_caps, flow_res)) in cases.iter().enumerate() {
        let want = max_min_rates(caps, flow_caps, flow_res);
        scratch.solve_all(caps, flow_caps, flow_res, &mut rates);
        assert_bits_equal(&want, &rates, &format!("degenerate case {i}"));
    }
}

#[test]
fn dragonfly_six_resource_routes_bit_identical() {
    // Real routes from a dragonfly topology: cross-group flows hold six
    // links (NIC tx, ToR up, global out, global in, ToR down, NIC rx).
    let cluster = ClusterSpec::txgaia();
    let spec = TopologySpec {
        kind: TopologyKind::Dragonfly,
        groups: 7,
        spines: 2,
        oversubscription: Some(4.0),
        global_oversubscription: 2.0,
        ..Default::default()
    };
    let f = fabric(FabricKind::EthernetRoce25);
    let topo = Topology::build(&spec, &f, &cluster).unwrap();
    let nic = f.effective_bandwidth();
    let mut rng = Rng::new(0xD4A90);
    let mut scratch = MaxMinScratch::new();
    let mut rates = Vec::new();
    let mut saw_six = false;
    for trial in 0..200 {
        let n_flows = 2 + rng.below(48) as usize;
        let mut ids: Vec<usize> = Vec::new();
        let mut routes = Vec::new();
        for _ in 0..n_flows {
            let src = rng.below(448) as usize;
            let mut dst = rng.below(448) as usize;
            if dst == src {
                dst = (dst + 37) % 448;
            }
            let r = topo.route(src, dst, rng.below(8));
            ids.extend(r.res.iter());
            routes.push(r.res);
        }
        ids.sort_unstable();
        ids.dedup();
        let caps: Vec<f64> = ids.iter().map(|&id| topo.caps()[id]).collect();
        let flow_res: Vec<FlowResources> = routes
            .iter()
            .map(|route| {
                let mut fr = FlowResources::new();
                for id in route.iter() {
                    fr.push(ids.binary_search(&id).unwrap());
                }
                fr
            })
            .collect();
        let flow_caps: Vec<f64> =
            (0..n_flows).map(|_| nic * rng.uniform_in(0.3, 1.0)).collect();
        let want = max_min_rates(&caps, &flow_caps, &flow_res);
        scratch.solve_all(&caps, &flow_caps, &flow_res, &mut rates);
        assert_bits_equal(&want, &rates, &format!("dragonfly trial {trial}"));
        saw_six |= flow_res.iter().any(|fr| fr.len() == 6);
    }
    assert!(saw_six, "no trial crossed a group — 6-resource routes never exercised");
}

#[test]
fn scratch_interleaved_shapes_stay_clean() {
    // Alternating large and tiny instances through ONE arena must match
    // a fresh solver on every instance (sparse reset correctness).
    let mut rng = Rng::new(0xC1EA7);
    let mut shared = MaxMinScratch::new();
    let mut rates_a = Vec::new();
    let mut rates_b = Vec::new();
    for trial in 0..300 {
        let (caps, flow_caps, flow_res) = if trial % 2 == 0 {
            random_grid(&mut rng, 12, 48)
        } else {
            random_grid(&mut rng, 2, 3)
        };
        shared.solve_all(&caps, &flow_caps, &flow_res, &mut rates_a);
        MaxMinScratch::new().solve_all(&caps, &flow_caps, &flow_res, &mut rates_b);
        assert_bits_equal(&rates_b, &rates_a, &format!("interleave trial {trial}"));
    }
}
