//! Property tests for the shared-tenancy subsystem (`fabric::tenancy` +
//! the coordinator's straggler model):
//!
//! * a zero-background-load, unit-slowdown `TenancySpec` is bit-for-bit
//!   identical to the default (pre-tenancy) trainer, for **all five**
//!   collective algorithms — the tenancy machinery must be invisible
//!   when disabled, and the committed `table1` golden stays byte-exact;
//! * background traffic strictly increases exposed communication on a
//!   contended 25 GbE cell, and step time is monotone in the load
//!   (loads are realized by thinning one full-rate arrival stream, so
//!   higher loads see a superset of the same flows — see
//!   `fabric::tenancy`);
//! * the tenancy sweep CSV is byte-identical across `--jobs`, the
//!   60%-load 25GbE @ 128-GPU cell beats the dedicated cell on exposed
//!   comm time (the paper's shared-vs-dedicated question, answerable at
//!   last), and tenancy seeds are reproducible.

use fabricbench::collectives::{
    BinomialTree, Collective, Hierarchical, PipelinedRing, RecursiveHalvingDoubling, RingAllreduce,
};
use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{ClusterSpec, FabricKind, RunSpec, TenancySpec, TransportOptions};
use fabricbench::experiments::ablations;
use fabricbench::experiments::sweeps::Runner;
use fabricbench::trainer::{ThroughputResult, TrainerSim};
use fabricbench::util::units::MIB;

fn trainer(kind: FabricKind, tenancy: TenancySpec) -> TrainerSim {
    TrainerSim {
        arch: fabricbench::models::zoo::resnet50(),
        fabric: fabric(kind),
        cluster: ClusterSpec::txgaia(),
        opts: TransportOptions::default(),
        strategy: Box::new(RingAllreduce),
        per_gpu_batch: 64,
        precision: fabricbench::models::perf::Precision::Fp32,
        fusion_bytes: 64.0 * MIB,
        overlap: true,
        step_overhead: 0.0,
        coordination_overhead: fabricbench::trainer::coordinator::DEFAULT_COORDINATION_OVERHEAD,
        tenancy,
        workload: fabricbench::config::WorkloadSpec::default(),
        faults: fabricbench::fabric::FaultSpec::default(),
    }
}

fn spec(measure: usize) -> RunSpec {
    RunSpec { warmup_steps: 1, measure_steps: measure, ..Default::default() }
}

fn exposed(r: &ThroughputResult) -> f64 {
    r.comm_fraction * r.step_time_mean
}

#[test]
fn zero_load_unit_slowdown_is_bit_identical_for_all_five_collectives() {
    // A fully *configured* tenancy spec whose knobs are all at their
    // neutral points: load 0 (no generator), factor exactly 1 (no
    // persistent draw), jitter 0 (no per-step draw). Everything else —
    // seed, node sets, pattern, source — is deliberately non-default, so
    // this pins "disabled means disabled", not "default means default".
    let neutral = TenancySpec {
        background_load: 0.0,
        pattern: fabricbench::config::TrafficPattern::Shuffle,
        source: fabricbench::config::SourceModel::OnOff,
        src_first: Some(64),
        src_count: Some(16),
        straggler_frac: 0.7,
        straggler_factor: 1.0,
        straggler_jitter: 0.0,
        seed: 0xDEAD_BEEF,
        ..Default::default()
    };
    let strategies: Vec<fn() -> Box<dyn Collective>> = vec![
        || Box::new(RingAllreduce),
        || Box::new(RecursiveHalvingDoubling),
        || Box::new(Hierarchical::default()),
        || Box::new(BinomialTree),
        || Box::new(PipelinedRing { segments: 3 }),
    ];
    for make in strategies {
        let mut base = trainer(FabricKind::EthernetRoce25, TenancySpec::default());
        base.strategy = make();
        let name = base.strategy.name();
        let mut tenant = trainer(FabricKind::EthernetRoce25, neutral);
        tenant.strategy = make();
        let a = base.run(16, &spec(3)).unwrap();
        let b = tenant.run(16, &spec(3)).unwrap();
        assert_eq!(
            a.step_time_mean.to_bits(),
            b.step_time_mean.to_bits(),
            "{name}: neutral tenancy moved the step time"
        );
        assert_eq!(a.images_per_sec.to_bits(), b.images_per_sec.to_bits(), "{name}");
        assert_eq!(a.comm_fraction.to_bits(), b.comm_fraction.to_bits(), "{name}");
        assert_eq!(a.step_time_p95.to_bits(), b.step_time_p95.to_bits(), "{name}");
    }
}

#[test]
fn table1_golden_untouched_by_tenancy_module() {
    // The cheap committed golden: the tenancy subsystem must not move a
    // byte of the default-config drivers. (fig3 is covered by
    // tests/golden_outputs.rs — no need to run the CFD sweep twice.)
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("table1.csv");
    let want = std::fs::read_to_string(&path).expect("committed golden tests/golden/table1.csv");
    assert_eq!(
        want,
        fabricbench::experiments::table1::run().to_csv(),
        "default config must stay bit-for-bit pre-tenancy"
    );
}

#[test]
fn background_strictly_increases_exposed_comm_on_contended_cell() {
    // Paired seeds: identical compute jitter, the tenant is the only
    // difference. 32 GPUs on 25 GbE is a comm-bound cell whose ring
    // traffic receives on the incast's destination nodes.
    let quiet = trainer(FabricKind::EthernetRoce25, TenancySpec::default())
        .run(32, &spec(3))
        .unwrap();
    let shared = trainer(FabricKind::EthernetRoce25, TenancySpec::neighbor_incast(0.6))
        .run(32, &spec(3))
        .unwrap();
    assert!(
        exposed(&shared) > exposed(&quiet),
        "60% background must expose more comm: {} !> {}",
        exposed(&shared),
        exposed(&quiet)
    );
    assert!(
        shared.step_time_mean > quiet.step_time_mean,
        "60% background must stretch the step: {} !> {}",
        shared.step_time_mean,
        quiet.step_time_mean
    );
}

#[test]
fn step_time_monotone_in_background_load() {
    // Thinning coupling: at one seed, the accepted flow set at load a is
    // a subset of the set at load b > a, so adding load can only add
    // contention. (The tolerance absorbs sub-nanosecond re-association
    // noise from max-min re-solves; any real violation dwarfs it.)
    let mut last = 0.0f64;
    for load in [0.0, 0.1, 0.3, 0.6] {
        let tenancy = if load > 0.0 {
            TenancySpec::neighbor_incast(load)
        } else {
            TenancySpec::default()
        };
        let r = trainer(FabricKind::EthernetRoce25, tenancy).run(32, &spec(3)).unwrap();
        assert!(
            r.step_time_mean + 1e-9 >= last,
            "load {load}: step {} < previous {last}",
            r.step_time_mean
        );
        last = r.step_time_mean;
    }
}

#[test]
fn tenancy_seeds_are_reproducible_and_matter() {
    let run = |seed: u64| {
        let mut t = TenancySpec::neighbor_incast(0.5);
        t.seed = seed;
        trainer(FabricKind::EthernetRoce25, t).run(16, &spec(3)).unwrap()
    };
    let a = run(11);
    let b = run(11);
    assert_eq!(a.step_time_mean.to_bits(), b.step_time_mean.to_bits(), "same seed replays");
    assert_eq!(a.step_time_p95.to_bits(), b.step_time_p95.to_bits());
    let c = run(12);
    assert_ne!(
        a.step_time_mean.to_bits(),
        c.step_time_mean.to_bits(),
        "a different tenancy seed must see a different realization"
    );
}

#[test]
fn tenancy_sweep_stable_across_jobs_and_answers_the_shared_question() {
    // One pair of sweep runs carries every grid-level assertion (the
    // 24-cell grid is 24 full trainer simulations — don't run it more
    // than twice).
    let (seq, pts) = ablations::tenancy_sweep_with(true, &Runner::sequential());
    let (par, _) = ablations::tenancy_sweep_with(true, &Runner::new(4));
    assert_eq!(seq.to_csv(), par.to_csv(), "CSV must not depend on --jobs");

    assert_eq!(pts.len(), 24); // 2 fabrics x 4 loads x 3 gpu counts
    assert_eq!(seq.rows.len(), 24);
    assert!(pts.iter().all(|p| p.images_per_sec > 0.0));

    let eth = |load: f64, gpus: usize| {
        pts.iter()
            .find(|p| p.fabric.contains("GbE") && p.load == load && p.gpus == gpus)
            .unwrap()
    };
    // THE acceptance cell: on 25 GbE at 128 GPUs, a 60%-loaded shared
    // fabric exposes strictly more communication than a dedicated one —
    // the paper's shared-vs-dedicated question is now a measurable axis.
    assert!(
        eth(0.6, 128).exposed_secs > eth(0.0, 128).exposed_secs,
        "shared 25GbE@128 must expose more comm: {} !> {}",
        eth(0.6, 128).exposed_secs,
        eth(0.0, 128).exposed_secs
    );
    // Seed-paired + thinning-coupled cells: the load axis is monotone in
    // step time at the scale where training spans racks.
    let mut last = 0.0f64;
    for load in [0.0, 0.1, 0.3, 0.6] {
        let step = eth(load, 128).step_time_mean;
        assert!(step + 1e-9 >= last, "load {load}: step {step} < {last}");
        last = step;
    }
}
