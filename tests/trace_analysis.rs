//! Dedicated coverage for `fabric::trace` analysis: span / per-node byte
//! accounting / inter-rack split (including empty-trace edge cases), the
//! per-tenant breakdown added by the shared-tenancy model, and an
//! integration pass that checks what the engine actually records.

use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{ClusterSpec, FabricKind, TenancySpec, TransportOptions};
use fabricbench::fabric::tenancy::BackgroundTraffic;
use fabricbench::fabric::{FlowReq, MessageEvent, NetSim, Trace};

fn ev(
    src: usize,
    dst: usize,
    bytes: f64,
    start: f64,
    end: f64,
    xr: bool,
    tenant: usize,
) -> MessageEvent {
    MessageEvent {
        src_node: src,
        dst_node: dst,
        bytes,
        start,
        end,
        inter_rack: xr,
        tenant,
    }
}

fn sample() -> Trace {
    let mut t = Trace::default();
    t.record(ev(0, 1, 100.0, 0.0, 1.0, false, 0));
    t.record(ev(1, 2, 300.0, 0.5, 2.0, true, 0));
    t.record(ev(0, 2, 100.0, 1.0, 3.0, true, 0));
    t.record(ev(40, 3, 500.0, 0.2, 2.5, true, 1)); // a tenant's flow
    t
}

#[test]
fn empty_trace_edge_cases() {
    let t = Trace::default();
    assert!(t.is_empty());
    assert_eq!(t.len(), 0);
    assert_eq!(t.span(), (0.0, 0.0), "empty span collapses to zero, not infinities");
    assert!(t.bytes_by_node().is_empty());
    assert_eq!(t.inter_rack_byte_fraction(), 0.0);
    assert_eq!(t.tenant_bytes(), (0.0, 0.0));
    assert_eq!(t.background_byte_fraction(), 0.0);
    let tl = t.utilization_timeline(4);
    assert_eq!(tl, vec![0.0; 4], "no events -> an all-zero timeline");
    // The summary must render without panicking on the degenerate trace.
    let md = t.summary("empty").to_markdown();
    assert!(md.contains("messages"));
}

#[test]
fn span_counts_and_ordering() {
    let t = sample();
    assert_eq!(t.len(), 4);
    assert!(!t.is_empty());
    assert_eq!(t.span(), (0.0, 3.0));
    // A single event's span is its own window.
    let mut one = Trace::default();
    one.record(ev(5, 6, 10.0, 2.0, 2.5, false, 0));
    assert_eq!(one.span(), (2.0, 2.5));
}

#[test]
fn bytes_by_node_sorts_descending_and_is_training_only() {
    let by = sample().bytes_by_node();
    // The tenant's sender (node 40, 500 B) is excluded: per-node tx
    // accounting describes the training job, like the engine stats.
    assert_eq!(by.len(), 2);
    assert_eq!(by[0], (1, 300.0));
    assert_eq!(by[1], (0, 200.0), "two sends from node 0 accumulate");
    assert!(by.windows(2).all(|w| w[0].1 >= w[1].1));
}

#[test]
fn inter_rack_split_is_training_only() {
    let t = sample();
    // 300 + 100 of the job's 500 bytes crossed racks; the tenant's
    // (all-inter-rack) 500 bytes must not swamp the job's locality.
    assert!((t.inter_rack_byte_fraction() - 0.8).abs() < 1e-12);
}

#[test]
fn per_tenant_breakdown() {
    let mut t = sample();
    let (training, background) = t.tenant_bytes();
    assert_eq!(training, 500.0);
    assert_eq!(background, 500.0);
    assert!((t.background_byte_fraction() - 0.5).abs() < 1e-12);
    let md = t.summary("shared").to_markdown();
    assert!(md.contains("background byte fraction"), "summary must attribute tenants");
    // Attributed fleet tenants break down per id; the anonymous
    // generator's flows (id 1) and a job's (id 9) stay separate.
    t.record(ev(41, 4, 200.0, 0.3, 1.5, true, 9));
    assert_eq!(t.bytes_by_tenant(), vec![(0, 500.0), (1, 500.0), (9, 200.0)]);
    assert_eq!(t.tenant_bytes(), (500.0, 700.0));
}

#[test]
fn utilization_timeline_conserves_bytes() {
    let t = sample();
    for buckets in [1, 3, 10] {
        let tl = t.utilization_timeline(buckets);
        assert_eq!(tl.len(), buckets);
        let total: f64 = tl.iter().sum();
        assert!((total - 1000.0).abs() < 1e-9, "buckets={buckets}: {total}");
    }
}

#[test]
fn engine_trace_attributes_tenants() {
    // End to end: a traced simulator under background load records both
    // tenants, flags them correctly, and the analysis splits them.
    let mut sim = NetSim::new(
        fabric(FabricKind::EthernetRoce25),
        ClusterSpec::txgaia(),
        TransportOptions::default(),
    );
    let bg = BackgroundTraffic::new(
        &TenancySpec::neighbor_incast(0.7),
        &sim.fabric,
        &sim.cluster,
        3,
    )
    .unwrap();
    sim.set_background(bg);
    sim.enable_trace();
    let ep = |node: usize| NetSim::endpoint(node, 0, fabricbench::cluster::EndpointKind::Cpu);
    let bytes = 64.0 * 1024.0 * 1024.0;
    let reqs: Vec<FlowReq> =
        (0..8).map(|i| FlowReq { src: ep(8 + i), dst: ep(i), bytes, ready: 0.0 }).collect();
    sim.transfer_batch(&reqs);
    let trace = sim.trace.as_ref().unwrap();
    let training = trace.events.iter().filter(|e| !e.is_background()).count();
    let background = trace.events.iter().filter(|e| e.is_background()).count();
    assert_eq!(training, 8, "every training flow is recorded exactly once");
    assert!(background > 0, "the tenant's flows are traced too");
    assert_eq!(background as u64, sim.stats.background_messages, "trace and stats agree");
    let (tb, bb) = trace.tenant_bytes();
    assert_eq!(tb, 8.0 * bytes);
    assert!((bb - sim.stats.background_bytes).abs() < 1e-6);
    assert!(trace.background_byte_fraction() > 0.0);
    assert!(trace.events.iter().all(|e| e.end > e.start));
}
