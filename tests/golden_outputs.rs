//! Golden-file regression tests: the CSV artifacts of the deterministic
//! experiment drivers (`table1`, `fig3`) are compared byte-for-byte
//! against fixtures under `tests/golden/`.
//!
//! * First run (fixture missing): the current output is recorded and the
//!   test passes — the bootstrap is itself the regen path, so a fresh
//!   checkout self-seeds on its first `cargo test`.
//! * Mismatch: the test fails with the offset/line/column of the first
//!   differing byte and both lines.
//! * Intentional change: `FABRICBENCH_REGEN_GOLDEN=1 cargo test -q`
//!   rewrites the fixtures.

use fabricbench::experiments::{fig3, table1};
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn check_golden(name: &str, csv: &str) {
    let path = golden_dir().join(format!("{name}.csv"));
    let regen = std::env::var("FABRICBENCH_REGEN_GOLDEN").is_ok();
    if regen || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, csv).unwrap();
        if !regen {
            eprintln!(
                "golden: bootstrapped {} — first run records the current output",
                path.display()
            );
        }
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    if want == csv {
        return;
    }
    let pos = want
        .bytes()
        .zip(csv.bytes())
        .position(|(a, b)| a != b)
        .unwrap_or_else(|| want.len().min(csv.len()));
    let upto = &csv[..pos.min(csv.len())];
    let line = upto.matches('\n').count() + 1;
    let col = pos - upto.rfind('\n').map_or(0, |i| i + 1);
    panic!(
        "golden mismatch for '{name}': first differing byte at offset {pos} (line {line}, column {col})\n\
         expected {} bytes, got {} bytes\n\
         expected line: {:?}\n\
         actual   line: {:?}\n\
         If the change is intentional, regenerate with:\n\
         FABRICBENCH_REGEN_GOLDEN=1 cargo test -q golden",
        want.len(),
        csv.len(),
        want.lines().nth(line - 1).unwrap_or("<past end>"),
        csv.lines().nth(line - 1).unwrap_or("<past end>"),
    );
}

#[test]
fn table1_csv_matches_golden() {
    check_golden("table1", &table1::run().to_csv());
}

#[test]
fn fig3_quick_csv_matches_golden() {
    // The CFD model has no stochastic terms, so the quick sweep is fully
    // deterministic — any CSV drift is a genuine model/engine change.
    let (t, _) = fig3::run(true);
    check_golden("fig3_quick", &t.to_csv());
}

#[test]
fn golden_runs_are_reproducible_in_process() {
    // The property the fixtures rely on: two in-process runs are
    // byte-identical (no hidden wall-clock or HashMap-order dependence).
    assert_eq!(table1::run().to_csv(), table1::run().to_csv());
    let (a, _) = fig3::run(true);
    let (b, _) = fig3::run(true);
    assert_eq!(a.to_csv(), b.to_csv());
}
