//! Property suite for the multi-tier topology subsystem (routing +
//! reduction timing), locking down four guarantees:
//!
//! (a) **Default equivalence** — the default spec (one spine fed by the
//!     fabric's scalar `rack_uplink_gbps`) reduces *bit-for-bit* to the
//!     pre-topology rack-uplink model, across the fig3 driver cells and
//!     trainer runs, and the committed golden CSVs are unchanged.
//! (b) **Route determinism and symmetry** — `route(a -> b)` is a pure
//!     function of `(endpoints, flow_seq, seed)` and the mirror image of
//!     `route(b -> a)`.
//! (c) **Per-link flow conservation** — a flow occupies exactly the
//!     links of its route, observable via per-link drain times.
//! (d) **Oversubscription monotonicity** — worsening the leaf->spine
//!     taper never speeds anything up, and saturating traffic strictly
//!     slows down.

use fabricbench::cfd::solver::StrongScaling;
use fabricbench::cluster::{EndpointKind, Placement};
use fabricbench::collectives::{Collective, NullBuffers, RecursiveHalvingDoubling};
use fabricbench::config::presets::{fabric, paper_fabrics};
use fabricbench::config::spec::{
    ClusterSpec, FabricKind, RunSpec, TopologyKind, TopologySpec, TransportOptions,
};
use fabricbench::config::toml;
use fabricbench::fabric::topology::Topology;
use fabricbench::fabric::{Comm, FlowReq, NetSim};
use fabricbench::util::prop;

fn cpu_ep(node: usize) -> fabricbench::cluster::Endpoint {
    NetSim::endpoint(node, 0, EndpointKind::Cpu)
}

/// An explicit fat-tree spec that must be indistinguishable from the
/// default: one spine, leaf = rack, uplink pinned to the fabric scalar.
fn explicit_legacy_spec(kind: FabricKind, cluster: &ClusterSpec) -> TopologySpec {
    let f = fabric(kind);
    TopologySpec {
        kind: TopologyKind::FatTree,
        leaf_ports: Some(cluster.nodes_per_rack),
        spines: 1,
        uplink_gbps: Some(f.rack_uplink_gbps),
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// (a) default equivalence
// ---------------------------------------------------------------------

#[test]
fn explicit_one_spine_fat_tree_matches_default_on_fig3_cells() {
    // The fig3 driver cells are RNG-free: comparing the full ScalingPoint
    // to_bits pins the engine's resource wiring, not a tolerance.
    let scaling = StrongScaling::paper();
    for base in paper_fabrics() {
        let mut explicit = base.clone();
        explicit.topology = explicit_legacy_spec(base.kind, &scaling.cluster);
        for cores in [40usize, 320, 1280, 2560, 5120] {
            let a = scaling.run_point(&base, cores).unwrap();
            let b = scaling.run_point(&explicit, cores).unwrap();
            assert_eq!(
                a.comm_time.to_bits(),
                b.comm_time.to_bits(),
                "{} @ {cores} cores: comm {} vs {}",
                base.name,
                a.comm_time,
                b.comm_time
            );
            assert_eq!(a.comm_wire_time.to_bits(), b.comm_wire_time.to_bits());
            assert_eq!(a.compute_time.to_bits(), b.compute_time.to_bits());
            assert_eq!(a.inter_rack_messages, b.inter_rack_messages);
        }
    }
}

#[test]
fn explicit_one_spine_fat_tree_matches_default_on_trainer_cells() {
    // Table-1-style trainer cells (the stochastic path): same seed, same
    // bits. 128 GPUs spans two ToRs, so the up/down links are genuinely
    // exercised, not just allocated.
    let cluster = ClusterSpec::txgaia();
    for gpus in [32usize, 128] {
        for base in paper_fabrics() {
            let mut explicit = base.clone();
            explicit.topology = explicit_legacy_spec(base.kind, &cluster);
            let mk = |fab: fabricbench::config::FabricSpec| fabricbench::trainer::TrainerSim {
                arch: fabricbench::models::zoo::resnet50(),
                fabric: fab,
                cluster: cluster.clone(),
                opts: TransportOptions::default(),
                strategy: Box::new(fabricbench::collectives::RingAllreduce),
                per_gpu_batch: 64,
                precision: fabricbench::models::perf::Precision::Fp32,
                fusion_bytes: 64.0 * fabricbench::util::units::MIB,
                overlap: true,
                step_overhead: 0.0,
                coordination_overhead:
                    fabricbench::trainer::coordinator::DEFAULT_COORDINATION_OVERHEAD,
                tenancy: fabricbench::config::TenancySpec::default(),
                workload: fabricbench::config::WorkloadSpec::default(),
                faults: fabricbench::fabric::FaultSpec::default(),
            };
            let spec = RunSpec { measure_steps: 3, warmup_steps: 1, ..Default::default() };
            let a = mk(base.clone()).run(gpus, &spec).unwrap();
            let b = mk(explicit).run(gpus, &spec).unwrap();
            assert_eq!(
                a.step_time_mean.to_bits(),
                b.step_time_mean.to_bits(),
                "{} @ {gpus} GPUs: {} vs {}",
                base.name,
                a.step_time_mean,
                b.step_time_mean
            );
            assert_eq!(a.comm_fraction.to_bits(), b.comm_fraction.to_bits());
        }
    }
}

#[test]
fn committed_goldens_unchanged_under_default_topology() {
    // The committed fixtures predate the topology subsystem: regenerating
    // them through the route-derived engine must be a no-op. (Mirrors
    // tests/golden_outputs.rs but exists here so a topology regression
    // is reported as a topology failure, with a clearer message.)
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    let require = std::env::var("FABRICBENCH_REQUIRE_GOLDEN").is_ok();
    for (name, csv) in [
        ("table1", fabricbench::experiments::table1::run().to_csv()),
        ("fig3_quick", fabricbench::experiments::fig3::run(true).0.to_csv()),
    ] {
        let path = dir.join(format!("{name}.csv"));
        if !path.exists() {
            assert!(!require, "golden fixture {} missing", path.display());
            continue; // golden_outputs.rs owns bootstrap behavior
        }
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            want, csv,
            "default topology changed the '{name}' golden CSV — the \
             bit-for-bit legacy-equivalence guarantee is broken"
        );
    }
}

// ---------------------------------------------------------------------
// (b) deterministic, symmetric routes
// ---------------------------------------------------------------------

#[test]
fn routes_are_deterministic_and_symmetric() {
    let cluster = ClusterSpec::txgaia();
    let spec = TopologySpec { spines: 8, oversubscription: Some(2.0), ..Default::default() };
    let topo = Topology::build(&spec, &fabric(FabricKind::EthernetRoce25), &cluster).unwrap();
    prop::forall(
        0x7070_0901,
        256,
        |r| (r.below(448) as usize, r.below(448) as usize, r.next_u64() % 64),
        |&(a, b, seq)| {
            if a == b {
                return Ok(());
            }
            let f1 = topo.route(a, b, seq);
            let f2 = topo.route(a, b, seq);
            let rev = topo.route(b, a, seq);
            let ids1: Vec<usize> = f1.res.iter().collect();
            if ids1 != f2.res.iter().collect::<Vec<_>>() {
                return Err(format!("route({a},{b},{seq}) not deterministic"));
            }
            // Mirror image: reverse the reverse route and map each link
            // to its forward counterpart (tx<->rx, up<->down same spine).
            let mut mirrored: Vec<usize> = rev
                .res
                .iter()
                .map(|id| mirror_link(&topo, id))
                .collect();
            mirrored.reverse();
            if ids1 != mirrored {
                return Err(format!(
                    "route({a},{b},{seq}) != mirror of route({b},{a},{seq}): {ids1:?} vs {mirrored:?}"
                ));
            }
            if f1.spine != rev.spine {
                return Err(format!("spine differs: {:?} vs {:?}", f1.spine, rev.spine));
            }
            Ok(())
        },
    );
}

/// Map a link id to its reverse-direction counterpart: tx(n) <-> rx(n),
/// up(t, s) <-> down(t, s); dragonfly global-out(g) <-> global-in(g).
fn mirror_link(topo: &Topology, id: usize) -> usize {
    let n = topo.n_nodes;
    let ts = topo.n_tors * topo.n_spines;
    if id < n {
        topo.rx_id(id)
    } else if id < 2 * n {
        topo.tx_id(id - n)
    } else if id < 2 * n + ts {
        id + ts // up -> down, same (tor, spine)
    } else if id < 2 * n + 2 * ts {
        id - ts
    } else if id < 2 * n + 2 * ts + topo.n_groups {
        id + topo.n_groups // global-out -> global-in, same group
    } else {
        id - topo.n_groups
    }
}

// ---------------------------------------------------------------------
// (c) per-link flow conservation
// ---------------------------------------------------------------------

#[test]
fn a_flow_occupies_exactly_its_route() {
    // Submit one cross-ToR flow on a fresh engine: after the batch, the
    // drain time is positive on precisely the four links of its route
    // and zero everywhere else.
    let f = fabric(FabricKind::EthernetRoce25);
    let cluster = ClusterSpec::txgaia();
    let mut s = NetSim::new(f, cluster, TransportOptions::default());
    let times = s.transfer_batch(&[FlowReq {
        src: cpu_ep(3),
        dst: cpu_ep(70),
        bytes: 1e6,
        ready: 0.0,
    }]);
    assert!(times[0].recv_complete > 0.0);
    let route = s.topology.route(3, 70, 0); // seq 0: the flow just sent
    let route_ids: std::collections::BTreeSet<usize> = route.res.iter().collect();
    assert_eq!(route_ids.len(), 4, "cross-ToR route must hold 4 links");
    for id in 0..s.topology.num_resources() {
        let busy = s.resource_busy_until(id);
        if route_ids.contains(&id) {
            assert!(busy > 0.0, "route link {} idle", s.topology.link_label(id));
        } else {
            assert_eq!(busy, 0.0, "off-route link {} touched", s.topology.link_label(id));
        }
    }
}

#[test]
fn batch_occupancy_is_the_union_of_routes() {
    // Several flows (intra- and inter-ToR, shared sources): the set of
    // touched links is exactly the union of the per-flow routes.
    let f = fabric(FabricKind::OmniPath100);
    let cluster = ClusterSpec::txgaia();
    let mut s = NetSim::new(f, cluster, TransportOptions::default());
    let pairs = [(0usize, 1usize), (0, 40), (5, 100), (33, 34), (100, 5)];
    let reqs: Vec<FlowReq> = pairs
        .iter()
        .map(|&(a, b)| FlowReq { src: cpu_ep(a), dst: cpu_ep(b), bytes: 1e5, ready: 0.0 })
        .collect();
    s.transfer_batch(&reqs);
    let mut expect = std::collections::BTreeSet::new();
    let mut seq = std::collections::HashMap::new();
    for &(a, b) in &pairs {
        let k = seq.entry((a, b)).or_insert(0u64);
        for id in s.topology.route(a, b, *k).res.iter() {
            expect.insert(id);
        }
        *k += 1;
    }
    for id in 0..s.topology.num_resources() {
        assert_eq!(
            s.resource_busy_until(id) > 0.0,
            expect.contains(&id),
            "link {} occupancy disagrees with the route union",
            s.topology.link_label(id)
        );
    }
}

// ---------------------------------------------------------------------
// (d) oversubscription monotonicity
// ---------------------------------------------------------------------

#[test]
fn rhd_allreduce_monotone_in_oversubscription() {
    // 128 GPUs span two ToRs; recursive halving-doubling's long-distance
    // level puts every pair across the bisection at once. Tightening the
    // taper must never help, and 8:1 must strictly hurt.
    let cluster = ClusterSpec::txgaia();
    let placement = Placement::gpus(&cluster, 128).unwrap();
    let mut times = Vec::new();
    for ratio in [1.0f64, 2.0, 4.0, 8.0] {
        let mut f = fabric(FabricKind::EthernetRoce25);
        f.topology.oversubscription = Some(ratio);
        let mut net = NetSim::new(f, cluster.clone(), TransportOptions::default());
        let mut comm = Comm::new(&mut net, &placement);
        let t = RecursiveHalvingDoubling
            .allreduce(&mut comm, &mut NullBuffers { elems: 4_000_000 });
        if let Some(&last) = times.last() {
            assert!(t + 1e-12 >= last, "ratio {ratio}: allreduce sped up ({t} < {last})");
        }
        times.push(t);
    }
    assert!(
        times[3] > times[0] * 1.02,
        "8:1 vs 1:1 should be measurably slower: {times:?}"
    );
}

#[test]
fn symmetric_cross_tor_batch_monotone_in_oversubscription() {
    // Engine-level version with no collective structure: 32 saturating
    // rack0 <-> rack1 flows.
    let cluster = ClusterSpec::txgaia();
    let mut last = 0.0;
    for ratio in [1.0f64, 2.0, 4.0, 8.0] {
        let mut f = fabric(FabricKind::EthernetRoce25);
        f.topology.oversubscription = Some(ratio);
        let mut s = NetSim::new(f, cluster.clone(), TransportOptions::default());
        let mut reqs = Vec::new();
        for i in 0..16 {
            let bytes = 8.0 * 1024.0 * 1024.0;
            reqs.push(FlowReq { src: cpu_ep(i), dst: cpu_ep(32 + i), bytes, ready: 0.0 });
            reqs.push(FlowReq { src: cpu_ep(32 + i), dst: cpu_ep(i), bytes, ready: 0.0 });
        }
        let t = s
            .transfer_batch(&reqs)
            .iter()
            .map(|ft| ft.recv_complete)
            .fold(0.0, f64::max);
        assert!(t + 1e-12 >= last, "ratio {ratio}: {t} < {last}");
        last = t;
    }
}

// ---------------------------------------------------------------------
// negative paths: TOML + cluster validation through the public surface
// ---------------------------------------------------------------------

#[test]
fn topology_toml_negative_paths_are_loud() {
    // Value errors (zero-capacity link, sub-unity ratio) and type errors,
    // in the same loud style as the [transport] table.
    for doc in [
        "uplink_gbps = 0.0",
        "oversubscription = 0.99",
        "spines = 0",
        "groups = 0",
        "global_oversubscription = 0.5",
        "kind = \"hypercube\"",
        "spines = \"many\"",
        "oversubscription = false",
        "leaf_ports = 2.5",
    ] {
        let parsed = toml::parse(doc).unwrap();
        assert!(
            TopologySpec::from_toml(&parsed).is_err(),
            "'{doc}' must be rejected loudly"
        );
    }
}

#[test]
fn try_new_rejects_more_nodes_than_leaf_ports() {
    let mut cluster = ClusterSpec::txgaia();
    cluster.nodes = 32;
    cluster.nodes_per_rack = 8;
    let mut f = fabric(FabricKind::OmniPath100);
    f.topology.tors = Some(2);
    f.topology.leaf_ports = Some(8); // 16 downlinks for 32 nodes
    let err = NetSim::try_new(f, cluster, TransportOptions::default())
        .err()
        .expect("undersized leaf tier must be rejected")
        .to_string();
    assert!(err.contains("leaf"), "unexpected error text: {err}");
}
