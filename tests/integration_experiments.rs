//! Integration tests: experiment drivers end-to-end (quick sweeps),
//! CSV emission, and cross-module consistency.

use fabricbench::experiments::{ablations, affinity, fig3, fig4, fig5, microbench, table1};
use fabricbench::metrics::Recorder;

#[test]
fn table1_emits_and_saves() {
    let t = table1::run();
    assert_eq!(t.rows.len(), 4);
    let dir = std::env::temp_dir().join("fb_it_table1");
    let rec = Recorder::at(&dir);
    let path = rec.save("table1", &t).unwrap();
    let csv = std::fs::read_to_string(path).unwrap();
    assert!(csv.lines().count() == 5);
    assert!(csv.contains("resnet50"));
}

#[test]
fn fig3_quick_has_both_fabrics() {
    let (t, rows) = fig3::run(true);
    assert!(t.rows.len() >= 10);
    assert!(rows.iter().any(|r| r.fabric.contains("GbE")));
    assert!(rows.iter().any(|r| r.fabric.contains("OPA")));
    // Strong scaling sanity on the quick sweep.
    for fab in ["GbE", "OPA"] {
        let pts: Vec<_> = rows.iter().filter(|r| r.fabric.contains(fab)).collect();
        assert!(pts.windows(2).all(|w| w[1].compute <= w[0].compute));
    }
}

#[test]
fn fig4_quick_deficit_and_monotonicity() {
    let (t, rows) = fig4::run(true);
    assert_eq!(t.rows.len(), rows.len());
    let deficit = fig4::mean_ethernet_deficit(&rows);
    assert!(deficit > 0.0, "Ethernet should lose on average, got {deficit}%");
    // Every (model, fabric) series is monotone in GPUs.
    for r in &rows {
        assert!(r.images_per_sec > 0.0);
        assert!(r.scaling_eff <= 1.05);
    }
}

#[test]
fn fig5_quick_strategies_consistent() {
    let (_, rows) = fig5::run(true);
    // Same cell from different strategies should be within 3x (they all
    // hide most comm under compute at quick scales).
    let cell = |strategy: &str| {
        rows.iter()
            .find(|r| {
                r.model == "resnet50"
                    && r.strategy.contains(strategy)
                    && r.fabric.contains("OPA")
                    && r.gpus == 32
            })
            .unwrap()
            .images_per_sec
    };
    let ring = cell("ring");
    let rhd = cell("rhd");
    let hier = cell("hier");
    for (name, v) in [("rhd", rhd), ("hier", hier)] {
        let ratio = v / ring;
        assert!((0.33..3.0).contains(&ratio), "{name}: ratio to ring = {ratio}");
    }
}

#[test]
fn affinity_not_significant() {
    let (_, results) = affinity::run(true);
    for r in results {
        for ((_, _), p) in r.p_values {
            assert!(p > 0.05);
        }
    }
}

#[test]
fn microbench_tables_consistent_with_specs() {
    let t = microbench::p2p(true);
    // Large-message achieved GB/s column must be below each line rate.
    for row in &t.rows {
        let gbs: f64 = row[3].parse().unwrap();
        assert!(gbs < 13.0, "achieved {gbs} GB/s exceeds any fabric here");
    }
}

#[test]
fn ablations_quick() {
    let (t1, pts1) = ablations::fusion_sweep(true);
    assert_eq!(t1.rows.len(), pts1.len());
    let (t2, pts2) = ablations::toggles(true);
    assert_eq!(t2.rows.len(), pts2.len());
    assert!(pts2[0].images_per_sec > 0.0);
}
