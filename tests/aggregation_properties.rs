//! Property tests for frontier-scale flow aggregation: collapsing
//! same-route flows into integer-weighted fluid aggregates must be a
//! pure engine speedup — per-flow completion times are **bit-identical**
//! with aggregation on vs off (not merely within a tolerance; the
//! weighted max-min solve performs the same f64 operations as the
//! expanded one), and the event/solve counters match too. Exercised
//! through the public `transfer_batch` API over mixed
//! aggregated/singleton batches, shared-tenancy background flows, and
//! ECMP multi-spine topologies.

use fabricbench::cluster::{EndpointKind, Placement};
use fabricbench::collectives::{Collective, Hierarchical, NullBuffers};
use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{
    ClusterSpec, FabricKind, TopologyKind, TopologySpec, TransportOptions,
};
use fabricbench::config::TenancySpec;
use fabricbench::fabric::{BackgroundTraffic, Comm, FlowReq, NetSim};
use fabricbench::util::rng::Rng;

fn opts(aggregation: bool) -> TransportOptions {
    TransportOptions { flow_aggregation: aggregation, ..Default::default() }
}

/// Random batch mixing duplicate-route flows (same src/dst/bytes/ready,
/// several copies) with singletons — both aggregation regimes in one
/// solve, across both GPU and CPU endpoints.
fn random_mixed_batch(rng: &mut Rng, nodes: usize) -> Vec<FlowReq> {
    let mut reqs = Vec::new();
    let n_groups = 1 + rng.below(8) as usize;
    for _ in 0..n_groups {
        let src = rng.below(nodes as u64) as usize;
        let mut dst = rng.below(nodes as u64) as usize;
        if dst == src {
            dst = (dst + 1) % nodes;
        }
        let kind = if rng.below(2) == 0 { EndpointKind::Gpu } else { EndpointKind::Cpu };
        let bytes = match rng.below(4) {
            0 => 0.0, // zero-byte flows complete at arrival
            1 => 512.0,
            2 => 1.5e6,
            _ => 64.0 * 1024.0 * 1024.0,
        };
        let ready = rng.below(4) as f64 * 75.0e-6;
        let copies = 1 + rng.below(5) as usize; // 1 = singleton
        for _ in 0..copies {
            reqs.push(FlowReq {
                src: NetSim::endpoint(src, 0, kind),
                dst: NetSim::endpoint(dst, 0, kind),
                bytes,
                ready,
            });
        }
    }
    reqs
}

fn assert_batches_bit_identical(
    label: &str,
    mut on: NetSim,
    mut off: NetSim,
    batches: &[Vec<FlowReq>],
) {
    for (bi, reqs) in batches.iter().enumerate() {
        let t_on = on.transfer_batch(reqs);
        let t_off = off.transfer_batch(reqs);
        for (i, (a, b)) in t_on.iter().zip(&t_off).enumerate() {
            assert_eq!(
                a.recv_complete.to_bits(),
                b.recv_complete.to_bits(),
                "{label}: batch {bi} flow {i} recv_complete {} vs {}",
                a.recv_complete,
                b.recv_complete
            );
            assert_eq!(
                a.send_release.to_bits(),
                b.send_release.to_bits(),
                "{label}: batch {bi} flow {i} send_release"
            );
        }
    }
    // The aggregated loop walks the same event sequence over fewer
    // flow records: engine counters must agree exactly.
    assert_eq!(on.stats.fluid_events, off.stats.fluid_events, "{label}: fluid_events");
    assert_eq!(on.solver.solves, off.solver.solves, "{label}: solves");
    assert_eq!(on.solver.rounds, off.solver.rounds, "{label}: rounds");
    assert_eq!(on.stats.budget_exceeded, off.stats.budget_exceeded, "{label}: budget");
    assert_eq!(off.stats.agg_collapsed, 0, "{label}: off path must not collapse");
    assert!(
        on.stats.agg_collapsed > 0,
        "{label}: trials must include genuinely collapsed flows"
    );
}

#[test]
fn mixed_batches_bit_identical_across_aggregation_toggle() {
    let cluster = ClusterSpec::txgaia();
    let mut rng = Rng::new(0xA66_0001);
    let on = NetSim::new(fabric(FabricKind::EthernetRoce25), cluster.clone(), opts(true));
    let off = NetSim::new(fabric(FabricKind::EthernetRoce25), cluster, opts(false));
    let batches: Vec<Vec<FlowReq>> =
        (0..40).map(|_| random_mixed_batch(&mut rng, 48)).collect();
    assert_batches_bit_identical("mixed", on, off, &batches);
}

#[test]
fn tenancy_background_flows_bit_identical_across_toggle() {
    // Background tenant flows join every fluid batch; attribution and
    // tracing happen per-flow outside the solve, so tenant traffic
    // aggregates like any other same-route flow — and the shared-fabric
    // timings must stay bit-identical.
    let cluster = ClusterSpec::txgaia();
    let spec = TenancySpec {
        src_first: Some(64),
        src_count: Some(16),
        dst_first: Some(32),
        dst_count: Some(8),
        ..TenancySpec::neighbor_incast(0.5)
    };
    let build = |agg: bool| {
        let mut net = NetSim::new(fabric(FabricKind::EthernetRoce25), cluster.clone(), opts(agg));
        let bg = BackgroundTraffic::new(&spec, &net.fabric, &net.cluster, 11).unwrap();
        net.set_background(bg);
        net
    };
    let mut rng = Rng::new(0xA66_0002);
    let batches: Vec<Vec<FlowReq>> =
        (0..25).map(|_| random_mixed_batch(&mut rng, 40)).collect();
    let (mut on, mut off) = (build(true), build(false));
    for (bi, reqs) in batches.iter().enumerate() {
        let t_on = on.transfer_batch(reqs);
        let t_off = off.transfer_batch(reqs);
        for (i, (a, b)) in t_on.iter().zip(&t_off).enumerate() {
            assert_eq!(
                a.recv_complete.to_bits(),
                b.recv_complete.to_bits(),
                "tenancy: batch {bi} flow {i}"
            );
        }
    }
    assert!(on.stats.background_messages > 0, "tenant must have injected flows");
    assert_eq!(on.stats.background_messages, off.stats.background_messages);
    assert_eq!(on.stats.fluid_events, off.stats.fluid_events);
    assert_eq!(on.stats.budget_exceeded, off.stats.budget_exceeded);
    assert!(on.stats.agg_collapsed > 0, "incast duplicates must collapse");
}

#[test]
fn ecmp_multi_spine_keys_routes_apart_and_stays_bit_identical() {
    // On a 4-spine oversubscribed fat-tree, same-(src,dst) flows can hash
    // to different spines (distinct routes) — the aggregation key is the
    // exact resource route, so ECMP-split flows must stay separate units
    // while same-spine duplicates still collapse. Either way: bit-exact.
    let mut cluster = ClusterSpec::txgaia();
    cluster.nodes_per_rack = 8;
    let topo = TopologySpec {
        kind: TopologyKind::FatTree,
        spines: 4,
        oversubscription: Some(4.0),
        ..TopologySpec::default()
    };
    let build = |agg: bool| {
        let mut fab = fabric(FabricKind::OmniPath100);
        fab.topology = topo;
        fab.topology.validate_for(&cluster).unwrap();
        NetSim::new(fab, cluster.clone(), opts(agg))
    };
    let mut rng = Rng::new(0xA66_0003);
    let (mut on, mut off) = (build(true), build(false));
    let mut collapsed_total = 0u64;
    for bi in 0..30 {
        // Cross-rack fan: many copies between few node pairs, so the
        // engine assigns several flow_seq values per pair and ECMP
        // spreads them over spines.
        let mut reqs = Vec::new();
        for _ in 0..(2 + rng.below(4)) {
            let src = rng.below(8) as usize;
            let dst = 8 + rng.below(8) as usize;
            let bytes = [4096.0, 2.0e6, 16.0e6][rng.below(3) as usize];
            for _ in 0..(1 + rng.below(6)) {
                reqs.push(FlowReq {
                    src: NetSim::endpoint(src, 0, EndpointKind::Cpu),
                    dst: NetSim::endpoint(dst, 0, EndpointKind::Cpu),
                    bytes,
                    ready: 0.0,
                });
            }
        }
        let t_on = on.transfer_batch(&reqs);
        let t_off = off.transfer_batch(&reqs);
        for (i, (a, b)) in t_on.iter().zip(&t_off).enumerate() {
            assert_eq!(
                a.recv_complete.to_bits(),
                b.recv_complete.to_bits(),
                "ecmp: batch {bi} flow {i}"
            );
        }
        collapsed_total = on.stats.agg_collapsed;
    }
    assert_eq!(on.stats.fluid_events, off.stats.fluid_events);
    assert_eq!(on.solver.solves, off.solver.solves);
    assert!(collapsed_total > 0, "same-spine duplicates must still collapse");
    assert!(
        on.stats.agg_units > collapsed_total / 8,
        "ECMP split must keep distinct routes as distinct units"
    );
}

#[test]
fn hierarchical_collective_round_trips_the_whole_stack() {
    // End-to-end through Comm + a real collective on 8-GPU nodes (the
    // frontier shape): per-rank completion clocks bit-identical.
    let mut cluster = ClusterSpec::txgaia();
    cluster.gpus_per_node = 8;
    cluster.nodes_per_rack = 4;
    let placement = Placement::gpus(&cluster, 64).unwrap();
    let run = |agg: bool| {
        let mut net = NetSim::new(fabric(FabricKind::EthernetRoce25), cluster.clone(), opts(agg));
        let t = {
            let mut comm = Comm::new(&mut net, &placement);
            Hierarchical::default().allreduce(&mut comm, &mut NullBuffers { elems: 1 << 18 })
        };
        (t, net.stats.fluid_events, net.stats.agg_collapsed)
    };
    let (t_on, ev_on, collapsed) = run(true);
    let (t_off, ev_off, _) = run(false);
    assert_eq!(t_on.to_bits(), t_off.to_bits());
    assert_eq!(ev_on, ev_off);
    assert!(collapsed > 0, "8-GPU nodes produce same-route flows");
}
