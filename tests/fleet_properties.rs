//! Fleet-scheduler property suite: the determinism contract (bit-for-bit
//! single-job reproduction, fixed-seed replay, `--jobs`-independent sweep
//! CSV), the churn edge cases (priority preemption, checkpoint-restart
//! accounting around a node failure), and the placement-policy ordering
//! the ISSUE's acceptance cell pins (topology-aware <= pack <= spread on
//! p99 JCT at 60% occupancy of the oversubscribed fat-tree cell).

use fabricbench::cluster::jobs::job_trace;
use fabricbench::cluster::FleetSim;
use fabricbench::config::{ClusterSpec, FleetSpec, PlacementPolicy, RunSpec};
use fabricbench::experiments::fleet::{fleet_sweep_with, fleet_trainer};
use fabricbench::experiments::Runner;
use fabricbench::trainer::TrainerSim;

fn spec(seed: u64) -> RunSpec {
    RunSpec { seed, warmup_steps: 1, measure_steps: 3, ..Default::default() }
}

#[test]
fn single_job_fleet_reproduces_standalone_trainer_bitwise() {
    // The acceptance pin: a one-job, no-churn fleet IS the standalone
    // trainer. Pack over an empty cluster places nodes [0..4), which is
    // block placement; the job's inner run seed is exactly the run seed;
    // no neighbor -> no tenants -> the timing cache behaves identically.
    let trainer = fleet_trainer();
    let run = spec(0xFAB0_15);
    let fleet = FleetSpec::single_job(4, 25);
    let report = FleetSim::new(&trainer, fleet).unwrap().run(&run).unwrap();
    assert_eq!(report.jobs.len(), 1);
    let job = &report.jobs[0];
    assert_eq!((job.nodes, job.gpus, job.steps, job.preemptions), (4, 8, 25, 0));

    let standalone = trainer.run(8, &run).unwrap();
    assert_eq!(
        job.step_time.to_bits(),
        standalone.step_time_mean.to_bits(),
        "fleet job 1 must reproduce TrainerSim::run bit-for-bit: {} vs {}",
        job.step_time,
        standalone.step_time_mean
    );
    // And the schedule around it is exact linear accounting: arrival 0,
    // no restart, JCT = steps x step time.
    assert!(job.arrival == 0.0 && job.jct > 0.0);
    let want = 25.0 * standalone.step_time_mean;
    assert!((job.jct - want).abs() < 1e-9 * want, "jct {} != steps*step {want}", job.jct);
    assert_eq!(report.preemptions, 0);
    assert_eq!(report.failures, 0);
}

/// A contended scenario: gangs of 1/3-2/3 of the cluster arriving far
/// faster than they finish, three priority levels, preemption on.
fn churn_fleet(seed: u64) -> FleetSpec {
    FleetSpec {
        jobs: 6,
        interarrival_secs: 1.0,
        gang_min: 12,
        gang_max: 24,
        steps_min: 10,
        steps_max: 20,
        priority_levels: 3,
        preemption: true,
        elastic: false,
        checkpoint_restart_secs: 5.0,
        node_failures: 0,
        repair_secs: 30.0,
        neighbor_load: 0.5,
        placement: PlacementPolicy::TopologyAware,
        seed,
    }
}

fn assert_report_invariants(fleet: &FleetSpec, r: &fabricbench::cluster::FleetReport) {
    assert_eq!(r.jobs.len(), fleet.jobs, "every job must finish");
    assert!(r.makespan > 0.0 && r.images_per_sec > 0.0);
    let sum: usize = r.jobs.iter().map(|j| j.preemptions).sum();
    assert_eq!(sum, r.preemptions, "preemption ledger must balance");
    for j in &r.jobs {
        assert!(j.completion > j.arrival, "job {}: completion before arrival", j.id);
        assert!(j.step_time > 0.0 && j.nodes > 0 && j.gpus == j.nodes * 2);
        // No lower bound against steps x step_time here: step_time is the
        // *final* placement's rate, and repricing across placements can
        // make it slower than the rate most steps actually ran at. The
        // exact accounting is pinned where the rate cannot change
        // (single-job and failure tests below).
    }
}

#[test]
fn preemption_fires_under_contention_and_everyone_still_finishes() {
    let trainer = fleet_trainer();
    let run = spec(3);
    let mut preempted = None;
    for fleet_seed in 1..=5 {
        let fleet = churn_fleet(fleet_seed);
        let r = FleetSim::new(&trainer, fleet).unwrap().run(&run).unwrap();
        assert_report_invariants(&fleet, &r);
        if r.preemptions > 0 {
            preempted = Some(r);
            break;
        }
    }
    let r = preempted.expect("no fleet seed in 1..=5 preempted under 3-level heavy contention");
    // A preempted job survives (it is in the report with a completion at
    // all), strictly outranked: a victim never outranks its evictor, so
    // no top-priority job is ever a victim.
    let top = r.jobs.iter().map(|j| j.priority).max().unwrap();
    for j in r.jobs.iter().filter(|j| j.preemptions > 0) {
        assert!(j.priority < top, "job {} at top priority {top} was preempted", j.id);
    }
}

#[test]
fn fixed_seed_replay_is_bitwise_and_seeds_matter() {
    let trainer = fleet_trainer();
    let fleet = churn_fleet(2);
    let sig = |r: &fabricbench::cluster::FleetReport| -> Vec<(u64, u64, usize, usize)> {
        r.jobs
            .iter()
            .map(|j| (j.jct.to_bits(), j.step_time.to_bits(), j.nodes, j.preemptions))
            .collect()
    };
    let a = FleetSim::new(&trainer, fleet).unwrap().run(&spec(3)).unwrap();
    let b = FleetSim::new(&trainer, fleet).unwrap().run(&spec(3)).unwrap();
    assert_eq!(sig(&a), sig(&b), "same (fleet, run) seed must replay bit-for-bit");
    let c = FleetSim::new(&trainer, fleet).unwrap().run(&spec(4)).unwrap();
    assert_ne!(sig(&a), sig(&c), "the run seed folds into trace and trainer alike");
}

#[test]
fn node_failure_costs_exactly_repair_plus_restart() {
    // A 4-node cluster fully occupied by one long job: the seeded
    // failure must hit the gang, requeue it until the repair, and charge
    // one checkpoint restart. The re-placement reuses the only possible
    // node set, so the step time memoizes to the identical value and the
    // JCT decomposes exactly: steps x step + repair + restart.
    let mut cluster = ClusterSpec::txgaia();
    cluster.nodes = 4;
    cluster.nodes_per_rack = 2;
    let trainer = TrainerSim { cluster, ..fleet_trainer() };
    let fleet = FleetSpec {
        jobs: 1,
        interarrival_secs: 1.0, // failure horizon: the first second
        gang_min: 4,
        gang_max: 4,
        steps_min: 200,
        steps_max: 200,
        priority_levels: 1,
        preemption: false,
        elastic: false,
        checkpoint_restart_secs: 5.0,
        node_failures: 1,
        repair_secs: 30.0,
        neighbor_load: 0.0,
        placement: PlacementPolicy::Pack,
        seed: 7,
    };
    let r = FleetSim::new(&trainer, fleet).unwrap().run(&spec(11)).unwrap();
    assert_report_invariants(&fleet, &r);
    assert_eq!(r.failures, 1);
    let job = &r.jobs[0];
    assert_eq!(job.preemptions, 1, "the failure must evict the gang");
    let want = 200.0 * job.step_time + 30.0 + 5.0;
    assert!(
        (job.jct - want).abs() < 1e-6 * want,
        "JCT {} != steps*step + repair + restart = {want}",
        job.jct
    );
}

#[test]
fn elastic_job_shrinks_through_a_failure_instead_of_waiting() {
    // Same deterministic failure scenario as above, but the job may
    // shrink to 2 nodes: instead of idling out the 30 s repair it drops
    // to 3 nodes immediately and grows back when the node returns. It
    // pays two checkpoint restarts (eviction + growth) yet keeps
    // training through the outage, so its JCT must beat the rigid
    // run's repair + restart overhead by a wide margin (the rigid job
    // loses the full 30 s window; the elastic one only the restarts
    // plus the 3-vs-4-node rate difference over that window).
    let mut cluster = ClusterSpec::txgaia();
    cluster.nodes = 4;
    cluster.nodes_per_rack = 2;
    let trainer = TrainerSim { cluster, ..fleet_trainer() };
    let run = spec(11);
    let base = FleetSpec {
        jobs: 1,
        interarrival_secs: 1.0,
        gang_min: 2, // elastic floor — and the low edge of the gang draw
        gang_max: 4,
        steps_min: 200,
        steps_max: 200,
        priority_levels: 1,
        preemption: false,
        elastic: true,
        checkpoint_restart_secs: 5.0,
        node_failures: 1,
        repair_secs: 30.0,
        neighbor_load: 0.0,
        placement: PlacementPolicy::Pack,
        seed: 0,
    };
    // The gang size is drawn uniformly from [2, 4]; scan fleet seeds for
    // a trace that wants the whole cluster, so the failure must evict.
    let fleet = (1..=16)
        .map(|s| FleetSpec { seed: s, ..base })
        .find(|f| job_trace(f, run.seed)[0].nodes_wanted == 4)
        .expect("no fleet seed in 1..=16 draws a 4-node gang from [2, 4]");
    let elastic = FleetSim::new(&trainer, fleet).unwrap().run(&run).unwrap();
    let rigid = FleetSim::new(&trainer, FleetSpec { elastic: false, ..fleet })
        .unwrap()
        .run(&run)
        .unwrap();
    assert_report_invariants(&fleet, &elastic);
    assert_report_invariants(&fleet, &rigid);
    assert_eq!((elastic.failures, rigid.failures), (1, 1));
    let (e, r) = (&elastic.jobs[0], &rigid.jobs[0]);
    assert_eq!(e.preemptions, 1, "the eviction counts; voluntary growth does not");
    assert_eq!(e.nodes, 4, "grown back to the full gang after the repair");
    assert_eq!(r.nodes, 4);
    assert!(
        e.jct < r.jct - 15.0,
        "elastic JCT {} must beat rigid {} by most of the repair window",
        e.jct,
        r.jct
    );
}

#[test]
fn fleet_sweep_stable_across_jobs_and_topology_wins_the_tail() {
    // One pair of sweep runs carries every grid-level assertion (9 fleet
    // simulations per run — don't run the grid more than twice).
    let (seq, pts) = fleet_sweep_with(true, &Runner::sequential());
    let (par, _) = fleet_sweep_with(true, &Runner::new(4));
    assert_eq!(seq.to_csv(), par.to_csv(), "CSV must not depend on --jobs");

    assert_eq!(pts.len(), 9); // 3 policies x 3 occupancies
    assert!(pts.iter().all(|p| p.images_per_sec > 0.0 && p.p99_jct > 0.0));

    // THE acceptance cell: at 60% occupancy on the 4:1-oversubscribed
    // fat-tree, ToR-packing placement must not lose the JCT tail to
    // packing by node id, which must not lose to spreading — the gangs
    // a policy keeps inside one ToR ride isolated NIC links, while
    // straddlers contend with every neighbor's attributed traffic on
    // the thin uplinks.
    let p99 = |policy: &str| {
        pts.iter()
            .find(|p| p.policy == policy && p.occupancy == 0.6)
            .unwrap()
            .p99_jct
    };
    let (topo, pack, spread) = (p99("topology"), p99("pack"), p99("spread"));
    assert!(topo <= pack + 1e-9, "topology p99 {topo} must not exceed pack {pack}");
    assert!(pack <= spread + 1e-9, "pack p99 {pack} must not exceed spread {spread}");
}
