//! Property tests for the fault-injection subsystem (`fabric::faults` +
//! the degradation-aware engine and collectives):
//!
//! * an inactive `FaultSpec` — even with every parameter knob moved off
//!   its default — is bit-for-bit identical to the default trainer for
//!   **all five** collective algorithms, and the committed `table1`
//!   golden stays byte-exact: `faults = none` is the pre-fault engine;
//! * the acceptance scenario: a spine dying mid-step on the 4:1
//!   fat-tree at 32 GPUs strictly increases exposed communication vs
//!   the healthy paired run while the step still completes over the
//!   surviving ECMP spines — rerouted flows counted, nothing failed;
//! * the same fault seed replays bitwise-identical step times
//!   (fresh-sim determinism);
//! * step time is monotone non-decreasing in brownout severity on the
//!   contended 25 GbE @ 32-GPU cell.

use fabricbench::cluster::EndpointKind;
use fabricbench::collectives::{
    BinomialTree, Collective, Hierarchical, PipelinedRing, RecursiveHalvingDoubling, RingAllreduce,
};
use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{ClusterSpec, FabricKind, RunSpec, TenancySpec, TransportOptions};
use fabricbench::fabric::{FaultEvent, FaultSpec, FaultTarget, FlowReq, NetSim};
use fabricbench::trainer::TrainerSim;
use fabricbench::util::units::MIB;

fn trainer(kind: FabricKind, faults: FaultSpec) -> TrainerSim {
    TrainerSim {
        arch: fabricbench::models::zoo::resnet50(),
        fabric: fabric(kind),
        cluster: ClusterSpec::txgaia(),
        opts: TransportOptions::default(),
        strategy: Box::new(RingAllreduce),
        per_gpu_batch: 64,
        precision: fabricbench::models::perf::Precision::Fp32,
        fusion_bytes: 64.0 * MIB,
        overlap: true,
        step_overhead: 0.0,
        coordination_overhead: fabricbench::trainer::coordinator::DEFAULT_COORDINATION_OVERHEAD,
        tenancy: TenancySpec::default(),
        workload: fabricbench::config::WorkloadSpec::default(),
        faults,
    }
}

fn spec(measure: usize) -> RunSpec {
    RunSpec { warmup_steps: 1, measure_steps: measure, ..Default::default() }
}

fn cpu_ep(node: usize) -> fabricbench::cluster::Endpoint {
    NetSim::endpoint(node, 0, EndpointKind::Cpu)
}

/// A NIC brownout on the ring's busiest nodes, covering the whole run.
fn nic_brownout(factor: f64) -> FaultSpec {
    let mut f = FaultSpec::default();
    for node in [0usize, 1] {
        f.events.push(FaultEvent {
            target: FaultTarget::Nic(node),
            at: 0.0,
            duration: 1e3,
            factor,
        });
    }
    f
}

#[test]
fn inactive_spec_is_bit_identical_for_all_five_collectives() {
    // A fully *configured* fault spec whose only neutral knob is the
    // one that matters: no rate, no events. Everything else — seed,
    // durations, horizon, brownout shape — is deliberately non-default,
    // so this pins "inactive means inactive", not "default means
    // default".
    let neutral = FaultSpec {
        rate: 0.0,
        seed: 0xDEAD_BEEF,
        mean_duration: 7.5,
        horizon: 123.0,
        brownout_frac: 0.9,
        brownout_factor: 0.01,
        events: Vec::new(),
    };
    let strategies: Vec<fn() -> Box<dyn Collective>> = vec![
        || Box::new(RingAllreduce),
        || Box::new(RecursiveHalvingDoubling),
        || Box::new(Hierarchical::default()),
        || Box::new(BinomialTree),
        || Box::new(PipelinedRing { segments: 3 }),
    ];
    for make in strategies {
        let mut base = trainer(FabricKind::EthernetRoce25, FaultSpec::default());
        base.strategy = make();
        let name = base.strategy.name();
        let mut faulty = trainer(FabricKind::EthernetRoce25, neutral.clone());
        faulty.strategy = make();
        let a = base.run(16, &spec(3)).unwrap();
        let b = faulty.run(16, &spec(3)).unwrap();
        assert_eq!(
            a.step_time_mean.to_bits(),
            b.step_time_mean.to_bits(),
            "{name}: inactive fault spec moved the step time"
        );
        assert_eq!(a.images_per_sec.to_bits(), b.images_per_sec.to_bits(), "{name}");
        assert_eq!(a.comm_fraction.to_bits(), b.comm_fraction.to_bits(), "{name}");
        assert_eq!(a.step_time_p95.to_bits(), b.step_time_p95.to_bits(), "{name}");
        assert_eq!(b.fault_exposure, 0.0, "{name}: inactive spec must report zero exposure");
    }
}

#[test]
fn table1_golden_untouched_by_fault_module() {
    // The cheap committed golden: the fault subsystem must not move a
    // byte of the default-config drivers. (fig3 is covered by
    // tests/golden_outputs.rs — no need to run the CFD sweep twice.)
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("table1.csv");
    let want = std::fs::read_to_string(&path).expect("committed golden tests/golden/table1.csv");
    assert_eq!(
        want,
        fabricbench::experiments::table1::run().to_csv(),
        "default config must stay bit-for-bit pre-fault"
    );
}

#[test]
fn mid_step_spine_down_reroutes_completes_and_slows() {
    // The acceptance scenario, engine level: 24 cross-rack flows on a
    // 4-spine 4:1 fat-tree, spine 0 dying a quarter of the way through
    // the healthy batch and staying down past its end. Every flow that
    // hashed onto spine 0 must re-route over the three survivors (so
    // the batch completes with zero failures) and the lost bisection
    // capacity must strictly stretch the batch.
    let mk = || {
        let mut f = fabric(FabricKind::EthernetRoce25);
        f.topology.spines = 4;
        f.topology.oversubscription = Some(4.0);
        NetSim::new(f, ClusterSpec::txgaia(), TransportOptions::default())
    };
    let reqs: Vec<FlowReq> = (0..24)
        .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(40 + i), bytes: 8.0 * MIB, ready: 0.0 })
        .collect();
    let mut healthy = mk();
    let h = healthy
        .transfer_batch(&reqs)
        .iter()
        .map(|t| t.recv_complete)
        .fold(0.0, f64::max);
    assert!(h > 0.0);
    assert_eq!(healthy.stats.reroutes + healthy.stats.failed_flows, 0);

    let mut faulted = mk();
    faulted.set_faults(&FaultSpec::spine_down(0, h * 0.25, h * 4.0)).unwrap();
    let f = faulted
        .transfer_batch(&reqs)
        .iter()
        .map(|t| t.recv_complete)
        .fold(0.0, f64::max);
    assert_eq!(faulted.stats.failed_flows, 0, "ECMP survivors must absorb every flow");
    assert!(faulted.stats.reroutes > 0, "flows crossing the dead spine must re-route");
    assert!(
        f > h * (1.0 + 1e-9),
        "losing a quarter of the bisection must stretch the batch: {f} !> {h}"
    );
}

#[test]
fn mid_step_spine_down_increases_exposed_comm_at_trainer_level() {
    // The same scenario through the trainer: 32 GPUs spanning four
    // small racks of the 4-spine 4:1 fat-tree, hierarchical allreduce.
    // The paired healthy run fixes the step length; the faulted run
    // sees spine 0 die a quarter of the way into its (single) measured
    // step and reports both a longer step and a nonzero fault exposure.
    let mk = |faults: FaultSpec| {
        let mut t = trainer(FabricKind::EthernetRoce25, faults);
        t.fabric.topology.spines = 4;
        t.fabric.topology.oversubscription = Some(4.0);
        t.cluster.nodes_per_rack = 4;
        t.strategy = Box::new(Hierarchical::default());
        t
    };
    let run = RunSpec { warmup_steps: 0, measure_steps: 1, ..Default::default() };
    let healthy = mk(FaultSpec::default()).run(32, &run).unwrap();
    assert_eq!(healthy.fault_exposure, 0.0);
    let s = healthy.step_time_mean;
    let faulted =
        mk(FaultSpec::spine_down(0, s * 0.25, s * 1e3)).run(32, &run).unwrap();
    assert!(
        faulted.step_time_mean > s * (1.0 + 1e-9),
        "spine-down must stretch the step: {} !> {s}",
        faulted.step_time_mean
    );
    assert!(
        faulted.fault_exposure > 0.0,
        "the trainer must surface the degraded window as exposure"
    );
    assert!(faulted.fault_exposure <= 1.0);
}

#[test]
fn same_fault_seed_replays_bitwise() {
    // Fresh-sim determinism: two independently constructed trainers
    // with the same random fault trace agree to the bit, and a
    // different fault seed genuinely moves the trace.
    let spec3 = spec(3);
    let mk = |fseed: u64| {
        trainer(FabricKind::EthernetRoce25, FaultSpec::random(20.0, fseed))
            .run(32, &spec3)
            .unwrap()
    };
    let a = mk(0xFA_017);
    let b = mk(0xFA_017);
    assert_eq!(a.step_time_mean.to_bits(), b.step_time_mean.to_bits());
    assert_eq!(a.step_time_p95.to_bits(), b.step_time_p95.to_bits());
    assert_eq!(a.comm_fraction.to_bits(), b.comm_fraction.to_bits());
    assert_eq!(a.fault_exposure.to_bits(), b.fault_exposure.to_bits());
}

#[test]
fn brownout_severity_is_monotone_on_contended_cell() {
    // Paired seeds: identical compute jitter, the NIC capacity factor is
    // the only variable. Keeping less of the NIC can never make the
    // 25 GbE @ 32-GPU ring faster.
    let healthy = trainer(FabricKind::EthernetRoce25, FaultSpec::default())
        .run(32, &spec(3))
        .unwrap();
    let mut last = healthy.step_time_mean;
    for factor in [0.8, 0.4, 0.1] {
        let r = trainer(FabricKind::EthernetRoce25, nic_brownout(factor))
            .run(32, &spec(3))
            .unwrap();
        assert!(
            r.step_time_mean >= last * (1.0 - 1e-9),
            "brownout factor {factor} sped the step up: {} < {last}",
            r.step_time_mean
        );
        assert!(r.fault_exposure > 0.99, "window covers the whole run, factor {factor}");
        last = r.step_time_mean;
    }
}
