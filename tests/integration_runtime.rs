//! Integration tests for the three-layer stack: AOT artifacts -> PJRT
//! runtime -> real training. These run only when `make artifacts` has
//! produced the artifacts directory (they are the repo's core end-to-end
//! signal, also exercised by examples/e2e_training.rs).

use fabricbench::config::presets::fabric;
use fabricbench::config::spec::FabricKind;
use fabricbench::runtime::engine::{Engine, Input};
use fabricbench::runtime::Manifest;
use fabricbench::trainer::data::SyntheticDataset;
use fabricbench::trainer::real::RealTrainer;

fn engine() -> Option<Engine> {
    fabricbench::runtime::artifacts_dir().map(|d| Engine::load(&d).unwrap())
}

#[test]
fn manifest_and_params_agree() {
    let Some(dir) = fabricbench::runtime::artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let params = m.load_init_params(&dir).unwrap();
    assert_eq!(params.len(), m.params.len());
    for (p, spec) in params.iter().zip(&m.params) {
        assert_eq!(p.len(), spec.elems());
        assert!(p.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn predict_artifact_runs_and_shapes_match() {
    let Some(engine) = engine() else { return };
    let predict = engine.compile("predict").unwrap();
    let m = &engine.manifest;
    let params = m.load_init_params(&engine.dir).unwrap();
    let dataset = SyntheticDataset::new(5, 0.25);
    let (x, _) = dataset.batch(0, 0, 1, m.batch);
    let img_shape = [m.batch, m.image[0], m.image[1], m.image[2]];
    let mut inputs: Vec<Input> = params
        .iter()
        .zip(&m.params)
        .map(|(p, s)| Input::F32(p, &s.shape))
        .collect();
    inputs.push(Input::F32(&x, &img_shape));
    let out = predict.run(&inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), m.batch * m.classes);
    assert!(out[0].iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_gradients_match_param_shapes() {
    let Some(engine) = engine() else { return };
    let ts = engine.compile("train_step").unwrap();
    let m = &engine.manifest;
    let params = m.load_init_params(&engine.dir).unwrap();
    let dataset = SyntheticDataset::new(6, 0.25);
    let (x, y) = dataset.batch(0, 0, 1, m.batch);
    let img_shape = [m.batch, m.image[0], m.image[1], m.image[2]];
    let label_shape = [m.batch];
    let mut inputs: Vec<Input> = params
        .iter()
        .zip(&m.params)
        .map(|(p, s)| Input::F32(p, &s.shape))
        .collect();
    inputs.push(Input::F32(&x, &img_shape));
    inputs.push(Input::I32(&y, &label_shape));
    let out = ts.run(&inputs).unwrap();
    assert_eq!(out.len(), 1 + m.params.len());
    assert!(out[0][0] > 0.0, "initial loss must be positive");
    for (g, spec) in out[1..].iter().zip(&m.params) {
        assert_eq!(g.len(), spec.elems());
    }
}

#[test]
fn data_parallel_equals_single_worker_big_batch_direction() {
    // With equal data, 2-worker averaged gradients == the mean of the two
    // per-worker gradients; training with them must reduce loss.
    let Some(engine) = engine() else { return };
    let mut t = RealTrainer::new(engine).unwrap();
    let report = t.train(2, 8, 0.1, &fabric(FabricKind::OmniPath100), None).unwrap();
    assert!(report.losses.last().unwrap() < &report.losses[0]);
}

#[test]
fn longer_training_reaches_high_accuracy() {
    // The cornerstone E2E assertion (kept moderate for CI time).
    let Some(engine) = engine() else { return };
    let mut t = RealTrainer::new(engine).unwrap();
    let report = t.train(4, 60, 0.1, &fabric(FabricKind::EthernetRoce25), None).unwrap();
    assert!(
        report.final_accuracy > 0.6,
        "accuracy after 60 steps: {}",
        report.final_accuracy
    );
    assert!(report.virtual_comm_time > 0.0);
}
