//! Failure-injection integration tests: the system must fail loudly and
//! informatively, never silently.

use fabricbench::cluster::Placement;
use fabricbench::config::spec::{ClusterSpec, FabricSpec, FabricKind};
use fabricbench::config::toml;
use fabricbench::runtime::Manifest;

#[test]
fn oversubscribed_placement_rejected() {
    let c = ClusterSpec::txgaia();
    let too_many = c.nodes * c.gpus_per_node + 1;
    let err = Placement::gpus(&c, too_many).unwrap_err();
    assert!(err.to_string().contains("nodes"), "unhelpful error: {err}");
}

#[test]
fn corrupt_manifest_rejected() {
    for bad in [
        "{",                         // truncated
        "[]",                        // wrong top-level type
        r#"{"model": "m"}"#,         // missing fields
    ] {
        assert!(Manifest::parse(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn missing_artifacts_dir_is_clean_error() {
    // Load the bogus path directly — no process-env mutation. The old
    // set_var/remove_var dance raced with every other env-reading test
    // in this parallel harness, and `Manifest::load` never consulted the
    // variable anyway.
    let err = Manifest::load(std::path::Path::new("/nonexistent/nowhere")).unwrap_err();
    assert!(format!("{err:#}").contains("manifest"));
}

#[test]
fn invalid_fabric_toml_rejected() {
    for doc in [
        "kind = \"warp\"",
        "kind = \"opa-100\"\nlatency_us = -1.0",
        "kind = \"opa-100\"\nefficiency = 2.0",
        "kind = \"opa-100\"\nbandwidth_gbps = 0.0",
    ] {
        let v = toml::parse(doc).unwrap();
        assert!(FabricSpec::from_toml(&v).is_err(), "accepted: {doc}");
    }
}

#[test]
fn zero_sized_cluster_rejected() {
    let v = toml::parse("nodes = 0").unwrap();
    assert!(ClusterSpec::from_toml(&v).is_err());
}

#[test]
fn fabric_kind_parse_errors_are_informative() {
    let err = FabricKind::parse("token-ring").unwrap_err();
    assert!(err.to_string().contains("token-ring"));
}

#[test]
fn init_params_wrong_size_rejected() {
    let m = Manifest::parse(
        r#"{
      "model": "m", "batch": 2, "image": [2, 2, 1], "classes": 2,
      "param_count": 4,
      "params": [{"name": "w", "shape": [4]}],
      "artifacts": {
        "train_step": {"file": "t", "inputs": ["w", "x", "y"], "outputs": ["loss", "gw"]},
        "sgd_update": {"file": "s", "inputs": ["w", "gw", "lr"], "outputs": ["w"]},
        "predict": {"file": "p", "inputs": ["w", "x"], "outputs": ["logits"]}
      }
    }"#,
    )
    .unwrap();
    let dir = std::env::temp_dir().join("fb_it_badbin");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("init_params.bin"), [0u8; 8]).unwrap(); // 8 != 16
    assert!(m.load_init_params(&dir).is_err());
}
