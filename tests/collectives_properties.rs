//! Property-based correctness suite for the collective library.
//!
//! The oracle is **bit-for-bit**: buffers are filled with integer-valued
//! f32 (via `util::prop::vec_f32_int`), whose sums over <= 17 ranks stay
//! exactly representable, so every reduction order must produce the
//! identical bit pattern as the naive rank-order sum. No tolerance means
//! a chunk-bookkeeping bug of even one element cannot hide behind float
//! reassociation.
//!
//! Grid (per the issue): ranks in 2..=17, elems in {1, 7, 1024, 100_003},
//! algorithm in {ring, tree, recursive halving-doubling, hierarchical,
//! pipelined ring} — one test per algorithm so the grid shards across
//! the test harness's threads — plus a randomized `prop::forall` sweep
//! over all five.

use fabricbench::cluster::Placement;
use fabricbench::collectives::{
    BinomialTree, Collective, Hierarchical, PipelinedRing, RealBuffers,
    RecursiveHalvingDoubling, RingAllreduce,
};
use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{ClusterSpec, FabricKind, TransportOptions};
use fabricbench::fabric::{Comm, NetSim};
use fabricbench::util::prop;
use fabricbench::util::rng::Rng;

const RANKS: std::ops::RangeInclusive<usize> = 2..=17;
const ELEMS: [usize; 4] = [1, 7, 1024, 100_003];

fn int_buffers(ranks: usize, elems: usize, seed: u64) -> RealBuffers {
    let mut rng = Rng::new(seed);
    RealBuffers::new((0..ranks).map(|_| prop::vec_f32_int(&mut rng, elems, 8)).collect())
}

fn naive_sum(bufs: &RealBuffers) -> Vec<f32> {
    let n = bufs.data[0].len();
    let mut out = vec![0.0f32; n];
    for b in &bufs.data {
        for (o, x) in out.iter_mut().zip(b) {
            *o += *x;
        }
    }
    out
}

/// Run `algo` over a GPU world on `cluster` + `fab` and demand exact
/// equality with the naive sum on every rank.
fn check_exact_with(
    cluster: ClusterSpec,
    fab: fabricbench::config::FabricSpec,
    algo: &dyn Collective,
    ranks: usize,
    elems: usize,
    seed: u64,
) -> Result<(), String> {
    let placement = Placement::gpus(&cluster, ranks).unwrap();
    let mut net = NetSim::new(fab, cluster, TransportOptions::default());
    let mut bufs = int_buffers(ranks, elems, seed);
    let expect = naive_sum(&bufs);
    let mut comm = Comm::new(&mut net, &placement);
    let t = algo.allreduce(&mut comm, &mut bufs);
    if ranks > 1 && !(t > 0.0) {
        return Err(format!("{}: no virtual time elapsed (p={ranks})", algo.name()));
    }
    for (r, buf) in bufs.data.iter().enumerate() {
        for (i, (&got, &want)) in buf.iter().zip(&expect).enumerate() {
            if got.to_bits() != want.to_bits() {
                return Err(format!(
                    "{}: rank {r} elem {i}: {got} != {want} bit-for-bit (p={ranks}, n={elems}, seed={seed:#x})",
                    algo.name()
                ));
            }
        }
    }
    Ok(())
}

/// The original single-rack oracle (all grid ranks fit inside one
/// TX-GAIA rack).
fn check_exact(algo: &dyn Collective, ranks: usize, elems: usize, seed: u64) -> Result<(), String> {
    check_exact_with(
        ClusterSpec::txgaia(),
        fabric(FabricKind::OmniPath100),
        algo,
        ranks,
        elems,
        seed,
    )
}

/// TX-GAIA shrunk to 2-node racks: 4 GPUs per ToR, so the grid's rank
/// counts span 2..=5 ToRs and hierarchical leader election goes
/// genuinely multi-tier (per-ToR rings + inter-ToR leader ring).
fn small_rack_cluster() -> ClusterSpec {
    let mut cluster = ClusterSpec::txgaia();
    cluster.nodes_per_rack = 2;
    cluster
}

fn grid(algo: &dyn Collective) {
    for ranks in RANKS {
        for &elems in &ELEMS {
            let seed = 0xB17F_0B17 ^ ((ranks as u64) << 32) ^ elems as u64;
            if let Err(msg) = check_exact(algo, ranks, elems, seed) {
                panic!("{msg}");
            }
        }
    }
}

#[test]
fn ring_bit_for_bit_grid() {
    grid(&RingAllreduce);
}

#[test]
fn tree_bit_for_bit_grid() {
    grid(&BinomialTree);
}

#[test]
fn recursive_halving_doubling_bit_for_bit_grid() {
    grid(&RecursiveHalvingDoubling);
}

#[test]
fn hierarchical_bit_for_bit_grid() {
    grid(&Hierarchical::default());
}

#[test]
fn pipelined_ring_bit_for_bit_grid() {
    // Cover several segment counts including degenerate (1 = plain ring)
    // and more segments than elements.
    for segments in [1usize, 3, 4, 9] {
        let algo = PipelinedRing { segments };
        for ranks in RANKS {
            for &elems in &[1usize, 7, 1024] {
                let seed =
                    0x5E6_0000 ^ ((segments as u64) << 40) ^ ((ranks as u64) << 20) ^ elems as u64;
                if let Err(msg) = check_exact(&algo, ranks, elems, seed) {
                    panic!("{msg}");
                }
            }
        }
        // One large-buffer point per segment count keeps runtime sane.
        if let Err(msg) = check_exact(&algo, 17, 100_003, 0x5E6_1111 ^ segments as u64) {
            panic!("{msg}");
        }
    }
}

#[test]
fn multi_tor_placements_bit_for_bit_grid() {
    // Satellite of the topology issue: the exact to_bits oracle must also
    // hold when ranks span 2..=5 ToRs, i.e. under topology-aware
    // hierarchical leader election (per-ToR rings, inter-ToR leader
    // ring, fan-out). Every algorithm runs the multi-ToR grid; the
    // hierarchical one is the interesting case.
    let algos: Vec<Box<dyn Collective>> = vec![
        Box::new(RingAllreduce),
        Box::new(BinomialTree),
        Box::new(RecursiveHalvingDoubling),
        Box::new(Hierarchical::default()),
        Box::new(PipelinedRing { segments: 3 }),
    ];
    for algo in &algos {
        for ranks in [5usize, 8, 9, 12, 16, 17] {
            for &elems in &[1usize, 7, 1024] {
                let seed = 0x707_70C5 ^ ((ranks as u64) << 24) ^ elems as u64;
                if let Err(msg) = check_exact_with(
                    small_rack_cluster(),
                    fabric(FabricKind::OmniPath100),
                    algo.as_ref(),
                    ranks,
                    elems,
                    seed,
                ) {
                    panic!("multi-ToR: {msg}");
                }
            }
        }
    }
    // One large-buffer point for the hierarchy (keeps runtime sane).
    if let Err(msg) = check_exact_with(
        small_rack_cluster(),
        fabric(FabricKind::OmniPath100),
        &Hierarchical::default(),
        17,
        100_003,
        0x707_1111,
    ) {
        panic!("multi-ToR: {msg}");
    }
}

#[test]
fn multi_tor_oracle_independent_of_oversubscription() {
    // The taper moves *time*, never values: the same multi-ToR oracle
    // under an 8:1 fat-tree with 2 spines must still be exact.
    let mut fab = fabric(FabricKind::OmniPath100);
    fab.topology.spines = 2;
    fab.topology.oversubscription = Some(8.0);
    for ranks in [8usize, 13, 17] {
        if let Err(msg) = check_exact_with(
            small_rack_cluster(),
            fab.clone(),
            &Hierarchical::default(),
            ranks,
            513,
            0x5EED ^ ranks as u64,
        ) {
            panic!("oversubscribed multi-ToR: {msg}");
        }
    }
}

#[test]
fn randomized_cross_algorithm_property() {
    // Random (algorithm, ranks, elems, seed) tuples on top of the
    // exhaustive grid — catches interactions the grid's fixed seeds miss.
    let algos: Vec<Box<dyn Collective>> = vec![
        Box::new(RingAllreduce),
        Box::new(BinomialTree),
        Box::new(RecursiveHalvingDoubling),
        Box::new(Hierarchical::default()),
        Box::new(PipelinedRing { segments: 4 }),
    ];
    prop::forall(
        0xA11_4ED0CE,
        48,
        |r| {
            (
                r.below(algos.len() as u64) as usize,
                2 + r.below(16) as usize,
                1 + r.below(2048) as usize,
                r.next_u64(),
            )
        },
        |&(ai, ranks, elems, seed)| check_exact(algos[ai].as_ref(), ranks, elems, seed),
    );
}
