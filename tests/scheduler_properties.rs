//! Property tests for the multi-stream overlap scheduler
//! (`trainer::scheduler`):
//!
//! * `num_streams = 1` reproduces the pre-scheduler serialized trainer
//!   timeline **bit for bit** (the reference loop below is a verbatim
//!   copy of the old coordinator inner loop);
//! * step time is monotonically non-increasing in `num_streams`;
//! * stream counts beyond the bucket count change nothing (round-robin
//!   assignment leaves the extra streams empty);
//! * the `ablations::streams` sweep CSV is byte-identical for any
//!   `--jobs` at a fixed seed.

use fabricbench::cluster::{Placement, V100};
use fabricbench::collectives::{fuse, Collective, NullBuffers, RingAllreduce, BYTES_PER_ELEM};
use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{ClusterSpec, FabricKind, RunSpec, TransportOptions};
use fabricbench::experiments::ablations;
use fabricbench::experiments::sweeps::Runner;
use fabricbench::fabric::{Comm, NetSim};
use fabricbench::models::perf::{step_cost, Precision};
use fabricbench::trainer::TrainerSim;
use fabricbench::util::rng::Rng;
use fabricbench::util::stats;
use fabricbench::util::units::MIB;

fn trainer(kind: FabricKind, num_streams: usize, fusion_bytes: f64) -> TrainerSim {
    TrainerSim {
        arch: fabricbench::models::zoo::resnet50(),
        fabric: fabric(kind),
        cluster: ClusterSpec::txgaia(),
        opts: TransportOptions { num_streams, ..Default::default() },
        strategy: Box::new(RingAllreduce),
        per_gpu_batch: 64,
        precision: Precision::Fp32,
        fusion_bytes,
        overlap: true,
        step_overhead: 0.0,
        coordination_overhead: fabricbench::trainer::coordinator::DEFAULT_COORDINATION_OVERHEAD,
        tenancy: fabricbench::config::TenancySpec::default(),
        workload: fabricbench::config::WorkloadSpec::default(),
        faults: fabricbench::fabric::FaultSpec::default(),
    }
}

fn spec() -> RunSpec {
    RunSpec { warmup_steps: 1, measure_steps: 4, ..Default::default() }
}

/// Verbatim re-implementation of the pre-scheduler serialized trainer
/// step loop (coordinator::simulate_step before PR 2), kept here as the
/// independent oracle for the `num_streams = 1` bit-compat guarantee.
/// Returns (step_time_mean, step_time_p95).
fn reference_serialized(t: &TrainerSim, gpus: usize, run: &RunSpec) -> (f64, f64) {
    let placement = Placement::gpus(&t.cluster, gpus).unwrap();
    let mut net = NetSim::new(t.fabric.clone(), t.cluster.clone(), t.opts);
    let mut rng = Rng::new(run.seed ^ (gpus as u64) << 32 ^ t.arch.total_params());
    let cost = step_cost(&t.arch, &V100, t.per_gpu_batch, t.precision, None);
    let buckets = fuse(&t.arch.gradient_tensor_bytes(), t.fusion_bytes);

    let mut step_times = Vec::new();
    for step in 0..run.warmup_steps + run.measure_steps {
        net.reset();
        let jitter: Vec<f64> = (0..gpus).map(|_| rng.lognormal_median(1.0, 0.02)).collect();
        let fwd: Vec<f64> = jitter.iter().map(|j| cost.fwd * j).collect();
        let bwd: Vec<f64> = jitter.iter().map(|j| cost.bwd * j).collect();
        let compute_done: Vec<f64> = fwd.iter().zip(&bwd).map(|(f, b)| f + b).collect();

        let mut prev_done: Vec<f64> = vec![0.0; gpus];
        let mut comm_done: Vec<f64> = vec![0.0; gpus];
        for bucket in &buckets {
            let start: Vec<f64> = (0..gpus)
                .map(|r| {
                    let ready = if t.overlap {
                        fwd[r] + bwd[r] * bucket.ready_frac
                    } else {
                        compute_done[r]
                    };
                    ready.max(prev_done[r]) + t.coordination_overhead
                })
                .collect();
            let elems = (bucket.bytes / BYTES_PER_ELEM).ceil() as usize;
            let mut comm = Comm::with_start(&mut net, &placement, &start);
            let mut bufs = NullBuffers { elems };
            t.strategy.allreduce(&mut comm, &mut bufs);
            comm_done.copy_from_slice(&comm.t);
            prev_done.copy_from_slice(&comm.t);
        }
        let end = (0..gpus)
            .map(|r| comm_done[r].max(compute_done[r]) + cost.optimizer)
            .fold(0.0, f64::max)
            + t.step_overhead;
        if step >= run.warmup_steps {
            step_times.push(end);
        }
    }
    (stats::mean(&step_times), stats::percentile(&step_times, 95.0))
}

#[test]
fn streams1_bit_identical_to_serialized_reference() {
    for kind in [FabricKind::EthernetRoce25, FabricKind::OmniPath100] {
        let t = trainer(kind, 1, 64.0 * MIB);
        let run = spec();
        let got = t.run(32, &run).unwrap();
        let (want_mean, want_p95) = reference_serialized(&t, 32, &run);
        assert_eq!(
            got.step_time_mean.to_bits(),
            want_mean.to_bits(),
            "{kind:?}: streams=1 mean {} != serialized reference {}",
            got.step_time_mean,
            want_mean
        );
        assert_eq!(got.step_time_p95.to_bits(), want_p95.to_bits(), "{kind:?}: p95 drifted");
    }
}

#[test]
fn step_time_monotone_non_increasing_in_streams() {
    // Fixed seed, identical jitter: adding streams may only remove
    // head-of-line blocking, never add work. At 64 MiB fusion the
    // acceptance cell also holds: 2 streams *strictly* beat the
    // serialized coordinator on Ethernet (asserted here on the same runs
    // instead of re-simulating in a separate test).
    for fusion_mib in [64.0, 16.0] {
        let run = spec();
        let mut step_times = Vec::new();
        for streams in [1usize, 2, 4, 8] {
            let t = trainer(FabricKind::EthernetRoce25, streams, fusion_mib * MIB);
            let r = t.run(32, &run).unwrap();
            if let Some(&p) = step_times.last() {
                assert!(
                    r.step_time_mean <= p + 1e-9,
                    "fusion {fusion_mib} MiB: streams={streams} step {} > previous {}",
                    r.step_time_mean,
                    p
                );
            }
            step_times.push(r.step_time_mean);
        }
        if fusion_mib == 64.0 {
            assert!(
                step_times[1] < step_times[0],
                "2 streams {} !< serialized {} (acceptance cell)",
                step_times[1],
                step_times[0]
            );
        }
    }
}

#[test]
fn extra_streams_beyond_buckets_change_nothing() {
    // 64 MiB fusion on ResNet-50 yields 2 buckets: stream counts past 2
    // leave the extra channels empty and must be bit-identical.
    let run = spec();
    let two = trainer(FabricKind::EthernetRoce25, 2, 64.0 * MIB).run(32, &run).unwrap();
    for streams in [4usize, 8] {
        let more = trainer(FabricKind::EthernetRoce25, streams, 64.0 * MIB)
            .run(32, &run)
            .unwrap();
        assert_eq!(
            more.step_time_mean.to_bits(),
            two.step_time_mean.to_bits(),
            "streams={streams} diverged from streams=2"
        );
        assert_eq!(more.comm_fraction.to_bits(), two.comm_fraction.to_bits());
    }
}

#[test]
fn streams_csv_identical_for_any_jobs() {
    let (seq, _) = ablations::streams_sweep_with(true, &Runner::sequential());
    let par = {
        let runner = Runner::new(4);
        let (t, _) = ablations::streams_sweep_with(true, &runner);
        t
    };
    assert_eq!(seq.to_csv(), par.to_csv(), "streams sweep CSV must not depend on --jobs");
}

#[test]
fn schedule_cache_on_off_bit_identical_trainer_and_sweep() {
    // The memoization tiers are exact-keyed: enabling them can change
    // wall-clock only, never an output bit — across stream counts and
    // across --jobs.
    let run = spec();
    for streams in [1usize, 4] {
        let on = trainer(FabricKind::EthernetRoce25, streams, 64.0 * MIB).run(32, &run).unwrap();
        let mut t = trainer(FabricKind::EthernetRoce25, streams, 64.0 * MIB);
        t.opts.schedule_cache = false;
        let off = t.run(32, &run).unwrap();
        assert_eq!(
            on.step_time_mean.to_bits(),
            off.step_time_mean.to_bits(),
            "streams={streams}: schedule cache changed the step time"
        );
        assert_eq!(on.comm_fraction.to_bits(), off.comm_fraction.to_bits());
        assert_eq!(on.images_per_sec.to_bits(), off.images_per_sec.to_bits());
    }
    // Sweep CSV: cache on (default), parallel — still byte-stable (the
    // cache is per-simulator, so worker interleaving cannot leak state).
    let (seq, _) = ablations::streams_sweep_with(true, &Runner::sequential());
    let (par, _) = ablations::streams_sweep_with(true, &Runner::new(3));
    assert_eq!(seq.to_csv(), par.to_csv());
}

#[test]
fn chunk_pipelining_runs_and_stays_sane() {
    // Chunks of a bucket are one logical launch (no extra coordination
    // cycles), so chunking costs at most the extra per-round latency
    // terms — well under 10 ms here.
    let run = spec();
    let plain = trainer(FabricKind::EthernetRoce25, 2, 64.0 * MIB).run(32, &run).unwrap();
    let mut t = trainer(FabricKind::EthernetRoce25, 2, 64.0 * MIB);
    t.opts.chunk_bytes = Some(16.0 * MIB);
    let chunked = t.run(32, &run).unwrap();
    assert!(chunked.step_time_mean > 0.0);
    assert!(
        chunked.step_time_mean < plain.step_time_mean + 0.01,
        "chunking must not add more than latency terms: {} vs {}",
        chunked.step_time_mean,
        plain.step_time_mean
    );
}
