//! Integration tests for the discrete-event fabric engine: closed-form
//! parity for uncontended flows, monotonicity of contended collectives,
//! and scheduling-independence of batch results.

use fabricbench::cluster::{EndpointKind, Placement};
use fabricbench::collectives::{Collective, NullBuffers, RingAllreduce};
use fabricbench::config::presets::fabric;
use fabricbench::config::spec::{ClusterSpec, FabricKind, TransportOptions};
use fabricbench::fabric::transport::{self, MessageGeometry};
use fabricbench::fabric::{Comm, FlowReq, NetSim};

fn sim(kind: FabricKind) -> NetSim {
    NetSim::new(fabric(kind), ClusterSpec::txgaia(), TransportOptions::default())
}

fn cpu_ep(node: usize) -> fabricbench::cluster::Endpoint {
    NetSim::endpoint(node, 0, EndpointKind::Cpu)
}

#[test]
fn uncontended_flow_matches_closed_form_within_1e9s() {
    // The parity bound from the issue: |event engine - analytic| < 1e-9 s
    // for a single flow, across fabrics, endpoint kinds and sizes
    // straddling the eager/rendezvous threshold and the inter-rack hop.
    for kind in [
        FabricKind::EthernetRoce25,
        FabricKind::EthernetTcp25,
        FabricKind::OmniPath100,
        FabricKind::InfinibandEdr100,
    ] {
        for endpoint in [EndpointKind::Cpu, EndpointKind::Gpu] {
            for inter_rack in [false, true] {
                for bytes in [0.0, 8.0, 1024.0, 65536.0, 1e6, 128.0 * 1024.0 * 1024.0] {
                    let mut s = sim(kind);
                    let dst_node = if inter_rack { 40 } else { 1 };
                    let src = NetSim::endpoint(0, 0, endpoint);
                    let dst = NetSim::endpoint(dst_node, 0, endpoint);
                    let (_, t) = s.message(src, dst, bytes, 0.0);
                    let geo = MessageGeometry {
                        bytes,
                        inter_rack,
                        endpoint,
                        src_slot: 0,
                        dst_slot: 0,
                    };
                    let cost =
                        transport::network_message(&s.fabric, &s.cluster, &s.opts, &geo);
                    let model = cost.total(bytes);
                    assert!(
                        (t - model).abs() < 1e-9,
                        "{kind:?}/{endpoint:?}/inter_rack={inter_rack}/{bytes}B: engine {t} vs closed form {model}"
                    );
                }
            }
        }
    }
}

#[test]
fn contended_ring_allreduce_monotone_in_message_size() {
    // Contention-accurate timings must still be monotone: a bigger buffer
    // can never finish earlier. 32 GPUs on Ethernet makes every round a
    // genuinely concurrent batch over shared rack infrastructure.
    for kind in [FabricKind::EthernetRoce25, FabricKind::OmniPath100] {
        let cluster = ClusterSpec::txgaia();
        let placement = Placement::gpus(&cluster, 32).unwrap();
        let mut last = 0.0;
        for elems in [1usize, 64, 4096, 65_536, 1 << 20, 1 << 22] {
            let mut net = NetSim::new(fabric(kind), cluster.clone(), TransportOptions::default());
            let mut comm = Comm::new(&mut net, &placement);
            let t = RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems });
            assert!(
                t + 1e-12 >= last,
                "{kind:?}: ring allreduce not monotone: {elems} elems -> {t} s (prev {last} s)"
            );
            last = t;
        }
    }
}

#[test]
fn batch_results_independent_of_request_order() {
    // Reversing the submission order of a concurrent round must not
    // change any flow's completion time (virtual time has no scheduling
    // bias): the engine is event-driven, not submission-driven.
    let bytes = 8.0 * 1024.0 * 1024.0;
    let reqs: Vec<FlowReq> = (0..12)
        .map(|i| FlowReq {
            // Three flows share each of four source nodes -> contended.
            src: cpu_ep(i % 4),
            dst: cpu_ep(8 + i),
            bytes: bytes * (1.0 + i as f64 / 12.0),
            ready: 1e-5 * i as f64,
        })
        .collect();
    let mut s = sim(FabricKind::EthernetRoce25);
    let fwd = s.transfer_batch(&reqs);
    let mut s2 = sim(FabricKind::EthernetRoce25);
    let rev_reqs: Vec<FlowReq> = reqs.iter().rev().copied().collect();
    let rev = s2.transfer_batch(&rev_reqs);
    for (i, ft) in fwd.iter().enumerate() {
        let rt = rev[reqs.len() - 1 - i];
        assert!(
            (ft.recv_complete - rt.recv_complete).abs() < 1e-9,
            "flow {i}: order-dependent completion {} vs {}",
            ft.recv_complete,
            rt.recv_complete
        );
    }
    assert_eq!(s.stats.peak_concurrent_flows, 12);
}

#[test]
fn work_conservation_through_a_shared_port() {
    // However many flows share one tx port, the port drains total bytes
    // at its capacity: the last completion must sit at (+overheads) the
    // aggregate serialization time, never earlier.
    let mut s = sim(FabricKind::OmniPath100);
    let bytes = 4.0 * 1024.0 * 1024.0;
    let n = 6;
    let reqs: Vec<FlowReq> = (0..n)
        .map(|i| FlowReq { src: cpu_ep(0), dst: cpu_ep(1 + i), bytes, ready: 0.0 })
        .collect();
    let times = s.transfer_batch(&reqs);
    let last = times.iter().map(|t| t.recv_complete).fold(0.0, f64::max);
    let drain = n as f64 * bytes / s.fabric.effective_bandwidth();
    assert!(last >= drain, "last completion {last} beats aggregate drain {drain}");
    assert!(last < drain * 1.1, "sharing overhead implausibly high: {last} vs {drain}");
}
