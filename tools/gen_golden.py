#!/usr/bin/env python3
"""Bootstrap generator for the golden CSV fixtures.

Faithful Python mirror of the two *deterministic* Rust experiment drivers
whose CSVs are pinned by ``tests/golden_outputs.rs``:

* ``table1::run()``      -> ``tests/golden/table1.csv``
* ``fig3::run(true)``    -> ``tests/golden/fig3_quick.csv``

The Rust code is the source of truth. This script exists because the
fixtures must live in-tree (CI forbids first-run self-seeding via
``FABRICBENCH_REQUIRE_GOLDEN=1``) and the original bootstrap environment
had no Rust toolchain to run ``FABRICBENCH_REGEN_GOLDEN=1 cargo test``.
Every formula below mirrors its Rust counterpart (referenced in comments);
both drivers are RNG-free and the fixtures quantize to <= 4 significant
digits, so an IEEE-754-faithful port reproduces the same bytes. After any
intentional model change, regenerate with the cargo path and commit the
diff; keep this mirror in sync or delete it once a toolchain is ambient.

Note (PR 4): the Rust engine's contended hot path moved to an incremental
solver (dirty bottleneck groups + completion heap + scratch-arena max-min
filling). This mirror intentionally keeps the simpler monolithic reference
loop: the incremental engine was validated byte-identical on both fixtures
by porting it into a copy of this mirror and diffing the CSVs (where the
old loop stays exact the two differ only by sub-1e-9 re-association noise,
absorbed by the 4-digit quantization), so it remains a faithful generator.

Note (PR 8): the reference loop below now carries the same stall fix as
the engine's reference oracle (projection retirement — a flow whose
projected finish selected t_next retires even when the f64 byte
subtraction leaves a sub-epsilon residue; previously `t + dt == t` spins
burned the whole event budget and silently froze rates) plus the engine's
larger event-budget formula and a budget_exceeded counter. Both changes
are byte-neutral for the two golden drivers (neither ever stalled or
tripped the budget; confirmed by regenerating and diffing the CSVs).
verify_aggregation() additionally pins the PR 8 flow-aggregation claim in
this mirror: the integer-weighted aggregated solve is bit-identical to
the expanded per-flow solve — the container still has no cargo, so this
is the satellite evidence that the engine-side fixes/additions preserve
exact semantics.

Note (PR 9): the reference loop gained the engine's fault-capacity merge
(fabric::faults / fabric::sim): an attached per-resource (t, mult) step
function is baked into the initial pricing at the first arrival and later
changes re-price capacities through a `next_fault` cursor that the event
loop treats as one more event source. The mirror covers brownouts only
(multiplier > 0); hard-downs need the re-route/park machinery, which is
pinned Rust-side by tests/fault_properties.rs. verify_faults() asserts
the two claims that make the goldens trustworthy under the new code: an
attached-but-never-firing timeline is byte-identical to the healthy loop
(so `faults = none` plus "no change lands in the batch" is the pre-fault
engine), and a mid-flight brownout lands exactly on the closed form
tau + (B - r*(tau - a)) / (r*f).

Usage: python3 tools/gen_golden.py [--out-dir tests/golden]
"""

import argparse
import os

# ---------------------------------------------------------------------------
# util/table.rs
# ---------------------------------------------------------------------------


def fnum(x: float) -> str:
    """Mirror of util::table::fnum."""
    if x == 0.0:
        return "0"
    a = abs(x)
    if a >= 1000.0:
        return f"{x:.0f}"
    if a >= 10.0:
        return f"{x:.1f}"
    if a >= 0.01:
        return f"{x:.3f}"
    mant, exp = f"{x:.3e}".split("e")
    return f"{mant}e{int(exp)}"  # Rust LowerExp: no '+', no leading zeros


def csv_cell(c: str) -> str:
    if "," in c or '"' in c or "\n" in c:
        return '"' + c.replace('"', '""') + '"'
    return c


def to_csv(headers, rows) -> str:
    out = ",".join(csv_cell(h) for h in headers) + "\n"
    for row in rows:
        out += ",".join(csv_cell(c) for c in row) + "\n"
    return out


# ---------------------------------------------------------------------------
# models/arch.rs — the layer algebra (params + forward FLOPs only)
# ---------------------------------------------------------------------------


class ArchBuilder:
    def __init__(self, h, w, c):
        self.h, self.w, self.c = h, w, c
        self.layers = []  # (params:int, flops:float)

    @staticmethod
    def _out(dim, k, stride, pad):
        return (dim + 2 * pad - k) // stride + 1

    def conv_rect(self, out_c, k, stride, pad, bias):
        k0, k1 = k
        p0, p1 = pad
        oh = self._out(self.h, k0, stride, p0)
        ow = self._out(self.w, k1, stride, p1)
        params = k0 * k1 * self.c * out_c + (out_c if bias else 0)
        flops = 2.0 * float(k0 * k1 * self.c) * float(out_c * oh * ow)
        self.layers.append((params, flops))
        self.h, self.w, self.c = oh, ow, out_c
        return self

    def conv(self, out_c, k, stride, pad, bias):
        return self.conv_rect(out_c, (k, k), stride, (pad, pad), bias)

    def bn(self):
        self.layers.append((2 * self.c, 4.0 * float(self.h * self.w * self.c)))
        return self

    def relu(self):
        self.layers.append((0, float(self.h * self.w * self.c)))
        return self

    def pool(self, k, stride, pad):
        oh = self._out(self.h, k, stride, pad)
        ow = self._out(self.w, k, stride, pad)
        self.layers.append((0, float(k * k) * float(oh * ow * self.c)))
        self.h, self.w = oh, ow
        return self

    def global_pool(self):
        self.layers.append((0, float(self.h * self.w * self.c)))
        self.h = self.w = 1
        return self

    def fc(self, out):
        inp = self.h * self.w * self.c
        self.layers.append((inp * out + out, 2.0 * float(inp * out)))
        self.h, self.w, self.c = 1, 1, out
        return self

    def total_params(self):
        return sum(p for p, _ in self.layers)

    def flops_fwd(self):
        s = 0.0
        for _, f in self.layers:
            s += f
        return s


def vgg16():
    b = ArchBuilder(224, 224, 3)
    for stage in ([64, 64], [128, 128], [256, 256, 256], [512, 512, 512], [512, 512, 512]):
        for c in stage:
            b.conv(c, 3, 1, 1, True).relu()
        b.pool(2, 2, 0)
    b.fc(4096).relu().fc(4096).relu().fc(1000)
    return b, 125.0


def alexnet():
    b = ArchBuilder(224, 224, 3)
    b.conv(64, 11, 4, 2, True).relu().pool(3, 2, 0)
    b.conv(192, 5, 1, 2, True).relu().pool(3, 2, 0)
    b.conv(384, 3, 1, 1, True).relu()
    b.conv(256, 3, 1, 1, True).relu()
    b.conv(256, 3, 1, 1, True).relu().pool(3, 2, 0)
    b.fc(4096).relu().fc(4096).relu().fc(1000)
    return b, 2400.0


def _bottleneck(b, width, stride, downsample, stride_on_3x3):
    h, w, c_in = b.h, b.w, b.c
    out_c = width * 4
    s1, s3 = (1, stride) if stride_on_3x3 else (stride, 1)
    b.conv(width, 1, s1, 0, False).bn().relu()
    b.conv(width, 3, s3, 1, False).bn().relu()
    b.conv(out_c, 1, 1, 0, False).bn()
    if downsample:
        side = ArchBuilder(h, w, c_in).conv(out_c, 1, stride, 0, False).bn()
        b.layers.extend(side.layers)
    b.relu()
    return b


def resnet50():
    b = ArchBuilder(224, 224, 3)
    b.conv(64, 7, 2, 3, False).bn().relu().pool(3, 2, 1)
    for width, blocks, stride in [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]:
        for blk in range(blocks):
            s = stride if blk == 0 else 1
            _bottleneck(b, width, s, blk == 0, False)  # v1: stride on 1x1
    b.global_pool().fc(1000)
    return b, 365.0


def inception_v3():
    layers = []

    def unit(h, w, c, out_c, k, stride, pad):
        u = ArchBuilder(h, w, c).conv_rect(out_c, k, stride, pad, False).bn().relu()
        layers.extend(u.layers)
        return u.h, u.w, u.c

    s = unit(299, 299, 3, 32, (3, 3), 2, (0, 0))
    s = unit(s[0], s[1], s[2], 32, (3, 3), 1, (0, 0))
    s = unit(s[0], s[1], s[2], 64, (3, 3), 1, (1, 1))
    h = (s[0] - 3) // 2 + 1
    w = (s[1] - 3) // 2 + 1
    c = s[2]
    s = unit(h, w, c, 80, (1, 1), 1, (0, 0))
    s = unit(s[0], s[1], s[2], 192, (3, 3), 1, (0, 0))
    h = (s[0] - 3) // 2 + 1
    w = (s[1] - 3) // 2 + 1
    c = s[2]

    for pool_c in (32, 64, 64):  # Inception-A
        out = 0
        unit(h, w, c, 64, (1, 1), 1, (0, 0))
        out += 64
        s2 = unit(h, w, c, 48, (1, 1), 1, (0, 0))
        unit(s2[0], s2[1], s2[2], 64, (5, 5), 1, (2, 2))
        out += 64
        s2 = unit(h, w, c, 64, (1, 1), 1, (0, 0))
        s2 = unit(s2[0], s2[1], s2[2], 96, (3, 3), 1, (1, 1))
        unit(s2[0], s2[1], s2[2], 96, (3, 3), 1, (1, 1))
        out += 96
        unit(h, w, c, pool_c, (1, 1), 1, (0, 0))
        out += pool_c
        c = out

    # Reduction-A
    s1 = unit(h, w, c, 384, (3, 3), 2, (0, 0))
    s2 = unit(h, w, c, 64, (1, 1), 1, (0, 0))
    s2 = unit(s2[0], s2[1], s2[2], 96, (3, 3), 1, (1, 1))
    unit(s2[0], s2[1], s2[2], 96, (3, 3), 2, (0, 0))
    h, w = s1[0], s1[1]
    c = 384 + 96 + c

    for mid in (128, 160, 160, 192):  # Inception-B
        out = 0
        unit(h, w, c, 192, (1, 1), 1, (0, 0))
        out += 192
        s2 = unit(h, w, c, mid, (1, 1), 1, (0, 0))
        s2 = unit(s2[0], s2[1], s2[2], mid, (1, 7), 1, (0, 3))
        unit(s2[0], s2[1], s2[2], 192, (7, 1), 1, (3, 0))
        out += 192
        s2 = unit(h, w, c, mid, (1, 1), 1, (0, 0))
        s2 = unit(s2[0], s2[1], s2[2], mid, (7, 1), 1, (3, 0))
        s2 = unit(s2[0], s2[1], s2[2], mid, (1, 7), 1, (0, 3))
        s2 = unit(s2[0], s2[1], s2[2], mid, (7, 1), 1, (3, 0))
        unit(s2[0], s2[1], s2[2], 192, (1, 7), 1, (0, 3))
        out += 192
        unit(h, w, c, 192, (1, 1), 1, (0, 0))
        out += 192
        c = out

    # Reduction-B
    s2 = unit(h, w, c, 192, (1, 1), 1, (0, 0))
    s1 = unit(s2[0], s2[1], s2[2], 320, (3, 3), 2, (0, 0))
    s2 = unit(h, w, c, 192, (1, 1), 1, (0, 0))
    s2 = unit(s2[0], s2[1], s2[2], 192, (1, 7), 1, (0, 3))
    s2 = unit(s2[0], s2[1], s2[2], 192, (7, 1), 1, (3, 0))
    unit(s2[0], s2[1], s2[2], 192, (3, 3), 2, (0, 0))
    h, w = s1[0], s1[1]
    c = 320 + 192 + c

    for _ in range(2):  # Inception-C
        out = 0
        unit(h, w, c, 320, (1, 1), 1, (0, 0))
        out += 320
        s2 = unit(h, w, c, 384, (1, 1), 1, (0, 0))
        unit(s2[0], s2[1], s2[2], 384, (1, 3), 1, (0, 1))
        unit(s2[0], s2[1], s2[2], 384, (3, 1), 1, (1, 0))
        out += 768
        s2 = unit(h, w, c, 448, (1, 1), 1, (0, 0))
        s2 = unit(s2[0], s2[1], s2[2], 384, (3, 3), 1, (1, 1))
        unit(s2[0], s2[1], s2[2], 384, (1, 3), 1, (0, 1))
        unit(s2[0], s2[1], s2[2], 384, (3, 1), 1, (1, 0))
        out += 768
        unit(h, w, c, 192, (1, 1), 1, (0, 0))
        out += 192
        c = out

    b = ArchBuilder(h, w, 0)
    b.c = c
    b.layers = layers + b.layers
    b.global_pool().fc(1000)
    return b, 240.0


# ---------------------------------------------------------------------------
# models/perf.rs + experiments/table1.rs
# ---------------------------------------------------------------------------

V100_PEAK_FP32 = 15.7e12
BWD_OVER_FWD = 2.0
IMAGENET_IMAGES = 1.281e6
ERA_SCALING = 0.9

# cluster/gpu.rs: (peak_fp32, mem_bw)
GPUS = {
    "GTX580": (1.58e12, 192.0e9),
    "K40": (5.0e12, 288.0e9),
    "P100": (10.6e12, 732.0e9),
    "TITAN_BLACK": (5.1e12, 336.0e9),
}


def modeled_hours(arch, ref_ips, gpu, gpus, epochs):
    flops_fwd = arch.flops_fwd()
    eff = (flops_fwd * (1.0 + BWD_OVER_FWD) * ref_ips) / V100_PEAK_FP32
    peak, mem_bw = gpu
    sustained = peak * eff
    batch = 32
    fwd = flops_fwd * float(batch) / sustained
    bwd = fwd * BWD_OVER_FWD
    optimizer = 5.0 * 4.0 * float(arch.total_params()) / mem_bw
    total = fwd + bwd + optimizer
    ips = float(batch) / total * float(gpus) * ERA_SCALING
    return epochs * IMAGENET_IMAGES / ips / 3600.0


def table1_csv():
    rows_spec = [
        ("alexnet", "5-7 days", "2 x NVIDIA GTX 580", 2, GPUS["GTX580"], 90.0, alexnet),
        ("inception_v3", "2 weeks", "8 x NVIDIA Tesla K40", 8, GPUS["K40"], 100.0, inception_v3),
        ("resnet50", "29 hours", "8 x NVIDIA Tesla P100", 8, GPUS["P100"], 90.0, resnet50),
        ("vgg16", "2-3 weeks", "4 x NVIDIA Titan Black", 4, GPUS["TITAN_BLACK"], 74.0, vgg16),
    ]
    rows = []
    for model, paper, hw, n, gpu, epochs, builder in rows_spec:
        arch, ref_ips = builder()
        hours = modeled_hours(arch, ref_ips, gpu, n, epochs)
        human = f"{hours / 24.0:.1f} days" if hours > 48.0 else f"{hours:.0f} hours"
        rows.append([model, paper, hw, human, f"{hours:.1f}"])
    headers = ["Model", "Paper time", "Hardware", "Modeled time", "Modeled hours"]
    return to_csv(headers, rows)


# ---------------------------------------------------------------------------
# fabric presets + cluster (config/presets.rs, config/spec.rs)
# ---------------------------------------------------------------------------


class Fabric:
    def __init__(self, name, latency_us, bw_gbps, eff, overhead_us, eager, hop_us, knee, coeff, uplink_gbps):
        self.name = name
        self.latency = latency_us * 1e-6
        self.bandwidth_gbps = bw_gbps
        self.efficiency = eff
        self.per_msg_overhead = overhead_us * 1e-6
        self.eager_threshold = eager
        self.switch_hop_latency = hop_us * 1e-6
        self.congestion_knee_flows = knee
        self.congestion_coeff = coeff
        self.rack_uplink_gbps = uplink_gbps

    def effective_bandwidth(self):
        return self.bandwidth_gbps * 1e9 / 8.0 * self.efficiency

    def rack_uplink_bandwidth(self):
        return self.rack_uplink_gbps * 1e9 / 8.0 * self.efficiency

    def congestion_factor(self, flows):
        if self.congestion_coeff <= 0.0 or flows <= self.congestion_knee_flows:
            return 1.0
        excess = (flows - self.congestion_knee_flows) / self.congestion_knee_flows
        return 1.0 / (1.0 + self.congestion_coeff * excess)


ETH = Fabric("25GbE-RoCE", 1.8, 25.0, 0.92, 0.6, 16.0 * 1024.0, 0.5, 160.0, 0.35, 200.0)
OPA = Fabric("OPA-100", 1.1, 100.0, 0.88, 0.4, 8.0 * 1024.0, 0.15, 1024.0, 0.1, 800.0)

CLUSTER_NODES = 448
CORES_PER_NODE = 40
NODES_PER_RACK = 32
SHM_BW = 10.0e9
SHM_LATENCY = 0.3e-6


# ---------------------------------------------------------------------------
# cfd/mesh.rs
# ---------------------------------------------------------------------------

PAPER_MESH = (32, 32, 32)
DG_NODES_1D = 8
FIELDS = 5
FACE_BYTES_PER_ELEM = float(DG_NODES_1D * DG_NODES_1D * FIELDS * 8)


def factor3(p):
    best = (p, 1, 1)
    best_score = float("inf")
    i = 1
    while i * i * i <= p:
        if p % i == 0:
            q = p // i
            j = i
            while j * j <= q:
                if q % j == 0:
                    k = q // j
                    a, b, c = float(k), float(j), float(i)
                    score = a * b + b * c + a * c
                    if score < best_score:
                        best_score = score
                        best = (k, j, i)
                j += 1
        i += 1
    return best


class MeshPartition:
    def __init__(self, mesh, ranks):
        self.mesh = mesh
        self.grid = factor3(ranks)
        self.ranks = ranks

    def block_dims(self):
        return (
            -(-self.mesh[0] // self.grid[0]),
            -(-self.mesh[1] // self.grid[1]),
            -(-self.mesh[2] // self.grid[2]),
        )

    def elems_per_rank(self):
        b = self.block_dims()
        return b[0] * b[1] * b[2]

    def rank_of(self, x, y, z):
        return (z * self.grid[1] + y) * self.grid[0] + x

    def coords_of(self, rank):
        gx, gy = self.grid[0], self.grid[1]
        return (rank % gx, (rank // gx) % gy, rank // (gx * gy))

    def neighbors(self, rank):
        x, y, z = self.coords_of(rank)
        gx, gy, gz = self.grid
        b = self.block_dims()
        faces = [
            ((x + gx - 1) % gx, y, z, b[1] * b[2]),
            ((x + 1) % gx, y, z, b[1] * b[2]),
            (x, (y + gy - 1) % gy, z, b[0] * b[2]),
            (x, (y + 1) % gy, z, b[0] * b[2]),
            (x, y, (z + gz - 1) % gz, b[0] * b[1]),
            (x, y, (z + 1) % gz, b[0] * b[1]),
        ]
        out = []
        for nx, ny, nz, area in faces:
            n = self.rank_of(nx, ny, nz)
            if n != rank:
                out.append((n, area))
        return out


# ---------------------------------------------------------------------------
# fabric/sim.rs + fabric/contention.rs — the fluid event engine
# ---------------------------------------------------------------------------


def time_eps(t):
    return 1e-12 * (1.0 + abs(t))


def byte_eps(b):
    return 1e-12 * (1.0 + b)


def max_min_rates(caps, flow_caps, flow_res):
    n = len(flow_caps)
    rate = [0.0] * n
    frozen = [False] * n
    remaining = list(caps)
    load = [0] * len(caps)
    for fr in flow_res:
        for rid in fr:
            load[rid] += 1
    unfrozen = n
    while unfrozen > 0:
        delta = float("inf")
        for i in range(n):
            if not frozen[i]:
                d = flow_caps[i] - rate[i]
                if d < delta:
                    delta = d
        for r, l in enumerate(load):
            if l > 0:
                d = remaining[r] / float(l)
                if d < delta:
                    delta = d
        if delta != float("inf") and delta > 0.0:
            for i in range(n):
                if not frozen[i]:
                    rate[i] += delta
            for r, l in enumerate(load):
                if l > 0:
                    remaining[r] -= delta * float(l)
        newly = 0
        for i in range(n):
            if frozen[i]:
                continue
            cap_hit = rate[i] >= flow_caps[i] * (1.0 - 1e-12)
            res_hit = any(remaining[r] <= caps[r] * 1e-12 for r in flow_res[i])
            if cap_hit or res_hit:
                frozen[i] = True
                newly += 1
                for r in flow_res[i]:
                    load[r] -= 1
        if newly == 0:
            break
        unfrozen -= newly
    return rate


def max_min_rates_weighted(caps, flow_caps, flow_res, weights):
    """Integer-weighted max_min_rates (PR 8 flow-aggregation mirror).

    Unit i stands for ``weights[i]`` identical member flows and
    ``rate[i]`` is the *per-member* rate. Resource loads are integer
    sums of weights, so every round's delta, every freeze decision, and
    every f64 operation matches the expanded unweighted solve exactly:
    bit-identity by construction, asserted by verify_aggregation()."""
    n = len(flow_caps)
    rate = [0.0] * n
    frozen = [False] * n
    remaining = list(caps)
    load = [0] * len(caps)
    for fr, w in zip(flow_res, weights):
        for rid in fr:
            load[rid] += w
    unfrozen = n
    while unfrozen > 0:
        delta = float("inf")
        for i in range(n):
            if not frozen[i]:
                d = flow_caps[i] - rate[i]
                if d < delta:
                    delta = d
        for r, l in enumerate(load):
            if l > 0:
                d = remaining[r] / float(l)
                if d < delta:
                    delta = d
        if delta != float("inf") and delta > 0.0:
            for i in range(n):
                if not frozen[i]:
                    rate[i] += delta
            for r, l in enumerate(load):
                if l > 0:
                    remaining[r] -= delta * float(l)
        newly = 0
        for i in range(n):
            if frozen[i]:
                continue
            cap_hit = rate[i] >= flow_caps[i] * (1.0 - 1e-12)
            res_hit = any(remaining[r] <= caps[r] * 1e-12 for r in flow_res[i])
            if cap_hit or res_hit:
                frozen[i] = True
                newly += 1
                for r in flow_res[i]:
                    load[r] -= weights[i]
        if newly == 0:
            break
        unfrozen -= newly
    return rate


class NetSim:
    """Mirror of fabric::sim::NetSim for CPU endpoints, fresh per batch."""

    def __init__(self, fabric):
        self.fabric = fabric
        self.n_nodes = CLUSTER_NODES
        self.n_racks = -(-CLUSTER_NODES // NODES_PER_RACK)
        nic = fabric.effective_bandwidth()
        uplink = fabric.rack_uplink_bandwidth()
        self.res_caps = [nic] * (2 * self.n_nodes) + [uplink] * (2 * self.n_racks)
        # FIFO resource carry-over across batches (fabric::sim): a flow's
        # arrival is floored by the busy_until of every resource on its
        # route, and each batch advances those clocks to its finishes.
        # The golden drivers issue one batch per fresh engine, so this is
        # inert for them; the DP-lowering verification below replays many
        # batches and needs it.
        self.busy_until = [0.0] * len(self.res_caps)
        self.inter_rack_messages = 0
        # PR 8 mirror of NetStats.budget_exceeded: counts fluid solves
        # that tripped the event budget (must stay 0 for the goldens).
        self.budget_exceeded = 0
        # PR 8 flow aggregation (off by default — the goldens pin the
        # expanded path; verify_aggregation() proves both are the same
        # bits). Mirrors TransportOptions.flow_aggregation and the
        # NetStats agg_units / agg_collapsed counters.
        self.aggregate = False
        self.agg_units = 0
        self.agg_collapsed = 0
        # PR 9 fault-injection mirror (off by default — the goldens pin
        # the healthy path). `fault_steps` maps a resource id of THIS
        # mirror's layout (tx / rx / rack-up / rack-down, see res_caps)
        # to a sorted (t, mult) step function, mirroring a compiled
        # FaultTimeline; attaching one forces every batch onto the fluid
        # path exactly as fabric::sim does. Brownouts only (mult > 0):
        # hard-downs need the re-route/park machinery, which stays
        # Rust-side (pinned by tests/fault_properties.rs).
        self.fault_steps = None
        self.fault_changes = ()

    def set_fault_steps(self, steps):
        """Attach a fault timeline: {res_id: [(t, mult), ...] sorted}.

        Mirror of NetSim::set_faults with a pre-compiled FaultTimeline —
        the step function's multiplier applies from t (inclusive);
        before the first entry it is 1. The mirror supports brownouts
        only, so every multiplier must be strictly positive."""
        for sf in steps.values():
            for st, mult in sf:
                assert mult > 0.0, "mirror supports brownouts only (mult > 0)"
                assert st >= 0.0
        self.fault_steps = steps
        self.fault_changes = tuple(sorted(set(t for sf in steps.values() for t, _ in sf)))

    def fault_mult_at(self, rid, t):
        """FaultTimeline::mult_at — last step at or before t wins."""
        sf = None if self.fault_steps is None else self.fault_steps.get(rid)
        if not sf:
            return 1.0
        k = 0
        while k < len(sf) and sf[k][0] <= t:
            k += 1
        return 1.0 if k == 0 else sf[k - 1][1]

    def fault_next_change_after(self, t):
        """FaultTimeline::next_change_after — first change strictly > t."""
        for c in self.fault_changes:
            if c > t:
                return c
        return float("inf")

    def network_cost(self, bytes_, inter_rack):
        # transport::network_message for a CPU endpoint with RDMA on.
        f = self.fabric
        sw = f.per_msg_overhead
        latency = f.latency
        if inter_rack:
            latency += 2.0 * f.switch_hop_latency
        if bytes_ > f.eager_threshold:
            latency += 2.0 * f.latency
        return sw, latency, sw, f.effective_bandwidth()

    def transfer_batch(self, reqs):
        """reqs: list of (src_node, dst_node, bytes, ready).
        Returns list of (send_release, recv_complete)."""
        out = [(0.0, 0.0)] * len(reqs)
        flows = []  # dicts
        for i, (src_node, dst_node, bytes_, ready) in enumerate(reqs):
            if src_node == dst_node:
                done = ready + (SHM_LATENCY + bytes_ / SHM_BW)
                out[i] = (done, done)
                continue
            src_rack = src_node // NODES_PER_RACK
            dst_rack = dst_node // NODES_PER_RACK
            inter_rack = src_rack != dst_rack
            if inter_rack:
                self.inter_rack_messages += 1
            send_ov, latency, recv_ov, bw = self.network_cost(bytes_, inter_rack)
            res = [src_node, self.n_nodes + dst_node]
            if inter_rack:
                res.append(2 * self.n_nodes + src_rack)
                res.append(2 * self.n_nodes + self.n_racks + dst_rack)
            arrival = ready + send_ov
            for rid in res:
                arrival = max(arrival, self.busy_until[rid])
            flows.append(
                dict(
                    req_idx=i,
                    src_node=src_node,
                    arrival=arrival,
                    bytes=bytes_,
                    cap=bw,
                    latency=latency,
                    recv_overhead=recv_ov,
                    res=res,
                )
            )
        if not flows:
            return out

        srcs = sorted(set(f["src_node"] for f in flows))
        factor = self.fabric.congestion_factor(float(len(srcs)))

        load = {}
        contended = False
        for f in flows:
            for rid in f["res"]:
                load[rid] = load.get(rid, 0) + 1
                if load[rid] > 1:
                    contended = True
        # An attached fault timeline forces the fluid path (and disables
        # aggregation), mirroring fabric::sim::transfer_batch; with no
        # timeline attached the dispatch is byte-identical to pre-PR 9.
        if contended or self.fault_steps is not None:
            if self.aggregate and self.fault_steps is None:
                finishes = self.fluid_finishes_aggregated(flows, factor)
            else:
                finishes = self.fluid_finishes(flows, factor)
        else:
            finishes = [f["arrival"] + f["bytes"] / (f["cap"] * factor) for f in flows]

        for f, fin in zip(flows, finishes):
            recv_complete = fin + f["latency"] + f["recv_overhead"]
            out[f["req_idx"]] = (fin, recv_complete)
            for rid in f["res"]:
                self.busy_until[rid] = max(self.busy_until[rid], fin)
        return out

    def fluid_finishes(self, flows, factor):
        n = len(flows)
        ids = sorted(set(rid for f in flows for rid in f["res"]))
        id_pos = {rid: k for k, rid in enumerate(ids)}
        caps = [self.res_caps[rid] * factor for rid in ids]
        res = [[id_pos[rid] for rid in f["res"]] for f in flows]
        fcaps = [f["cap"] * factor for f in flows]
        arrivals = [f["arrival"] for f in flows]
        sizes = [f["bytes"] for f in flows]

        order = sorted(range(n), key=lambda i: arrivals[i])
        finish = [0.0] * n
        remaining = list(sizes)
        active = []
        ptr = 0
        t = arrivals[order[0]]
        # PR 9 fault merge (mirrors sim.rs fluid_finishes): changes at
        # or before the first arrival are baked into the initial
        # pricing; later ones re-price through the `next_fault` cursor.
        # With no timeline attached, `next_fault` stays +inf and every
        # line below is byte-identical to the healthy loop.
        if self.fault_steps is not None:
            caps = [
                self.res_caps[rid] * factor * self.fault_mult_at(rid, t) for rid in ids
            ]
            next_fault = self.fault_next_change_after(t)
        else:
            next_fault = float("inf")
        # PR 8: engine budget formula (sim.rs fluid_finishes); the old
        # mirror's tighter 512 + 40M/(n+64) budget was never hit by the
        # golden drivers, so raising it is byte-neutral for the fixtures.
        max_events = 2048 + 200_000_000 // (n + 64)
        events = 0
        while True:
            # Merge fault capacity changes due at t: re-price every
            # touched resource at the change instant (the engine dirties
            # only the affected groups; the mirror re-solves everything
            # each round, so a full re-price is the same semantics).
            while next_fault <= t + time_eps(t):
                for k, rid in enumerate(ids):
                    caps[k] = self.res_caps[rid] * factor * self.fault_mult_at(
                        rid, next_fault
                    )
                assert all(c > 0.0 for c in caps), "mirror supports brownouts only"
                next_fault = self.fault_next_change_after(next_fault)
            while ptr < n and arrivals[order[ptr]] <= t + time_eps(t):
                fi = order[ptr]
                ptr += 1
                if remaining[fi] <= byte_eps(sizes[fi]):
                    finish[fi] = arrivals[fi]
                else:
                    active.append(fi)
            if not active:
                if ptr >= n:
                    break
                # Hop to the earlier of the next arrival and the next
                # fault change so joiners always price against current
                # capacities (sim.rs does the same).
                nxt_arrival = arrivals[order[ptr]]
                t = next_fault if next_fault < nxt_arrival else nxt_arrival
                continue

            a_caps = [fcaps[fi] for fi in active]
            a_res = [res[fi] for fi in active]
            rates = max_min_rates(caps, a_caps, a_res)

            events += 1
            if events > max_events:
                self.budget_exceeded += 1
                for k, fi in enumerate(active):
                    finish[fi] = t + remaining[fi] / rates[k] if rates[k] > 0.0 else t
                while ptr < n:
                    fi = order[ptr]
                    ptr += 1
                    # f64::MIN_POSITIVE (smallest positive normal)
                    finish[fi] = arrivals[fi] + sizes[fi] / max(fcaps[fi], 2.2250738585072014e-308)
                break

            t_next = float("inf")
            for k, fi in enumerate(active):
                if rates[k] > 0.0:
                    cand = t + remaining[fi] / rates[k]
                    if cand < t_next:
                        t_next = cand
            if ptr < n and arrivals[order[ptr]] < t_next:
                t_next = arrivals[order[ptr]]
            if next_fault < t_next:
                t_next = next_fault
            if t_next == float("inf"):
                for fi in active:
                    finish[fi] = t
                active = []
                continue

            # PR 8 stall fix (mirrors sim.rs): retire a flow whose
            # *projected* finish chose t_next even when the f64 byte
            # subtraction leaves a sub-epsilon residue — otherwise the
            # same argmin flow is re-picked every iteration with dt == 0
            # and the loop burns its whole event budget standing still.
            dt = max(t_next - t, 0.0)
            still = []
            for k, fi in enumerate(active):
                proj = t + remaining[fi] / rates[k] if rates[k] > 0.0 else float("inf")
                remaining[fi] -= rates[k] * dt
                if remaining[fi] <= byte_eps(sizes[fi]) or proj <= t_next + time_eps(t_next):
                    finish[fi] = t_next
                else:
                    still.append(fi)
            t = t_next
            active = still
            if not active and ptr >= n:
                break
        return finish

    def fluid_finishes_aggregated(self, flows, factor):
        """PR 8 mirror of the engine's aggregated fluid path: flows with
        an identical (route, cap, arrival, bytes) key collapse into one
        integer-weighted unit, the loop solves units with
        max_min_rates_weighted, and de-aggregation is trivial — every
        member finishes exactly when its unit does. Members of a unit
        always share remaining/rate, so the event sequence (and the
        budget trip point, keyed to the member count) is identical to
        fluid_finishes; verify_aggregation() asserts the bit-identity."""
        unit_of = []
        key_pos = {}
        u_res, u_cap, u_arr, u_bytes, u_w = [], [], [], [], []
        for f in flows:
            key = (tuple(f["res"]), fbits(f["cap"]), fbits(f["arrival"]), fbits(f["bytes"]))
            k = key_pos.get(key)
            if k is None:
                k = len(u_res)
                key_pos[key] = k
                u_res.append(f["res"])
                u_cap.append(f["cap"])
                u_arr.append(f["arrival"])
                u_bytes.append(f["bytes"])
                u_w.append(0)
            u_w[k] += 1
            unit_of.append(k)

        m = len(u_res)
        self.agg_units += m
        self.agg_collapsed += len(flows) - m
        ids = sorted(set(rid for r in u_res for rid in r))
        id_pos = {rid: k for k, rid in enumerate(ids)}
        caps = [self.res_caps[rid] * factor for rid in ids]
        res = [[id_pos[rid] for rid in r] for r in u_res]
        fcaps = [c * factor for c in u_cap]
        arrivals = u_arr
        sizes = u_bytes

        order = sorted(range(m), key=lambda i: arrivals[i])
        finish = [0.0] * m
        remaining = list(sizes)
        active = []
        ptr = 0
        t = arrivals[order[0]]
        # Budget keyed to the MEMBER count, not the unit count, so the
        # trip point (if ever reached) matches the unaggregated loop's.
        max_events = 2048 + 200_000_000 // (len(flows) + 64)
        events = 0
        while True:
            while ptr < m and arrivals[order[ptr]] <= t + time_eps(t):
                fi = order[ptr]
                ptr += 1
                if remaining[fi] <= byte_eps(sizes[fi]):
                    finish[fi] = arrivals[fi]
                else:
                    active.append(fi)
            if not active:
                if ptr >= m:
                    break
                t = arrivals[order[ptr]]
                continue

            a_caps = [fcaps[fi] for fi in active]
            a_res = [res[fi] for fi in active]
            a_w = [u_w[fi] for fi in active]
            rates = max_min_rates_weighted(caps, a_caps, a_res, a_w)

            events += 1
            if events > max_events:
                self.budget_exceeded += 1
                for k, fi in enumerate(active):
                    finish[fi] = t + remaining[fi] / rates[k] if rates[k] > 0.0 else t
                while ptr < m:
                    fi = order[ptr]
                    ptr += 1
                    finish[fi] = arrivals[fi] + sizes[fi] / max(fcaps[fi], 2.2250738585072014e-308)
                break

            t_next = float("inf")
            for k, fi in enumerate(active):
                if rates[k] > 0.0:
                    cand = t + remaining[fi] / rates[k]
                    if cand < t_next:
                        t_next = cand
            if ptr < m and arrivals[order[ptr]] < t_next:
                t_next = arrivals[order[ptr]]
            if t_next == float("inf"):
                for fi in active:
                    finish[fi] = t
                active = []
                continue

            dt = max(t_next - t, 0.0)
            still = []
            for k, fi in enumerate(active):
                proj = t + remaining[fi] / rates[k] if rates[k] > 0.0 else float("inf")
                remaining[fi] -= rates[k] * dt
                if remaining[fi] <= byte_eps(sizes[fi]) or proj <= t_next + time_eps(t_next):
                    finish[fi] = t_next
                else:
                    still.append(fi)
            t = t_next
            active = still
            if not active and ptr >= m:
                break
        return [finish[k] for k in unit_of]


# ---------------------------------------------------------------------------
# cfd/solver.rs — StrongScaling::run_point + fig3 quick sweep
# ---------------------------------------------------------------------------

CORE_PEAK_FLOPS = 80.0e9
CARTDG_EFFICIENCY = 0.10
NS_PHYSICS_FACTOR = 10.0
IMBALANCE_FRACTION = 0.03
RK_STAGES = 4
DG_FLOPS_PER_ELEM = 3.0 * float(FIELDS) * float(DG_NODES_1D**3 * DG_NODES_1D) * 2.0
PER_ELEM_SECONDS = NS_PHYSICS_FACTOR * DG_FLOPS_PER_ELEM / (CORE_PEAK_FLOPS * CARTDG_EFFICIENCY)


def run_point(fabric, cores):
    part = MeshPartition(PAPER_MESH, cores)
    net = NetSim(fabric)
    elems = part.elems_per_rank()
    compute_time = float(RK_STAGES) * float(elems) * PER_ELEM_SECONDS

    msgs = []
    for r in range(cores):
        for n, face_elems in part.neighbors(r):
            msgs.append((r, n, float(face_elems) * FACE_BYTES_PER_ELEM))

    # Comm::round over a fresh communicator (all clocks zero).
    reqs = [(src // CORES_PER_NODE, dst // CORES_PER_NODE, b, 0.0) for src, dst, b in msgs]
    times = net.transfer_batch(reqs)
    t = [0.0] * cores
    for (src, dst, _), (send_release, recv_complete) in zip(msgs, times):
        if send_release > t[src]:
            t[src] = send_release
        rc = max(recv_complete, 0.0)
        if rc > t[dst]:
            t[dst] = rc
    wire_per_stage = max(t) if t else 0.0

    interior_window = float(elems) * PER_ELEM_SECONDS
    msgs_per_rank = float(len(part.neighbors(0)))
    sync_overhead = msgs_per_rank * (fabric.per_msg_overhead + fabric.latency)
    if net.inter_rack_messages > 0:
        sync_overhead += 2.0 * fabric.switch_hop_latency
    imbalance = IMBALANCE_FRACTION * interior_window
    exposed = max(wire_per_stage - interior_window, 0.0) + sync_overhead + imbalance
    return (
        compute_time,
        float(RK_STAGES) * exposed,
        float(RK_STAGES) * wire_per_stage,
        net.inter_rack_messages,
    )


def fig3_quick_csv():
    headers = ["cores", "fabric", "compute (s)", "comm (s)", "comm wire (s)", "inter-rack msgs"]
    rows = []
    for fabric in (ETH, OPA):
        for cores in (40, 320, 1280, 2560, 5120):
            compute, comm, wire, inter_rack = run_point(fabric, cores)
            rows.append([str(cores), fabric.name, fnum(compute), fnum(comm), fnum(wire), str(inter_rack)])
    return to_csv(headers, rows)


# ---------------------------------------------------------------------------
# trainer/scheduler.rs + workload/mod.rs — DP-lowering bit-identity check
#
# PR 7 rebuilt the trainer's communication scheduler as a workload-IR
# executor: bucketed data-parallel allreduce is *lowered* to a graph of
# collective nodes (workload::lower_dp) and run by a topological-frontier
# executor (scheduler::exec_frontier). The refactor's contract is that
# this path is bit-for-bit the pre-IR scheduler — serialized and
# multi-stream, chunked or not. The Rust suite pins that with verbatim
# pre-refactor oracles; this mirror re-proves it where no Rust toolchain
# is ambient, using the stateful engine above (every formula below
# mirrors its Rust counterpart, referenced in comments). Ranks sit one
# per node, CPU endpoints, straddling a rack boundary, so rounds cross
# both NIC and up-link resources.
# ---------------------------------------------------------------------------

import struct

BYTES_PER_ELEM = 4.0  # collectives/mod.rs
STREAM_MERGE_WINDOW = 2.5e-4  # trainer/scheduler.rs
COORDINATION_OVERHEAD = 1.0e-3


def fbits(x: float) -> bytes:
    return struct.pack("<d", x)


def chunk_ranges(elems, parts):
    """Mirror of collectives::chunk_ranges."""
    base, extra = elems // parts, elems % parts
    out, start = [], 0
    for i in range(parts):
        ln = base + (1 if i < extra else 0)
        out.append((start, start + ln))
        start += ln
    return out


def split_chunks(buckets, chunk_bytes):
    """Mirror of scheduler::split_chunks: [(elems, ready, launch)]."""
    if chunk_bytes is None:
        return [(e, r, True) for e, r in buckets]
    out = []
    for elems, ready in buckets:
        bytes_ = elems * BYTES_PER_ELEM
        parts = max(int(-(-bytes_ // chunk_bytes)), 1)
        if parts <= 1 or elems < 2:
            out.append((elems, ready, True))
            continue
        for i, (lo, hi) in enumerate(chunk_ranges(elems, min(parts, elems))):
            out.append((hi - lo, ready, i == 0))
    return out


def ring_allreduce_rounds(p, elems):
    """Mirror of RingAllreduce::allreduce as recorded by Comm::recorder:
    2(p-1) Round ops (reduce-scatter then allgather), msgs (src,dst,bytes)."""
    chunks = chunk_ranges(elems, p)
    rounds = []
    for k in range(p - 1):  # reduce-scatter: chunk (i - k) mod p
        rounds.append(
            [(i, (i + 1) % p, (chunks[(i + p - k % p) % p][1] - chunks[(i + p - k % p) % p][0]) * BYTES_PER_ELEM) for i in range(p)]
        )
    for k in range(p - 1):  # allgather: chunk (i + 1 - k) mod p
        rounds.append(
            [(i, (i + 1) % p, (chunks[(i + 1 + p - k % p) % p][1] - chunks[(i + 1 + p - k % p) % p][0]) * BYTES_PER_ELEM) for i in range(p)]
        )
    return rounds


def apply_round(t, snapshot, msgs, times):
    """Mirror of mpi::apply_round."""
    for (src, dst, _), (send_release, recv_complete) in zip(msgs, times):
        t[src] = max(t[src], send_release)
        t[dst] = max(t[dst], max(recv_complete, snapshot[dst]))


def submit_round(net, node_of, snapshot, msgs):
    reqs = [(node_of[src], node_of[dst], b, snapshot[src]) for src, dst, b in msgs]
    return net.transfer_batch(reqs)


def legacy_serialized(net, node_of, works, p):
    """Mirror of scheduler::run_serialized (cache off): the pre-scheduler
    trainer loop — each collective starts after the previous finished on
    every rank."""
    prev_done = [0.0] * p
    comm_done = [0.0] * p
    intervals = []
    for elems, ready, launch in works:
        coord = COORDINATION_OVERHEAD if launch else 0.0
        start = [max(ready[r], prev_done[r]) + coord for r in range(p)]
        t = list(start)
        for msgs in ring_allreduce_rounds(p, elems):
            snapshot = list(t)
            times = submit_round(net, node_of, snapshot, msgs)
            apply_round(t, snapshot, msgs, times)
        comm_done = list(t)
        prev_done = list(t)
        intervals.append((max([0.0] + start), max([0.0] + t)))
    return comm_done, intervals


def legacy_multi_stream(net, node_of, buckets, p, num_streams, chunk_bytes):
    """Mirror of the pre-IR multi-stream scheduler (the verbatim oracle in
    scheduler.rs tests): per-stream op queues, merge-window batching.
    Streams are assigned per *bucket* (chunks of one bucket stay on its
    stream), and the stream count is capped by the bucket count."""
    s_count = min(num_streams, max(len(buckets), 1))
    works = []  # (elems, ready, launch, stream)
    for b, bucket in enumerate(buckets):
        for elems, ready, launch in split_chunks([bucket], chunk_bytes):
            works.append((elems, ready, launch, b % s_count))
    patterns = {}  # elems -> rounds (recording order = first-use order)
    for elems, _, _, _ in works:
        if elems not in patterns:
            patterns[elems] = ring_allreduce_rounds(p, elems)
    queues = [[] for _ in range(s_count)]
    for w, (elems, ready, launch, stream) in enumerate(works):
        q = queues[stream]
        q.append(("begin", w))
        for i in range(len(patterns[elems])):
            q.append(("op", w, i))
        q.append(("end", w))
    clocks = [[0.0] * p for _ in range(s_count)]
    intervals = [(0.0, 0.0)] * len(works)
    while True:
        for s in range(s_count):
            while queues[s]:
                item = queues[s][0]
                if item[0] == "begin":
                    w = item[1]
                    elems, ready, launch, _ = works[w]
                    coord = COORDINATION_OVERHEAD if launch else 0.0
                    for r in range(p):
                        clocks[s][r] = max(ready[r], clocks[s][r]) + coord
                    intervals[w] = (max([0.0] + clocks[s]), intervals[w][1])
                elif item[0] == "end":
                    w = item[1]
                    intervals[w] = (intervals[w][0], max([0.0] + clocks[s]))
                else:
                    break  # engine op: head of this stream's frontier
                queues[s].pop(0)
        cands = []
        for s in range(s_count):
            if queues[s] and queues[s][0][0] == "op":
                _, w, i = queues[s][0]
                msgs = patterns[works[w][0]][i]
                cands.append((s, min(clocks[s][src] for src, _, _ in msgs)))
        if not cands:
            break
        t0 = min(r for _, r in cands)
        chosen = [s for s, r in cands if r <= t0 + STREAM_MERGE_WINDOW]
        reqs, parts = [], []
        for s in chosen:
            _, w, i = queues[s][0]
            msgs = patterns[works[w][0]][i]
            snapshot = list(clocks[s])
            first = len(reqs)
            reqs.extend((node_of[src], node_of[dst], b, snapshot[src]) for src, dst, b in msgs)
            parts.append((s, msgs, snapshot, first))
        times = net.transfer_batch(reqs)
        for s, msgs, snapshot, first in parts:
            apply_round(clocks[s], snapshot, msgs, times[first : first + len(msgs)])
            queues[s].pop(0)
    comm_done = [max(clocks[s][r] for s in range(s_count)) for r in range(p)]
    return comm_done, intervals


def lower_dp(buckets, num_streams, chunk_bytes):
    """Mirror of workload::lower_dp: [(elems, ready, stream, launch)]."""
    s_count = min(num_streams, max(len(buckets), 1))
    nodes = []
    for b, (elems, ready) in enumerate(buckets):
        for c_elems, c_ready, launch in split_chunks([(elems, ready)], chunk_bytes):
            nodes.append((c_elems, c_ready, b % s_count, launch))
    return nodes


def exec_frontier(net, node_of, nodes, p):
    """Mirror of scheduler::exec_frontier on a DP graph (allreduce nodes,
    no deps): acquire each node's recorded schedule (dedup within the
    step), drain engine-free items per stream, then batch the heads of
    all streams ready within the merge window."""
    s_count = max((s for _, _, s, _ in nodes), default=0) + 1
    local = {}  # (sig, elems) -> rounds; sig constant: one strategy
    ops_of = []
    for elems, _, _, _ in nodes:
        key = ("allreduce", elems)
        if key not in local:
            local[key] = ring_allreduce_rounds(p, elems)
        ops_of.append(local[key])
    queues = [[] for _ in range(s_count)]
    for n, (elems, ready, stream, launch) in enumerate(nodes):
        q = queues[stream]
        q.append(("begin", n))
        for i in range(len(ops_of[n])):
            q.append(("op", n, i))
        q.append(("end", n))
    clocks = [[0.0] * p for _ in range(s_count)]
    intervals = [(0.0, 0.0)] * len(nodes)
    while True:
        while True:  # engine-free fixpoint (trivial for dependency-free DP)
            progress = False
            for s in range(s_count):
                while queues[s]:
                    item = queues[s][0]
                    if item[0] == "begin":
                        n = item[1]
                        _, ready, _, launch = nodes[n]
                        coord = COORDINATION_OVERHEAD if launch else 0.0
                        for r in range(p):
                            clocks[s][r] = max(ready[r], clocks[s][r]) + coord
                        intervals[n] = (max([0.0] + clocks[s]), intervals[n][1])
                    elif item[0] == "end":
                        n = item[1]
                        intervals[n] = (intervals[n][0], max([0.0] + clocks[s]))
                    else:
                        break
                    queues[s].pop(0)
                    progress = True
            if not progress:
                break
        cands = []
        for s in range(s_count):
            if queues[s] and queues[s][0][0] == "op":
                _, n, i = queues[s][0]
                msgs = ops_of[n][i]
                cands.append((s, min(clocks[s][src] for src, _, _ in msgs)))
        if not cands:
            break
        t0 = min(r for _, r in cands)
        chosen = [s for s, r in cands if r <= t0 + STREAM_MERGE_WINDOW]
        reqs, parts = [], []
        for s in chosen:
            _, n, i = queues[s][0]
            msgs = ops_of[n][i]
            snapshot = list(clocks[s])
            first = len(reqs)
            reqs.extend((node_of[src], node_of[dst], b, snapshot[src]) for src, dst, b in msgs)
            parts.append((s, msgs, snapshot, first))
        times = net.transfer_batch(reqs)
        for s, msgs, snapshot, first in parts:
            apply_round(clocks[s], snapshot, msgs, times[first : first + len(msgs)])
            queues[s].pop(0)
    comm_done = [max(clocks[s][r] for s in range(s_count)) for r in range(p)]
    return comm_done, intervals


def verify_dp_lowering():
    """Assert lower_dp + exec_frontier == the pre-IR scheduler, to the
    bit, on both fabrics at 1 and 4 streams, chunked and not. Mirrors
    scheduler.rs::dp_through_ir_matches_legacy_scheduler_bit_for_bit
    (with per-rank staggered readies on top). At 1 stream this checks
    the *frontier* executor against the serialized loop — the stronger
    form of the claim the Rust `execute` dispatch relies on."""
    p = 8
    node_of = [r * 8 for r in range(p)]  # one rank per node, racks 0 and 1
    checked = 0
    for fab in (ETH, OPA):
        for streams in (1, 4):
            for chunk in (None, 60_000.0):
                buckets = [
                    (30_000 + 17_000 * i, [0.003 * i + 0.0002 * r for r in range(p)])
                    for i in range(5)
                ]
                net_a = NetSim(fab)
                nodes = lower_dp(buckets, streams, chunk)
                got_done, got_iv = exec_frontier(net_a, node_of, nodes, p)
                net_b = NetSim(fab)
                if streams <= 1:
                    works = split_chunks(buckets, chunk)
                    want_done, want_iv = legacy_serialized(net_b, node_of, works, p)
                else:
                    want_done, want_iv = legacy_multi_stream(
                        net_b, node_of, buckets, p, streams, chunk
                    )
                tag = f"{fab.name} streams={streams} chunk={chunk}"
                assert len(got_done) == len(want_done), tag
                for a, b in zip(got_done, want_done):
                    assert fbits(a) == fbits(b), f"comm_done diverged: {tag}: {a!r} != {b!r}"
                assert len(got_iv) == len(want_iv), tag
                for (a0, a1), (b0, b1) in zip(got_iv, want_iv):
                    assert fbits(a0) == fbits(b0), f"interval start: {tag}: {a0!r} != {b0!r}"
                    assert fbits(a1) == fbits(b1), f"interval end: {tag}: {a1!r} != {b1!r}"
                checked += 1
    print(f"DP-lowering bit-identity: {checked} scenarios OK")


def verify_aggregation():
    """Assert the integer-weighted aggregated fluid path == the expanded
    per-flow solve, to the bit, on both fabrics (mirrors
    tests/aggregation_properties.rs). Random mixed batches of
    duplicate-route groups and singletons — including zero-byte flows,
    staggered readies, and inter-rack routes — replayed through
    transfer_batch so FIFO busy_until carry-over is exercised too. Also
    re-verifies the PR 8 stall fix through the mirror: both loops use
    the projection-retirement rule, and neither may trip the budget."""
    state = [0xA66_5EED]

    def nxt():
        # SplitMix64 (util/rng.rs) so trials are deterministic.
        state[0] = (state[0] + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        z = state[0]
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return z ^ (z >> 31)

    checked = 0
    collapsed = 0
    for fab in (ETH, OPA):
        net_a = NetSim(fab)
        net_a.aggregate = True
        net_b = NetSim(fab)
        for _ in range(30):
            reqs = []
            for _ in range(1 + nxt() % 6):
                src = nxt() % 48
                dst = nxt() % 48
                if dst == src:
                    dst = (dst + 1) % 48
                bytes_ = [0.0, 512.0, 1.5e6, 64.0 * 1024.0 * 1024.0][nxt() % 4]
                ready = float(nxt() % 4) * 75.0e-6
                for _ in range(1 + nxt() % 5):
                    reqs.append((src, dst, bytes_, ready))
            got = net_a.transfer_batch(reqs)
            want = net_b.transfer_batch(reqs)
            for i, ((a0, a1), (b0, b1)) in enumerate(zip(got, want)):
                assert fbits(a0) == fbits(b0), f"{fab.name} flow {i}: send {a0!r} != {b0!r}"
                assert fbits(a1) == fbits(b1), f"{fab.name} flow {i}: recv {a1!r} != {b1!r}"
            checked += 1
        assert net_a.budget_exceeded == 0 and net_b.budget_exceeded == 0, fab.name
        assert net_a.inter_rack_messages == net_b.inter_rack_messages, fab.name
        assert net_a.agg_collapsed > 0, f"{fab.name}: trials never collapsed a flow"
        collapsed += net_a.agg_collapsed
    print(f"flow-aggregation bit-identity: {checked} batches OK ({collapsed} flows collapsed)")


def verify_faults():
    """PR 9 pre-verification of the fault-capacity merge.

    Three claims, mirroring the guarantees tests/fault_properties.rs
    pins on the Rust engine:

    * neutrality — an attached timeline that never fires inside the
      batch (empty, or with its first change far beyond the last
      finish) reproduces the healthy fluid path byte-for-byte on a
      contended cross-rack batch, and forcing a lone uncontended flow
      onto the fluid path under such a timeline reproduces the
      closed-form finish to the bit: the merge plumbing (initial
      mult_at pricing, the next_fault cursor, the t_next clamp) is
      provably inert until a change lands;
    * analytic brownout — a single flow whose source NIC browns out to
      factor f at time tau mid-transfer finishes exactly at
      tau + (B - r*(tau - a)) / (r*f), where a is its arrival and r its
      healthy rate, compared bit-for-bit against the faulted loop;
    * monotone severity — deepening a mid-batch brownout on the shared
      rack uplink of a contended cross-rack batch never shrinks the
      batch makespan.
    """

    def cross_rack_batch():
        # 18 flows over 6 source NICs and the rack-0 up / rack-1 down
        # links: NIC- and uplink-contended, staggered readies, mixed
        # sizes — the shape the golden drivers exercise.
        sizes = [1.5e6, 64.0 * 1024.0 * 1024.0, 512.0]
        return [
            (i % 6, 32 + (i % 7), sizes[i % 3], float(i % 4) * 75.0e-6)
            for i in range(18)
        ]

    checked = 0
    for fab in (ETH, OPA):
        want = NetSim(fab).transfer_batch(cross_rack_batch())
        for steps in ({}, {0: [(1.0e9, 0.5)]}):
            sim = NetSim(fab)
            sim.set_fault_steps(steps)
            got = sim.transfer_batch(cross_rack_batch())
            for i, ((a0, a1), (b0, b1)) in enumerate(zip(got, want)):
                assert fbits(a0) == fbits(b0), f"{fab.name} flow {i}: send {a0!r} != {b0!r}"
                assert fbits(a1) == fbits(b1), f"{fab.name} flow {i}: recv {a1!r} != {b1!r}"
            checked += 1

        # A lone flow under an inert timeline is forced onto the fluid
        # path; its finish must still be the uncontended closed form.
        lone = [(0, 1, 4.0 * 1024.0 * 1024.0, 0.0)]
        sim = NetSim(fab)
        sim.set_fault_steps({0: [(1.0e9, 0.5)]})
        got = sim.transfer_batch(lone)
        want_lone = NetSim(fab).transfer_batch(lone)
        assert fbits(got[0][0]) == fbits(want_lone[0][0]), fab.name
        assert fbits(got[0][1]) == fbits(want_lone[0][1]), fab.name
        checked += 1

        # Analytic mid-flight brownout, same float ops as the loop:
        # one event at the healthy rate r until tau, then r*f to the
        # end (the faulted tx cap (nic*factor)*f binds below the
        # unfaulted flow cap).
        bytes_ = 64.0 * 1024.0 * 1024.0
        send_ov, latency, recv_ov, bw = NetSim(fab).network_cost(bytes_, False)
        factor = fab.congestion_factor(1.0)
        a = 0.0 + send_ov
        r = bw * factor
        f = 0.25
        tau = a + 0.4 * (bytes_ / r)
        sim = NetSim(fab)
        sim.set_fault_steps({0: [(tau, f)]})
        got = sim.transfer_batch([(0, 1, bytes_, 0.0)])[0]
        dt = max(tau - a, 0.0)
        rf = bw * factor * f
        want_fin = tau + (bytes_ - r * dt) / rf
        assert fbits(got[0]) == fbits(want_fin), (
            f"{fab.name}: brownout finish {got[0]!r} != closed form {want_fin!r}"
        )
        assert fbits(got[1]) == fbits(want_fin + latency + recv_ov), fab.name
        checked += 1

        # Monotone severity: browning out the rack-0 uplink mid-batch,
        # harder and harder, never shrinks the contended makespan.
        up0 = 2 * CLUSTER_NODES  # rack-0 up-link resource id
        healthy_make = max(rc for _, rc in want)
        last = healthy_make
        for mult in (0.6, 0.3, 0.1):
            sim = NetSim(fab)
            sim.set_fault_steps({up0: [(healthy_make * 0.25, mult)]})
            make = max(rc for _, rc in sim.transfer_batch(cross_rack_batch()))
            assert make >= last * (1.0 - 1e-12), (
                f"{fab.name}: uplink brownout {mult} shrank the makespan: "
                f"{make!r} < {last!r}"
            )
            last = make
        assert last > healthy_make * (1.0 + 1e-9), (
            f"{fab.name}: a 10x uplink brownout must stretch the batch"
        )
        checked += 1
    print(f"fault-merge verification: {checked} scenarios OK")


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    default_out = os.path.join(os.path.dirname(__file__), "..", "tests", "golden")
    ap.add_argument("--out-dir", default=default_out)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # Sanity pins from the Rust test suite (zoo.rs asserts these exactly).
    assert vgg16()[0].total_params() == 138_357_544
    assert alexnet()[0].total_params() == 61_100_840
    assert resnet50()[0].total_params() == 25_557_032
    inception_params = inception_v3()[0].total_params()
    assert abs(inception_params - 23.8e6) / 23.8e6 < 0.05, inception_params
    assert factor3(40) == (5, 4, 2)
    assert MeshPartition(PAPER_MESH, 64).elems_per_rank() == 512

    # PR 7 pre-verification: the workload-IR executor must reproduce the
    # pre-IR scheduler bit-for-bit before the fixtures are trusted.
    verify_dp_lowering()

    # PR 8 pre-verification: the weighted aggregated fluid path must
    # reproduce the expanded solve bit-for-bit, and the stall-fixed
    # retirement loop must finish every contended batch within budget.
    verify_aggregation()

    # PR 9 pre-verification: the fault-capacity merge must be provably
    # inert when no change lands in a batch (so the healthy goldens stay
    # byte-exact) and land a mid-flight brownout on its closed form.
    verify_faults()

    for name, csv in (("table1", table1_csv()), ("fig3_quick", fig3_quick_csv())):
        path = os.path.join(args.out_dir, f"{name}.csv")
        with open(path, "w") as fh:
            fh.write(csv)
        print(f"wrote {path} ({len(csv)} bytes)")


if __name__ == "__main__":
    main()
