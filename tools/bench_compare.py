#!/usr/bin/env python3
"""Diff two ``fabricbench-bench-v1`` JSON reports and fail on regression.

CI's perf-smoke job uploads a machine-readable bench artifact per
revision (see ``rust/src/util/benchjson.rs``: top-level ``schema`` key
plus ``bench -> workload -> {field: number}``). This tool compares the
current artifact against a committed baseline and exits non-zero when a
workload regresses past its threshold, turning the perf trajectory from
an "eyeball the artifact" convention into a gate.

Field policy (matched by suffix, most specific first):

* wall-clock fields (``wall_ms``, ``*_ms``, ``*_secs``) are noisy on
  shared CI runners: allowed to regress up to ``--time-tolerance-pct``
  (default 35%).
* everything else (event counts, solver iterations, flow counts, cache
  hits, ...) is deterministic for a fixed seed: allowed drift is
  ``--count-tolerance-pct`` (default 0% — an unexplained change in a
  deterministic counter IS the regression signal).

Fields where bigger is better (``cache_hits``, ``hit_rate``, ``img_s``,
``images_per_sec``) are compared in the improving direction. Workloads or
fields present on only one side are reported as warnings, not failures —
adding a bench must not require a lockstep baseline update, and a renamed
workload shows up loudly as one warning per side.

Usage:
    python3 tools/bench_compare.py BASELINE.json CURRENT.json \
        [--time-tolerance-pct 35] [--count-tolerance-pct 0]

Exit codes: 0 = within thresholds, 1 = regression, 2 = bad input.
"""

import argparse
import json
import sys

SCHEMA = "fabricbench-bench-v1"

# Fields where a larger value is an improvement, not a regression.
# agg_collapsed / collapse_pct: flows absorbed into an existing fluid
# aggregate — losing aggregation coverage is the regression direction.
HIGHER_IS_BETTER = {
    "cache_hits",
    "hit_rate",
    "img_s",
    "images_per_sec",
    "agg_collapsed",
    "collapse_pct",
}

TIME_SUFFIXES = ("_ms", "_secs", "_us", "_ns")


def load_report(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if doc.get("schema") != SCHEMA:
        print(f"error: {path}: schema {doc.get('schema')!r} != {SCHEMA!r}", file=sys.stderr)
        sys.exit(2)
    benches = {}
    for bench, workloads in doc.items():
        if bench == "schema" or not isinstance(workloads, dict):
            continue
        for workload, fields in workloads.items():
            if not isinstance(fields, dict):
                continue
            benches[(bench, workload)] = {
                k: float(v) for k, v in fields.items() if isinstance(v, (int, float))
            }
    return benches


def tolerance_pct(field, args):
    if field.endswith(TIME_SUFFIXES):
        return args.time_tolerance_pct
    return args.count_tolerance_pct


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--time-tolerance-pct", type=float, default=35.0)
    ap.add_argument("--count-tolerance-pct", type=float, default=0.0)
    args = ap.parse_args()

    base = load_report(args.baseline)
    cur = load_report(args.current)

    regressions, warnings, compared = [], [], 0
    for key in sorted(set(base) | set(cur)):
        bench, workload = key
        if key not in cur:
            warnings.append(f"workload {bench}/{workload} only in baseline")
            continue
        if key not in base:
            warnings.append(f"workload {bench}/{workload} only in current")
            continue
        for field in sorted(set(base[key]) | set(cur[key])):
            if field not in cur[key] or field not in base[key]:
                side = "baseline" if field in base[key] else "current"
                warnings.append(f"field {bench}/{workload}.{field} only in {side}")
                continue
            b, c = base[key][field], cur[key][field]
            compared += 1
            # Regressing direction: a drop in a higher-is-better field is
            # judged like a rise elsewhere, but the printed delta keeps
            # the raw sign.
            nb, nc = (-b, -c) if field in HIGHER_IS_BETTER else (b, c)
            if b == 0.0:
                worse = nc > nb
                delta = float("inf") if c != 0.0 else 0.0
            else:
                delta = (c - b) / abs(b) * 100.0
                worse = (nc - nb) / abs(nb if nb else 1.0) * 100.0 > tolerance_pct(field, args)
            line = f"{bench}/{workload}.{field}: {b:g} -> {c:g} ({delta:+.1f}%)"
            if worse:
                regressions.append(f"{line}  exceeds {tolerance_pct(field, args):g}%")
            else:
                print(f"ok   {line}")

    for w in warnings:
        print(f"warn {w}")
    if regressions:
        print(f"\n{len(regressions)} regression(s) vs {args.baseline}:")
        for r in regressions:
            print(f"FAIL {r}")
        return 1
    if compared == 0:
        print("warn nothing compared (disjoint reports?)")
    print(f"\n{compared} field(s) within thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
