#!/usr/bin/env python3
"""Diff two ``fabricbench-bench-v1`` JSON reports and fail on regression.

CI's perf-smoke job uploads a machine-readable bench artifact per
revision (see ``rust/src/util/benchjson.rs``: top-level ``schema`` key
plus ``bench -> workload -> {field: number}``). This tool compares the
current artifact against a committed baseline and exits non-zero when a
workload regresses past its threshold, turning the perf trajectory from
an "eyeball the artifact" convention into a gate.

Field policy (matched by suffix, most specific first):

* wall-clock fields (``wall_ms``, ``*_ms``, ``*_secs``) are noisy on
  shared CI runners: allowed to regress up to ``--time-tolerance-pct``
  (default 35%).
* everything else (event counts, solver iterations, flow counts, cache
  hits, ...) is deterministic for a fixed seed: allowed drift is
  ``--count-tolerance-pct`` (default 0% — an unexplained change in a
  deterministic counter IS the regression signal).

Fields where bigger is better (``cache_hits``, ``hit_rate``, ``img_s``,
``images_per_sec``) are compared in the improving direction. Workloads or
fields present on only one side are reported as warnings, not failures —
adding a bench must not require a lockstep baseline update, and a renamed
workload shows up loudly as one warning per side.

Usage:
    python3 tools/bench_compare.py BASELINE.json CURRENT.json \
        [--time-tolerance-pct 35] [--count-tolerance-pct 0]
    python3 tools/bench_compare.py --self-test

Exit codes: 0 = within thresholds, 1 = regression, 2 = bad input.
"""

import argparse
import json
import sys

SCHEMA = "fabricbench-bench-v1"

# Fields where a larger value is an improvement, not a regression.
# agg_collapsed / collapse_pct: flows absorbed into an existing fluid
# aggregate — losing aggregation coverage is the regression direction.
HIGHER_IS_BETTER = {
    "cache_hits",
    "hit_rate",
    "img_s",
    "images_per_sec",
    "agg_collapsed",
    "collapse_pct",
}

TIME_SUFFIXES = ("_ms", "_secs", "_us", "_ns")


def load_report(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if doc.get("schema") != SCHEMA:
        print(f"error: {path}: schema {doc.get('schema')!r} != {SCHEMA!r}", file=sys.stderr)
        sys.exit(2)
    return flatten_report(doc)


def flatten_report(doc):
    benches = {}
    for bench, workloads in doc.items():
        if bench == "schema" or not isinstance(workloads, dict):
            continue
        for workload, fields in workloads.items():
            if not isinstance(fields, dict):
                continue
            benches[(bench, workload)] = {
                k: float(v) for k, v in fields.items() if isinstance(v, (int, float))
            }
    return benches


def tolerance_pct(field, args):
    if field.endswith(TIME_SUFFIXES):
        return args.time_tolerance_pct
    return args.count_tolerance_pct


def compare(base, cur, args, emit=print):
    """Walk both flattened reports; returns (regressions, warnings, compared)."""
    regressions, warnings, compared = [], [], 0
    for key in sorted(set(base) | set(cur)):
        bench, workload = key
        if key not in cur:
            warnings.append(f"workload {bench}/{workload} only in baseline")
            continue
        if key not in base:
            warnings.append(f"workload {bench}/{workload} only in current")
            continue
        for field in sorted(set(base[key]) | set(cur[key])):
            if field not in cur[key] or field not in base[key]:
                side = "baseline" if field in base[key] else "current"
                warnings.append(f"field {bench}/{workload}.{field} only in {side}")
                continue
            b, c = base[key][field], cur[key][field]
            compared += 1
            # Regressing direction: a drop in a higher-is-better field is
            # judged like a rise elsewhere, but the printed delta keeps
            # the raw sign.
            nb, nc = (-b, -c) if field in HIGHER_IS_BETTER else (b, c)
            if b == 0.0:
                worse = nc > nb
                delta = float("inf") if c != 0.0 else 0.0
            else:
                delta = (c - b) / abs(b) * 100.0
                worse = (nc - nb) / abs(nb if nb else 1.0) * 100.0 > tolerance_pct(field, args)
            line = f"{bench}/{workload}.{field}: {b:g} -> {c:g} ({delta:+.1f}%)"
            if worse:
                regressions.append(f"{line}  exceeds {tolerance_pct(field, args):g}%")
            else:
                emit(f"ok   {line}")
    return regressions, warnings, compared


def self_test():
    """In-process check of the comparison semantics — no fixture files.

    Covers the orphan-key warning surface (workload on one side only,
    field on one side only) plus the gate directions: a counter drift at
    0% tolerance regresses, a wall-clock drift inside the window does
    not, and higher-is-better fields regress downward.
    """
    args = argparse.Namespace(time_tolerance_pct=35.0, count_tolerance_pct=0.0)
    base = flatten_report({
        "schema": SCHEMA,
        "engine": {
            "steady": {"wall_ms": 100.0, "events": 500, "cache_hits": 40},
            "removed": {"wall_ms": 1.0},
            "renamed_old": {"wall_ms": 1.0},
        },
    })
    cur = flatten_report({
        "schema": SCHEMA,
        "engine": {
            # wall_ms +20% is inside the 35% window; events drifting at
            # 0% tolerance and cache_hits dropping both regress; the
            # extra field is a warning.
            "steady": {"wall_ms": 120.0, "events": 501, "cache_hits": 39, "new_field": 1},
            "added": {"wall_ms": 2.0},
            "renamed_new": {"wall_ms": 1.0},
        },
    })
    regressions, warnings, compared = compare(base, cur, args, emit=lambda _line: None)

    def expect(cond, msg):
        if not cond:
            print(f"self-test FAIL: {msg}", file=sys.stderr)
            print(f"  regressions: {regressions}", file=sys.stderr)
            print(f"  warnings:    {warnings}", file=sys.stderr)
            sys.exit(1)

    expect(compared == 3, f"compared {compared} fields, want 3 (wall_ms/events/cache_hits)")
    expect(
        any("only in baseline" in w and "removed" in w for w in warnings),
        "baseline-only workload must warn",
    )
    expect(
        any("only in current" in w and "added" in w for w in warnings),
        "current-only workload must warn",
    )
    expect(
        any("renamed_old" in w for w in warnings) and any("renamed_new" in w for w in warnings),
        "a renamed workload must warn once per side",
    )
    expect(
        any("new_field" in w and "only in current" in w for w in warnings),
        "current-only field must warn",
    )
    expect(len(warnings) == 5, f"{len(warnings)} warnings, want exactly 5: {warnings}")
    expect(
        any("events" in r for r in regressions),
        "a deterministic counter drift at 0% tolerance must regress",
    )
    expect(
        any("cache_hits" in r for r in regressions),
        "a higher-is-better field dropping must regress",
    )
    expect(
        not any("wall_ms" in r for r in regressions),
        "+20% wall_ms is inside the 35% window",
    )
    expect(len(regressions) == 2, f"{len(regressions)} regressions, want exactly 2")

    # Identical reports: clean pass, no warnings.
    regressions, warnings, compared = compare(base, base, args, emit=lambda _line: None)
    expect(not regressions and not warnings, "identical reports must be clean")
    expect(compared == 5, f"identical reports compare all 5 fields, got {compared}")
    print("self-test: ok (orphan warnings, gate directions, clean identity)")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", nargs="?")
    ap.add_argument("current", nargs="?")
    ap.add_argument("--time-tolerance-pct", type=float, default=35.0)
    ap.add_argument("--count-tolerance-pct", type=float, default=0.0)
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="run the built-in comparison-semantics check and exit",
    )
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    if not args.baseline or not args.current:
        ap.error("baseline and current are required (or use --self-test)")

    base = load_report(args.baseline)
    cur = load_report(args.current)

    regressions, warnings, compared = compare(base, cur, args)

    for w in warnings:
        print(f"warn {w}")
    if regressions:
        print(f"\n{len(regressions)} regression(s) vs {args.baseline}:")
        for r in regressions:
            print(f"FAIL {r}")
        return 1
    if compared == 0:
        print("warn nothing compared (disjoint reports?)")
    print(f"\n{compared} field(s) within thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
