//! API-compatible stub of the `xla` crate (xla_extension 0.5.1 PJRT
//! bindings) for offline builds where the native XLA library is absent.
//!
//! Everything type-checks against the surface `fabricbench::runtime`
//! uses; the only runtime behavior is a clean error from
//! [`PjRtClient::cpu`], so `Engine::load` fails with an informative
//! message and every simulation path (which never touches PJRT) works
//! normally. Swap this path dependency for the real crate to run the
//! AOT-compiled artifacts.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversion
/// into `anyhow::Error`.
#[derive(Debug)]
pub struct Error {
    pub msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error {
        msg: "XLA/PJRT backend unavailable in this offline build (xla stub crate); \
              simulation paths are unaffected"
            .to_string(),
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    Pred,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    F64,
    S32,
    Pred,
}

/// Host-side tensor value (stub: carries nothing).
#[derive(Debug, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal::default()
    }

    pub fn scalar<T: Copy>(_v: T) -> Literal {
        Literal::default()
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(unavailable())
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Loaded executable handle (stub; cannot be constructed at runtime).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client (stub: creation always fails cleanly).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline"));
    }
}
