//! Minimal, source-compatible subset of the `anyhow` crate for fully
//! offline builds. Implements the surface fabricbench uses:
//!
//! * [`Error`] — a boxed dynamic error with a context chain
//! * [`Result<T>`] — alias with `Error` as the default error type
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`
//!
//! Display mirrors real anyhow: `{}` shows the outermost message, `{:#}`
//! shows the whole chain joined by `": "`, `{:?}` shows the chain on
//! separate lines (the "Caused by" report form, simplified).

use std::error::Error as StdError;
use std::fmt;

/// Dynamic error type: a chain of messages, outermost first.
pub struct Error {
    /// msgs[0] is the outermost context; the root cause is last.
    chain: Vec<String>,
    /// The original typed error, if this was built from one.
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], source: None }
    }

    /// Build from a typed error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { chain: vec![error.to_string()], source: Some(Box::new(error)) }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// Attempt to downcast the original typed error.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.source.as_deref().and_then(|e| e.downcast_ref::<E>())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: Error does NOT implement std::error::Error (that
// would conflict with the blanket From below).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn macros_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        let e = e.context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: boom 42");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn ensure_and_question_mark() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            let s = "7";
            let v: i32 = s.parse()?; // ParseIntError -> Error via From
            Ok(v + x)
        }
        assert_eq!(f(1).unwrap(), 8);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
    }

    #[test]
    fn option_context() {
        let none: Option<i32> = None;
        let err = none.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
    }
}
