//! Deterministic pseudo-random number generation: SplitMix64 (seeding) and
//! xoshiro256++ (bulk generation), plus the distributions the simulators
//! need. No external crates; all reproducible across platforms.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 cannot produce 4 zeros from
        // any seed, but keep the guard for clarity.
        if s.iter().all(|&x| x == 0) {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free enough for
    /// simulation purposes; modulo bias is negligible for n << 2^64).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// deterministic).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with mean/std.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal such that the *median* is `median` and sigma is the shape
    /// parameter — used for compute-time jitter (always positive,
    /// right-skewed, like real step-time distributions).
    pub fn lognormal_median(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.uniform();
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A fresh generator derived from this one (stream splitting).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.uniform()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_median_is_median() {
        let mut r = Rng::new(5);
        let n = 100_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal_median(3.0, 0.5)).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let med = xs[n / 2];
        assert!((med - 3.0).abs() < 0.1, "median={med}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_decorrelated() {
        let mut root = Rng::new(21);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
