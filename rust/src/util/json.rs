//! Minimal JSON parser + emitter (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by artifact manifests and result
//! records: objects, arrays, strings (with escapes), numbers, booleans,
//! null. Numbers are stored as `f64` (adequate: manifests carry shapes and
//! counts well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        // Only exact non-negative integers below 2^53 map onto usize;
        // negative, fractional, NaN, and infinite values are None rather
        // than whatever an `as`-cast would truncate/saturate them to.
        match self.as_f64() {
            Some(x) if x >= 0.0 && x.fract() == 0.0 && x < 9_007_199_254_740_992.0 => {
                Some(x as usize)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs: a high surrogate must be
                        // immediately followed by a low-surrogate escape,
                        // validated *before* the combining arithmetic (the
                        // old unchecked `lo - 0xDC00` underflowed on bad
                        // input). Lone surrogates are loud errors.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if (self.bump(), self.bump()) != (Some(b'\\'), Some(b'u')) {
                                return Err(self.err(
                                    "lone high surrogate \\u escape (expected \
                                     a \\uDC00-\\uDFFF low surrogate to follow)",
                                ));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err(
                                    "invalid surrogate pair: second \\u escape \
                                     is not a low surrogate (\\uDC00-\\uDFFF)",
                                ));
                            }
                            char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err(
                                "lone low surrogate \\u escape (no preceding \
                                 high surrogate)",
                            ));
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(ch.ok_or_else(|| self.err("bad \\u escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multibyte UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for `Json::Obj`.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(xs: I) -> Json {
    Json::Arr(xs.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("  -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""line\nquote\" uA pair😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "line\nquote\" uA pair😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let j = Json::parse(r#""héllo wörld 日本""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo wörld 日本");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"x"],"nested":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn surrogate_pair_escapes_decode_to_one_char() {
        let j = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "😀");
        assert_eq!(j.as_str().unwrap().chars().count(), 1);
        // Uppercase hex, mid-string.
        let j = Json::parse(r#""a\uD83D\uDE00b""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a😀b");
    }

    #[test]
    fn lone_and_invalid_surrogates_are_loud_errors() {
        for src in [
            r#""\ud83d""#,       // lone high at end of string
            r#""\ud83d rest""#,  // high followed by plain text
            r#""\ud83d\n""#,     // high followed by a non-\u escape
            r#""\ud83d\u0041""#, // high followed by a non-low \u escape
            r#""\ud83d\ud83d""#, // high followed by another high
            r#""\ude00""#,       // lone low
        ] {
            let e = Json::parse(src).unwrap_err();
            assert!(e.msg.contains("surrogate"), "{src}: {}", e.msg);
        }
    }

    #[test]
    fn utf16_escape_encodings_roundtrip() {
        // Any char written as \uXXXX escapes (a pair for astral planes)
        // must decode back to itself.
        for c in ['A', 'é', '日', '\u{FFFD}', '😀', '\u{10FFFF}'] {
            let mut buf = [0u16; 2];
            let mut src = String::from('"');
            for u in c.encode_utf16(&mut buf).iter() {
                src.push_str(&format!("\\u{u:04x}"));
            }
            src.push('"');
            let j = Json::parse(&src).unwrap();
            assert_eq!(j.as_str().unwrap().chars().collect::<Vec<_>>(), vec![c], "{src}");
        }
    }

    #[test]
    fn escape_roundtrips_seeded_random_strings() {
        // Seeded LCG property test: emit → parse is the identity for
        // strings mixing ASCII, control chars, BMP, and astral chars.
        let mut state = 0x9e37_79b9_7f4a_7c15u64;
        let mut next = move || {
            state = state
                .wrapping_mul(6_364_136_223_846_793_005)
                .wrapping_add(1_442_695_040_888_963_407);
            state >> 33
        };
        for _ in 0..200 {
            let len = (next() % 24) as usize;
            let s: String = (0..len)
                .map(|_| match next() % 4 {
                    0 => char::from_u32((next() % 0x80) as u32).unwrap(),
                    1 => char::from_u32(0x20 + (next() % 0x60) as u32).unwrap(),
                    2 => char::from_u32(0x4e00 + (next() % 0x100) as u32).unwrap(),
                    _ => char::from_u32(0x1f600 + (next() % 0x50) as u32).unwrap(),
                })
                .collect();
            let emitted = Json::Str(s.clone()).to_string();
            let parsed = Json::parse(&emitted).unwrap();
            assert_eq!(parsed.as_str().unwrap(), s, "via {emitted}");
        }
    }

    #[test]
    fn as_usize_rejects_non_integer_and_negative_numbers() {
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(-0.5).as_usize(), None);
        assert_eq!(Json::Num(2.5).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(f64::NEG_INFINITY).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_usize(), None);
        // Exact non-negative integers still convert (−0.0 is 0).
        assert_eq!(Json::Num(-0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(32.0).as_usize(), Some(32));
        assert_eq!(
            Json::Num(9_007_199_254_740_991.0).as_usize(),
            Some(9_007_199_254_740_991)
        );
        assert_eq!(Json::Str("32".into()).as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{
          "batch": 32,
          "params": [{"name": "conv1_w", "shape": [3, 3, 3, 8]}],
          "artifacts": {"train_step": {"file": "train_step.hlo.txt"}}
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(32));
        let p0 = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("name").unwrap().as_str(), Some("conv1_w"));
        let shape: Vec<usize> = p0
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![3, 3, 3, 8]);
    }
}
