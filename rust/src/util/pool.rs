//! Work-stealing map over scoped threads — the one parallel primitive
//! in the codebase. Extracted from the sweep [`Runner`]
//! (`experiments::sweeps`) so the fabric engine's intra-batch group
//! solves can ride the same machinery.
//!
//! Workers pull indices off a shared atomic cursor (work stealing: a
//! slow item never convoys the rest of the list behind one thread) and
//! send `(index, result)` pairs back over a channel; the caller
//! reassembles results **in item order**, so output is independent of
//! scheduling, worker count, and completion order. The sequential path
//! (`jobs <= 1` or a single item) is the same closure applied in a plain
//! loop — which is what makes parallel/sequential equivalence trivial to
//! reason about for the callers that pin bit-identical output across
//! `--jobs` / solver-thread counts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Map `f` over `0..n` on up to `jobs` threads; results in index order.
pub fn map_steal<O, F>(jobs: usize, n: usize, f: F) -> Vec<O>
where
    O: Send,
    F: Fn(usize) -> O + Sync,
{
    let jobs = jobs.max(1).min(n.max(1));
    if jobs <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, O)>();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);
    collect_slots(rx, n)
}

/// Like [`map_steal`], but each worker owns one reusable state from
/// `states` (e.g. a solver arena) passed to every `f` call it steals —
/// no per-item allocation, no sharing. Worker count is
/// `min(jobs, states.len(), n)`; with one worker (or one item) the
/// sequential path runs everything on `states[0]`.
pub fn map_steal_with<S, O, F>(jobs: usize, states: &mut [S], n: usize, f: F) -> Vec<O>
where
    S: Send,
    O: Send,
    F: Fn(&mut S, usize) -> O + Sync,
{
    assert!(!states.is_empty(), "map_steal_with needs at least one worker state");
    let jobs = jobs.max(1).min(states.len()).min(n.max(1));
    if jobs <= 1 {
        let s0 = &mut states[0];
        return (0..n).map(|i| f(s0, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, O)>();
    std::thread::scope(|scope| {
        for state in states.iter_mut().take(jobs) {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(state, i);
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);
    collect_slots(rx, n)
}

/// Run `n` copies of a worker loop to completion on scoped threads —
/// the service's accept pool: unlike [`map_steal`] there is no item
/// list, just long-lived workers sharing whatever `f` closes over (a
/// non-blocking listener, a shutdown flag). `n <= 1` runs `f(0)` on the
/// calling thread, same equivalence story as the map paths.
pub fn run_workers<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let n = n.max(1);
    if n == 1 {
        f(0);
        return;
    }
    std::thread::scope(|scope| {
        for i in 0..n {
            let f = &f;
            scope.spawn(move || f(i));
        }
    });
}

fn collect_slots<O>(rx: mpsc::Receiver<(usize, O)>, n: usize) -> Vec<O> {
    let mut slots: Vec<Option<O>> = (0..n).map(|_| None).collect();
    for (i, o) in rx {
        slots[i] = Some(o);
    }
    slots.into_iter().map(|o| o.expect("pool worker dropped an item")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_steal_preserves_order() {
        let seq = map_steal(1, 97, |i| i * i);
        let par = map_steal(4, 97, |i| i * i);
        assert_eq!(seq, par);
        assert_eq!(seq[10], 100);
    }

    #[test]
    fn map_steal_handles_empty_and_single() {
        assert!(map_steal(4, 0, |i| i).is_empty());
        assert_eq!(map_steal(4, 1, |i| i + 7), vec![7]);
    }

    #[test]
    fn map_steal_with_uses_worker_states() {
        // Each worker counts its own calls; the counts must sum to n and
        // the output must be order-exact regardless of who did what.
        let mut states = vec![0usize; 3];
        let out = map_steal_with(3, &mut states, 50, |calls, i| {
            *calls += 1;
            i * 2
        });
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(states.iter().sum::<usize>(), 50);
    }

    #[test]
    fn run_workers_runs_each_index_once() {
        use std::sync::atomic::AtomicUsize;
        let ran: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        run_workers(4, |i| {
            ran[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(ran.iter().all(|c| c.load(Ordering::SeqCst) == 1));
        // Sequential path: n=1 runs inline.
        let solo = AtomicUsize::new(0);
        run_workers(1, |i| {
            assert_eq!(i, 0);
            solo.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(solo.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn map_steal_with_sequential_path_uses_first_state() {
        let mut states = vec![0usize; 4];
        let out = map_steal_with(1, &mut states, 5, |calls, i| {
            *calls += 1;
            i
        });
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(states[0], 5);
        assert!(states[1..].iter().all(|&c| c == 0));
    }
}
