//! Miniature property-testing harness (the `proptest` crate is not
//! available in this offline environment). Provides seeded generators and
//! a `forall` runner with failure reporting including the case seed, so a
//! failing case can be replayed deterministically.

use crate::util::rng::Rng;

/// Number of cases per property (kept modest; properties here are cheap).
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` over `cases` generated inputs. On failure, panics with the
/// case index and derived seed for replay.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{cases} (seed {case_seed:#x}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Generate a vector of f32 in [-bound, bound] of length in [1, max_len].
pub fn vec_f32(rng: &mut Rng, max_len: usize, bound: f32) -> Vec<f32> {
    let len = 1 + rng.below(max_len as u64) as usize;
    (0..len)
        .map(|_| rng.uniform_in(-bound as f64, bound as f64) as f32)
        .collect()
}

/// Generate an integer in [lo, hi] inclusive.
pub fn int_in(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
    lo + rng.below(hi - lo + 1)
}

/// Generate exactly `len` *integer-valued* f32 in [-bound, bound].
///
/// Sums of a few thousand such values stay exactly representable in f32,
/// so every reduction order produces bit-identical results — this is the
/// generator behind the bit-for-bit collective correctness suite (a
/// tolerance-free oracle that float reassociation cannot weaken).
pub fn vec_f32_int(rng: &mut Rng, len: usize, bound: u32) -> Vec<f32> {
    (0..len)
        .map(|_| rng.below(2 * bound as u64 + 1) as f32 - bound as f32)
        .collect()
}

/// Generate a power of two in [1, max_pow2_exp].
pub fn pow2(rng: &mut Rng, max_exp: u32) -> u64 {
    1u64 << rng.below(max_exp as u64 + 1)
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(1, 64, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 64, |r| r.below(10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err(format!("{x} >= 5"))
            }
        });
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let v = vec_f32(&mut rng, 16, 2.0);
            assert!(!v.is_empty() && v.len() <= 16);
            assert!(v.iter().all(|x| x.abs() <= 2.0));
            let k = int_in(&mut rng, 5, 9);
            assert!((5..=9).contains(&k));
            let p = pow2(&mut rng, 6);
            assert!(p.is_power_of_two() && p <= 64);
        }
    }

    #[test]
    fn int_valued_floats_are_integers_in_range() {
        let mut rng = Rng::new(17);
        let v = vec_f32_int(&mut rng, 10_000, 8);
        assert_eq!(v.len(), 10_000);
        assert!(v.iter().all(|x| x.fract() == 0.0 && x.abs() <= 8.0));
        // Both signs appear.
        assert!(v.iter().any(|&x| x > 0.0) && v.iter().any(|&x| x < 0.0));
    }

    #[test]
    fn assert_close_detects_mismatch() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6, 1e-6).is_err());
    }
}
