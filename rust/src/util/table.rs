//! Table rendering (aligned ASCII / GitHub markdown) and CSV emission —
//! every experiment driver prints its paper-figure rows through this.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A simple column-typed table: header + rows of strings.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// GitHub-flavored markdown rendering.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                let pad = width - c.chars().count();
                let _ = write!(line, " {}{} |", c, " ".repeat(pad));
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &w));
        let mut sep = String::from("|");
        for width in &w {
            let _ = write!(sep, "{}|", "-".repeat(width + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &w));
        }
        out
    }

    /// RFC-4180-ish CSV (quotes cells containing commas/quotes/newlines).
    pub fn to_csv(&self) -> String {
        fn cell(c: &str) -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        }
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| cell(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| cell(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write the CSV into `results/<name>.csv` (creating the directory).
    pub fn save_csv(&self, dir: &Path, name: &str) -> io::Result<std::path::PathBuf> {
        fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a float with engineering-friendly precision.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 10.0 {
        format!("{x:.1}")
    } else if a >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["model", "img/s"]);
        t.row(vec!["ResNet50".into(), "360.1".into()]);
        t.row(vec!["VGG16".into(), "230".into()]);
        t
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| model    | img/s |"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn save_csv_writes_file() {
        let dir = std::env::temp_dir().join("fabricbench_table_test");
        let path = sample().save_csv(&dir, "demo").unwrap();
        let content = std::fs::read_to_string(path).unwrap();
        assert!(content.starts_with("model,img/s"));
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.6), "1235");
        assert_eq!(fnum(42.25), "42.2");
        assert_eq!(fnum(1.5), "1.500");
        assert_eq!(fnum(0.0001234), "1.234e-4");
    }
}
