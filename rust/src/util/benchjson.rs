//! Machine-readable bench reporting.
//!
//! Every `cargo bench` target (all `harness = false` mains) accepts
//!
//! ```text
//! --quick               CI-sized workloads
//! --bench-json <path>   append this bench's workloads to a JSON report
//! ```
//!
//! and records `workload -> {field: number}` entries. Several targets
//! can share one report file (each merges under its own top-level key),
//! which is how CI builds the `BENCH_PR4.json` perf-trajectory artifact:
//! run the same bench driver on two revisions and diff the numbers.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::PathBuf;

pub struct BenchReport {
    bench: String,
    entries: Vec<(String, Vec<(String, f64)>)>,
    path: Option<PathBuf>,
}

impl BenchReport {
    /// Parse the bench CLI; returns `(quick, report)`.
    pub fn from_env(bench: &str) -> (bool, BenchReport) {
        let mut quick = false;
        let mut path = None;
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            match a.as_str() {
                "--quick" => quick = true,
                "--bench-json" => path = args.next().map(PathBuf::from),
                _ => {}
            }
        }
        (quick, BenchReport { bench: bench.to_string(), entries: Vec::new(), path })
    }

    /// Record one workload's measurements (e.g. `wall_ms`, `events`).
    pub fn entry(&mut self, workload: &str, fields: &[(&str, f64)]) {
        self.entries.push((
            workload.to_string(),
            fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        ));
    }

    /// Write the report if `--bench-json` was given; merges into an
    /// existing file so several bench targets can share one artifact.
    pub fn finish(self) {
        let Some(path) = self.path else { return };
        let mut root: BTreeMap<String, Json> = std::fs::read_to_string(&path)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|j| j.as_obj().cloned())
            .unwrap_or_default();
        root.insert("schema".to_string(), json::s("fabricbench-bench-v1"));
        let workloads: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(w, fields)| {
                let obj: BTreeMap<String, Json> =
                    fields.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
                (w.clone(), Json::Obj(obj))
            })
            .collect();
        root.insert(self.bench.clone(), Json::Obj(workloads));
        if std::fs::write(&path, Json::Obj(root).to_string()).is_ok() {
            println!("bench report appended to {}", path.display());
        } else {
            eprintln!("warning: could not write bench report {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_merges_benches_into_one_file() {
        let path = std::env::temp_dir().join("fb_benchjson_test.json");
        let _ = std::fs::remove_file(&path);
        let mut a = BenchReport {
            bench: "engine".into(),
            entries: Vec::new(),
            path: Some(path.clone()),
        };
        a.entry("contended_64", &[("wall_ms", 1.5), ("events", 64.0)]);
        a.finish();
        let mut b = BenchReport {
            bench: "fig4".into(),
            entries: Vec::new(),
            path: Some(path.clone()),
        };
        b.entry("full", &[("wall_ms", 10.0)]);
        b.finish();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "fabricbench-bench-v1");
        let engine = j.get("engine").unwrap().get("contended_64").unwrap();
        assert_eq!(engine.get("events").unwrap().as_f64(), Some(64.0));
        let fig4 = j.get("fig4").unwrap().get("full").unwrap();
        assert_eq!(fig4.get("wall_ms").unwrap().as_f64(), Some(10.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn from_env_without_flags_is_inert() {
        // Under `cargo test` argv carries no bench flags: no path, and
        // finish() must be a no-op.
        let (_, rep) = BenchReport::from_env("x");
        rep.finish();
    }
}
