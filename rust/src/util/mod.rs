//! Shared substrates built from scratch (the execution environment is
//! fully offline: `anyhow` is vendored and `xla` is stubbed, nothing else
//! is available): deterministic PRNG, statistics, JSON, tables/CSV, unit
//! formatting, and a miniature property-testing harness.

pub mod benchjson;
pub mod hash;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
