//! The canonical FNV-1a implementation (64-bit, platform-stable).
//!
//! Three subsystems key on these hashes — the sweep runner's cell
//! artifacts (`experiments::sweeps`), the schedule cache
//! (`trainer::scheduler`) and collective schedule signatures
//! (`collectives`) — so there is exactly one implementation to keep
//! their keys stable.

pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Fold one 64-bit word into the running hash.
#[inline]
pub fn fnv1a_u64(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(FNV_PRIME)
}

/// Fold a byte string into the running hash (byte-at-a-time FNV-1a).
#[inline]
pub fn fnv1a_bytes(h: u64, s: &[u8]) -> u64 {
    s.iter().fold(h, |h, &b| (h ^ b as u64).wrapping_mul(FNV_PRIME))
}

/// Hash a string from the standard offset basis.
#[inline]
pub fn fnv1a_str(s: &str) -> u64 {
    fnv1a_bytes(FNV_OFFSET, s.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        assert_eq!(fnv1a_str(""), FNV_OFFSET);
        // Classic FNV-1a test vector: "a" -> 0xaf63dc4c8601ec8c.
        assert_eq!(fnv1a_str("a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a_str("fig5:a"), fnv1a_str("fig5:b"));
        assert_eq!(fnv1a_u64(FNV_OFFSET, 7), fnv1a_u64(FNV_OFFSET, 7));
        assert_ne!(fnv1a_u64(FNV_OFFSET, 7), fnv1a_u64(FNV_OFFSET, 8));
    }
}
