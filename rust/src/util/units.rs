//! Units: bytes, bandwidths, durations — parsing (for configs) and
//! humanized formatting (for reports). All internal math is SI: bytes,
//! bytes/second, seconds.

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Gigabits/second -> bytes/second.
pub fn gbps_to_bytes_per_sec(gbps: f64) -> f64 {
    gbps * 1e9 / 8.0
}

/// Bytes/second -> gigabits/second.
pub fn bytes_per_sec_to_gbps(bps: f64) -> f64 {
    bps * 8.0 / 1e9
}

/// Microseconds -> seconds.
pub fn us(x: f64) -> f64 {
    x * 1e-6
}

/// Parse "64MiB", "25Gbps", "1.5us", "12GB/s", plain numbers, etc.
/// Returns the value in base units (bytes, bytes/s, or seconds) along with
/// the detected dimension.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Quantity {
    Bytes(f64),
    BytesPerSec(f64),
    Seconds(f64),
    Scalar(f64),
}

pub fn parse_quantity(input: &str) -> Result<Quantity, String> {
    let s = input.trim();
    let split = s
        .find(|c: char| {
            !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+' || c == 'e' || c == 'E')
        })
        .unwrap_or(s.len());
    // Guard against "1e5" being split at 'e' when no unit follows a digit.
    let (num_str, unit) = {
        let (n, u) = s.split_at(split);
        (n.trim(), u.trim())
    };
    let value: f64 = num_str
        .parse()
        .map_err(|_| format!("bad number in quantity '{input}'"))?;
    let q = match unit {
        "" => Quantity::Scalar(value),
        "B" => Quantity::Bytes(value),
        "KiB" => Quantity::Bytes(value * KIB),
        "MiB" => Quantity::Bytes(value * MIB),
        "GiB" => Quantity::Bytes(value * GIB),
        "KB" => Quantity::Bytes(value * 1e3),
        "MB" => Quantity::Bytes(value * 1e6),
        "GB" => Quantity::Bytes(value * 1e9),
        "Gbps" | "Gb/s" => Quantity::BytesPerSec(gbps_to_bytes_per_sec(value)),
        "Mbps" | "Mb/s" => Quantity::BytesPerSec(value * 1e6 / 8.0),
        "GB/s" => Quantity::BytesPerSec(value * 1e9),
        "MB/s" => Quantity::BytesPerSec(value * 1e6),
        "ns" => Quantity::Seconds(value * 1e-9),
        "us" | "µs" => Quantity::Seconds(value * 1e-6),
        "ms" => Quantity::Seconds(value * 1e-3),
        "s" => Quantity::Seconds(value),
        _ => return Err(format!("unknown unit '{unit}' in '{input}'")),
    };
    Ok(q)
}

/// Humanize a byte count.
pub fn fmt_bytes(b: f64) -> String {
    let a = b.abs();
    if a >= GIB {
        format!("{:.2} GiB", b / GIB)
    } else if a >= MIB {
        format!("{:.2} MiB", b / MIB)
    } else if a >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{b:.0} B")
    }
}

/// Humanize a duration in seconds.
pub fn fmt_time(t: f64) -> String {
    let a = t.abs();
    if a >= 3600.0 {
        format!("{:.2} h", t / 3600.0)
    } else if a >= 60.0 {
        format!("{:.2} min", t / 60.0)
    } else if a >= 1.0 {
        format!("{t:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", t * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} us", t * 1e6)
    } else {
        format!("{:.1} ns", t * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_conversions() {
        assert!((gbps_to_bytes_per_sec(25.0) - 3.125e9).abs() < 1.0);
        assert!((bytes_per_sec_to_gbps(12.5e9) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn parse_byte_units() {
        assert_eq!(parse_quantity("64MiB").unwrap(), Quantity::Bytes(64.0 * MIB));
        assert_eq!(parse_quantity("2KB").unwrap(), Quantity::Bytes(2000.0));
        assert_eq!(parse_quantity("3 GiB").unwrap(), Quantity::Bytes(3.0 * GIB));
    }

    #[test]
    fn parse_bandwidth_units() {
        match parse_quantity("25Gbps").unwrap() {
            Quantity::BytesPerSec(b) => assert!((b - 3.125e9).abs() < 1.0),
            q => panic!("wrong dimension {q:?}"),
        }
        match parse_quantity("12.8GB/s").unwrap() {
            Quantity::BytesPerSec(b) => assert!((b - 12.8e9).abs() < 1.0),
            q => panic!("wrong dimension {q:?}"),
        }
    }

    #[test]
    fn parse_time_units() {
        assert_eq!(parse_quantity("1.5us").unwrap(), Quantity::Seconds(1.5e-6));
        assert_eq!(parse_quantity("3ms").unwrap(), Quantity::Seconds(3e-3));
    }

    #[test]
    fn parse_scalar_and_errors() {
        assert_eq!(parse_quantity("42").unwrap(), Quantity::Scalar(42.0));
        assert!(parse_quantity("12 parsecs").is_err());
        assert!(parse_quantity("abc").is_err());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(64.0 * MIB), "64.00 MiB");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(90.0), "1.50 min");
        assert_eq!(fmt_time(1.25e-6), "1.250 us");
    }
}
