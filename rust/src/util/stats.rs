//! Statistics: summary statistics, percentiles, linear regression, and
//! Welch's t-test (used to reproduce the paper's §IV.B claim that PCIe
//! affinity produced "no statistically significant difference").

/// Summary of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Sample mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (n-1 denominator). 0.0 if n < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

pub fn summarize(xs: &[f64]) -> Summary {
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: stddev(xs),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Percentile with linear interpolation (p in [0, 100]).
///
/// NaN inputs sort to the high end (`total_cmp` order) instead of
/// panicking — a poisoned sample degrades to a NaN percentile rather
/// than aborting a whole sweep.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Ordinary least squares y = a + b x. Returns (intercept, slope, r2).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linreg needs >= 2 points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (intercept, slope, r2)
}

/// Result of Welch's unequal-variances t-test.
#[derive(Clone, Debug)]
pub struct WelchResult {
    pub t: f64,
    pub df: f64,
    pub p_two_sided: f64,
    /// true when p < alpha
    pub significant_at_05: bool,
}

/// Welch's t-test for two independent samples.
pub fn welch_t_test(a: &[f64], b: &[f64]) -> WelchResult {
    assert!(a.len() >= 2 && b.len() >= 2, "welch needs n >= 2 per group");
    let (ma, mb) = (mean(a), mean(b));
    let (va, vb) = (variance(a), variance(b));
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let se2 = va / na + vb / nb;
    let t = if se2 == 0.0 { 0.0 } else { (ma - mb) / se2.sqrt() };
    // Welch–Satterthwaite degrees of freedom.
    let df = if se2 == 0.0 {
        na + nb - 2.0
    } else {
        se2 * se2
            / ((va / na) * (va / na) / (na - 1.0) + (vb / nb) * (vb / nb) / (nb - 1.0))
    };
    let p = 2.0 * (1.0 - student_t_cdf(t.abs(), df));
    WelchResult {
        t,
        df,
        p_two_sided: p,
        significant_at_05: p < 0.05,
    }
}

/// CDF of Student's t distribution via the regularized incomplete beta
/// function (continued-fraction evaluation, Numerical-Recipes style).
pub fn student_t_cdf(t: f64, df: f64) -> f64 {
    if !t.is_finite() {
        return if t > 0.0 { 1.0 } else { 0.0 };
    }
    let x = df / (df + t * t);
    let p = 0.5 * incomplete_beta(0.5 * df, 0.5, x);
    if t >= 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Regularized incomplete beta I_x(a, b).
pub fn incomplete_beta(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_beta = ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b);
    let front = (a * x.ln() + b * (1.0 - x).ln() - ln_beta).exp();
    // Continued fraction converges fastest for x < (a+1)/(a+b+2).
    if x < (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - front * beta_cf(b, a, 1.0 - x) / b
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 1e-14;
    const TINY: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // Even step.
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        // Odd step.
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Lanczos approximation of ln Γ(x).
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 7] = [
        76.180_091_729_471_46,
        -86.505_320_329_416_77,
        24.014_098_240_830_91,
        -1.231_739_572_450_155,
        0.120_865_097_386_617_5e-2,
        -0.539_523_938_495_3e-5,
        0.0,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000_000_000_190_015;
    for g in G.iter().take(6) {
        y += 1.0;
        ser += g / y;
    }
    -tmp + (2.506_628_274_631_000_5 * ser / x).ln()
}

/// Geometric mean (all inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// 95% confidence half-width of the mean (normal approximation).
pub fn ci95_halfwidth(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * stddev(xs) / (xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mean_and_variance() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "percentile of empty sample")]
    fn percentile_empty_panics_loudly() {
        percentile(&[], 50.0);
    }

    #[test]
    fn percentile_single_element_is_that_element() {
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.25], p).to_bits(), 7.25f64.to_bits(), "p={p}");
        }
        assert_eq!(median(&[7.25]).to_bits(), 7.25f64.to_bits());
    }

    #[test]
    fn percentile_nan_input_does_not_panic() {
        // total_cmp sorts NaN above +inf: low percentiles still see the
        // finite values, high percentiles report the poison instead of
        // aborting the process.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
        // All-NaN stays deterministic and non-panicking too.
        assert!(percentile(&[f64::NAN, f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn linreg_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ln_gamma_known_values() {
        // Γ(5) = 24
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-9);
        // Γ(0.5) = sqrt(pi)
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-9);
    }

    #[test]
    fn incomplete_beta_symmetry() {
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        for &(a, b, x) in &[(2.0, 3.0, 0.3), (0.5, 0.5, 0.7), (5.0, 1.0, 0.9)] {
            let lhs = incomplete_beta(a, b, x);
            let rhs = 1.0 - incomplete_beta(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-10, "a={a} b={b} x={x}");
        }
    }

    #[test]
    fn student_t_cdf_known_values() {
        // t=0 -> 0.5 for any df.
        assert!((student_t_cdf(0.0, 7.0) - 0.5).abs() < 1e-12);
        // df=1 (Cauchy): CDF(1) = 0.75.
        assert!((student_t_cdf(1.0, 1.0) - 0.75).abs() < 1e-6);
        // Large df approximates the normal: CDF(1.96, 1e6) ~ 0.975.
        assert!((student_t_cdf(1.96, 1e6) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn welch_detects_difference() {
        let a: Vec<f64> = (0..40).map(|i| 10.0 + 0.1 * (i % 5) as f64).collect();
        let b: Vec<f64> = (0..40).map(|i| 12.0 + 0.1 * (i % 5) as f64).collect();
        let r = welch_t_test(&a, &b);
        assert!(r.significant_at_05, "p={}", r.p_two_sided);
    }

    #[test]
    fn welch_same_distribution_usually_not_significant() {
        let mut rng = Rng::new(2024);
        let mut fails = 0;
        for _ in 0..50 {
            let a: Vec<f64> = (0..30).map(|_| rng.normal_with(5.0, 1.0)).collect();
            let b: Vec<f64> = (0..30).map(|_| rng.normal_with(5.0, 1.0)).collect();
            if welch_t_test(&a, &b).significant_at_05 {
                fails += 1;
            }
        }
        // ~5% false positive rate expected; allow generous slack.
        assert!(fails <= 8, "false positives: {fails}/50");
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ci95_shrinks_with_n() {
        let xs: Vec<f64> = (0..100).map(|i| (i % 10) as f64).collect();
        let ys: Vec<f64> = (0..400).map(|i| (i % 10) as f64).collect();
        assert!(ci95_halfwidth(&ys) < ci95_halfwidth(&xs));
    }
}
