//! Cluster hardware model: GPU catalog, rank placement, and PCIe-path
//! reasoning. The [`crate::config::ClusterSpec`] carries the sizes; this
//! module maps logical ranks (GPUs for training, cores for CFD) onto
//! nodes/racks and describes intra-node data paths.

pub mod gpu;
pub mod jobs;
pub mod placement;
pub mod scheduler;

pub use gpu::{GpuModel, V100};
pub use jobs::{FailureEvent, JobPhase, JobSpec, JobState};
pub use placement::{Endpoint, EndpointKind, Placement};
pub use scheduler::{FleetReport, FleetSim, JobOutcome};
