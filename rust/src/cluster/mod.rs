//! Cluster hardware model: GPU catalog, rank placement, and PCIe-path
//! reasoning. The [`crate::config::ClusterSpec`] carries the sizes; this
//! module maps logical ranks (GPUs for training, cores for CFD) onto
//! nodes/racks and describes intra-node data paths.

pub mod gpu;
pub mod placement;

pub use gpu::{GpuModel, V100};
pub use placement::{Endpoint, EndpointKind, Placement};
