//! Fleet job model: seeded arrival traces, node-failure traces, and
//! per-job lifecycle state for the fleet scheduler
//! (`cluster::scheduler`).
//!
//! Trace generation is a pure function of `(FleetSpec, run_seed)` with a
//! *fixed draw order* per job (gap, gang, steps, priority) so that
//! changing one knob — e.g. `priority_levels` — cannot silently reshuffle
//! every other draw. Failure draws come from an independently salted RNG
//! for the same reason.

use crate::config::FleetSpec;
use crate::util::rng::Rng;

/// Immutable description of one job in the arrival trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobSpec {
    /// 1-based fleet id. Id 0 is the observing job in trace attribution
    /// and id 1 the anonymous generator, so a job's *tenant id* is
    /// `id + 1` (see `cluster::scheduler`).
    pub id: usize,
    /// Submission time, seconds.
    pub arrival: f64,
    /// Gang size in nodes (the job wants every GPU on those nodes).
    pub nodes_wanted: usize,
    /// Smallest acceptable gang under elastic scheduling; equals
    /// `nodes_wanted` when the fleet is rigid.
    pub min_nodes: usize,
    /// Training length in optimizer steps.
    pub steps: usize,
    /// Priority level in `[0, priority_levels)`; higher wins.
    pub priority: usize,
}

/// A node going down (and coming back `repair_secs` later).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureEvent {
    pub time: f64,
    pub node: usize,
}

/// Where a job currently is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    /// Submitted, waiting for nodes.
    Queued,
    /// Placed on `nodes`, making progress (once past `resume_at`).
    Running,
    /// All steps done.
    Finished,
}

/// Mutable scheduler-side state of one job.
#[derive(Clone, Debug)]
pub struct JobState {
    pub spec: JobSpec,
    pub phase: JobPhase,
    /// Current node set (ascending); empty unless Running.
    pub nodes: Vec<usize>,
    /// Fractional steps completed so far (survives preemption — that is
    /// what checkpoint/restart buys).
    pub steps_done: f64,
    /// Progress is frozen until this instant (checkpoint-restart cost
    /// after every placement that wasn't the first).
    pub resume_at: f64,
    /// Seconds per step on the *current* placement (0 until placed).
    pub step_time: f64,
    pub preemptions: usize,
    pub first_start: Option<f64>,
    pub completion: Option<f64>,
}

impl JobState {
    pub fn new(spec: JobSpec) -> JobState {
        JobState {
            spec,
            phase: JobPhase::Queued,
            nodes: Vec::new(),
            steps_done: 0.0,
            resume_at: spec.arrival,
            step_time: 0.0,
            preemptions: 0,
            first_start: None,
            completion: None,
        }
    }

    /// Steps still owed.
    pub fn steps_left(&self) -> f64 {
        (self.spec.steps as f64 - self.steps_done).max(0.0)
    }

    /// When this placement will finish, seen from `now`: progress is
    /// frozen until `resume_at`, then each remaining step takes
    /// `step_time`. Only meaningful while Running.
    pub fn projected_completion(&self, now: f64) -> f64 {
        debug_assert!(self.phase == JobPhase::Running && self.step_time > 0.0);
        now.max(self.resume_at) + self.steps_left() * self.step_time
    }

    /// Advance linear progress over `[t0, t1]`.
    pub fn advance(&mut self, t0: f64, t1: f64) {
        if self.phase != JobPhase::Running || self.step_time <= 0.0 {
            return;
        }
        let from = t0.max(self.resume_at);
        if t1 > from {
            self.steps_done =
                (self.steps_done + (t1 - from) / self.step_time).min(self.spec.steps as f64);
        }
    }
}

/// Deterministic arrival trace. Jobs come out sorted by arrival (gaps are
/// non-negative, so generation order *is* arrival order) with 1-based
/// ids.
pub fn job_trace(fleet: &FleetSpec, run_seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::new(fleet.seed ^ run_seed ^ 0xF1EE_7_0B5);
    let mut t = 0.0;
    let mut jobs = Vec::with_capacity(fleet.jobs);
    for id in 1..=fleet.jobs {
        // Fixed draw order: gap, gang, steps, priority.
        let gap = if id == 1 { 0.0 } else { rng.exponential(fleet.interarrival_secs) };
        t += gap;
        let gang_span = (fleet.gang_max - fleet.gang_min + 1) as u64;
        let nodes_wanted = fleet.gang_min + rng.below(gang_span) as usize;
        let step_span = (fleet.steps_max - fleet.steps_min + 1) as u64;
        let steps = fleet.steps_min + rng.below(step_span) as usize;
        let priority = rng.below(fleet.priority_levels as u64) as usize;
        let min_nodes = if fleet.elastic { fleet.gang_min.min(nodes_wanted) } else { nodes_wanted };
        jobs.push(JobSpec { id, arrival: t, nodes_wanted, min_nodes, steps, priority });
    }
    jobs
}

/// Deterministic node-failure trace over the arrival window, sorted by
/// time. Independent RNG stream from [`job_trace`].
pub fn failure_trace(fleet: &FleetSpec, cluster_nodes: usize, run_seed: u64) -> Vec<FailureEvent> {
    let mut rng = Rng::new(fleet.seed ^ run_seed ^ 0xF1EE_FA11);
    let horizon = fleet.interarrival_secs * fleet.jobs as f64;
    let mut events: Vec<FailureEvent> = (0..fleet.node_failures)
        .map(|_| {
            // Fixed draw order: time, node.
            let time = rng.uniform_in(0.0, horizon);
            let node = rng.below(cluster_nodes as u64) as usize;
            FailureEvent { time, node }
        })
        .collect();
    events.sort_by(|a, b| a.time.total_cmp(&b.time));
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_trace_is_seeded_ordered_and_in_bounds() {
        let fleet = FleetSpec { jobs: 24, gang_min: 2, gang_max: 6, ..Default::default() };
        let a = job_trace(&fleet, 7);
        let b = job_trace(&fleet, 7);
        assert_eq!(a, b, "same (spec, run_seed) replays bit-for-bit");
        assert_ne!(a, job_trace(&fleet, 8), "run seed folds in");
        assert_ne!(a, job_trace(&FleetSpec { seed: 1, ..fleet }, 7), "fleet seed folds in");
        assert_eq!(a.len(), 24);
        assert_eq!(a[0].arrival, 0.0, "the first job arrives at t=0");
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival && w[0].id + 1 == w[1].id));
        for j in &a {
            assert!((2..=6).contains(&j.nodes_wanted));
            assert!((fleet.steps_min..=fleet.steps_max).contains(&j.steps));
            assert!(j.priority < fleet.priority_levels);
            assert_eq!(j.min_nodes, j.nodes_wanted, "rigid fleet: min == wanted");
        }
        // Elastic jobs may shrink down to gang_min.
        let elastic = job_trace(&FleetSpec { elastic: true, ..fleet }, 7);
        assert!(elastic.iter().all(|j| j.min_nodes == 2.min(j.nodes_wanted)));
    }

    #[test]
    fn single_job_preset_has_no_randomness_in_shape() {
        let fleet = FleetSpec::single_job(4, 50);
        let jobs = job_trace(&fleet, 123);
        assert_eq!(jobs.len(), 1);
        let j = jobs[0];
        assert_eq!((j.arrival, j.nodes_wanted, j.steps, j.priority), (0.0, 4, 50, 0));
        assert!(failure_trace(&fleet, 64, 123).is_empty());
    }

    #[test]
    fn failure_trace_is_sorted_and_seeded() {
        let fleet = FleetSpec { node_failures: 8, ..Default::default() };
        let a = failure_trace(&fleet, 32, 5);
        assert_eq!(a.len(), 8);
        assert!(a.windows(2).all(|w| w[0].time <= w[1].time));
        assert!(a.iter().all(|e| e.node < 32 && e.time >= 0.0));
        assert_eq!(a, failure_trace(&fleet, 32, 5));
        assert_ne!(a, failure_trace(&fleet, 32, 6));
    }

    #[test]
    fn job_state_progress_accounting() {
        let spec = JobSpec {
            id: 1,
            arrival: 10.0,
            nodes_wanted: 2,
            min_nodes: 2,
            steps: 100,
            priority: 0,
        };
        let mut js = JobState::new(spec);
        assert_eq!(js.phase, JobPhase::Queued);
        assert_eq!(js.steps_left(), 100.0);
        js.phase = JobPhase::Running;
        js.step_time = 0.5;
        js.resume_at = 20.0;
        // Nothing happens before resume_at; the projection is frozen too.
        js.advance(10.0, 20.0);
        assert_eq!(js.steps_done, 0.0);
        assert!((js.projected_completion(15.0) - 70.0).abs() < 1e-9, "frozen until resume_at");
        // Linear progress after, and the projection stays consistent.
        js.advance(20.0, 30.0);
        assert!((js.steps_done - 20.0).abs() < 1e-12);
        assert!((js.projected_completion(30.0) - 70.0).abs() < 1e-9);
        // Progress saturates at the step budget.
        js.advance(30.0, 1e6);
        assert_eq!(js.steps_done, 100.0);
        assert_eq!(js.steps_left(), 0.0);
    }
}
