//! GPU performance catalog — the accelerators appearing in the paper
//! (TX-GAIA's V100) and in Table I's historical rows.

/// Peak-rate model of a GPU (or the GPUs' relevant subset).
#[derive(Clone, Debug, PartialEq)]
pub struct GpuModel {
    pub name: &'static str,
    /// Peak fp32 throughput, FLOP/s.
    pub peak_fp32: f64,
    /// Peak mixed-precision (tensor-core / fp16) throughput, FLOP/s.
    pub peak_fp16: f64,
    /// Memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Device memory, bytes.
    pub mem_bytes: f64,
}

pub const V100: GpuModel = GpuModel {
    name: "V100-32GB",
    peak_fp32: 15.7e12,
    peak_fp16: 125.0e12,
    mem_bw: 900.0e9,
    mem_bytes: 32.0e9,
};

pub const P100: GpuModel = GpuModel {
    name: "P100",
    peak_fp32: 10.6e12,
    peak_fp16: 21.2e12,
    mem_bw: 732.0e9,
    mem_bytes: 16.0e9,
};

pub const K40: GpuModel = GpuModel {
    name: "K40",
    peak_fp32: 5.0e12,
    peak_fp16: 5.0e12, // no fast fp16 path
    mem_bw: 288.0e9,
    mem_bytes: 12.0e9,
};

pub const GTX580: GpuModel = GpuModel {
    name: "GTX 580",
    peak_fp32: 1.58e12,
    peak_fp16: 1.58e12,
    mem_bw: 192.0e9,
    mem_bytes: 1.5e9,
};

pub const TITAN_BLACK: GpuModel = GpuModel {
    name: "Titan Black",
    peak_fp32: 5.1e12,
    peak_fp16: 5.1e12,
    mem_bw: 336.0e9,
    mem_bytes: 6.0e9,
};

/// Look up a model by (case-insensitive) name fragment.
pub fn by_name(name: &str) -> Option<&'static GpuModel> {
    let n = name.to_ascii_lowercase();
    [&V100, &P100, &K40, &GTX580, &TITAN_BLACK].into_iter().find(|g| {
        g.name.to_ascii_lowercase().contains(&n) || n.contains(&g.name.to_ascii_lowercase())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_ordering_sane() {
        assert!(V100.peak_fp32 > P100.peak_fp32);
        assert!(P100.peak_fp32 > K40.peak_fp32);
        assert!(K40.peak_fp32 > GTX580.peak_fp32);
        assert!(V100.peak_fp16 > V100.peak_fp32); // tensor cores
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("v100").unwrap().name, "V100-32GB");
        assert_eq!(by_name("Titan Black").unwrap().name, "Titan Black");
        assert!(by_name("tpu-v5").is_none());
    }
}
