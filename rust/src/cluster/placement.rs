//! Rank placement: maps logical communicator ranks onto cluster nodes.
//!
//! Training ranks are GPUs (block placement: ranks 0..G fill node 0 first,
//! matching `mpirun -map-by slot`); CFD ranks are CPU cores. Placement is
//! what makes rack boundaries visible to the fabric simulator — the Fig 3
//! plateau at 1,280→2,560 cores is purely a placement effect.

use crate::config::ClusterSpec;

/// What kind of device terminates a message path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EndpointKind {
    /// GPU memory (training): subject to GPUDirect / staged-copy modeling.
    Gpu,
    /// Host memory (CFD / CPU MPI ranks).
    Cpu,
}

/// A rank's physical location.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Endpoint {
    pub rank: usize,
    pub node: usize,
    /// Slot within the node (GPU index or core index).
    pub slot: usize,
    pub kind: EndpointKind,
}

/// Block placement of `ranks` logical ranks over the cluster.
#[derive(Clone, Debug)]
pub struct Placement {
    pub endpoints: Vec<Endpoint>,
    pub slots_per_node: usize,
}

impl Placement {
    /// GPUs: `gpus` ranks, `cluster.gpus_per_node` per node.
    pub fn gpus(cluster: &ClusterSpec, gpus: usize) -> anyhow::Result<Placement> {
        Self::block(gpus, cluster.gpus_per_node, cluster.nodes, EndpointKind::Gpu)
    }

    /// CPU cores: `cores` ranks, `cluster.cores_per_node` per node.
    pub fn cores(cluster: &ClusterSpec, cores: usize) -> anyhow::Result<Placement> {
        Self::block(cores, cluster.cores_per_node, cluster.nodes, EndpointKind::Cpu)
    }

    /// GPUs block-placed over an *explicit* node set (the fleet
    /// scheduler's path): rank `r` lands on `nodes[r / gpus_per_node]`,
    /// slot `r % gpus_per_node`. On the contiguous prefix
    /// `[0, 1, 2, ...]` this is bit-identical to [`Placement::gpus`].
    /// `nodes` must be strictly ascending (policies emit sorted sets —
    /// rank order then matches node order, like block placement).
    pub fn gpus_on_nodes(
        cluster: &ClusterSpec,
        nodes: &[usize],
        gpus: usize,
    ) -> anyhow::Result<Placement> {
        let per_node = cluster.gpus_per_node;
        anyhow::ensure!(gpus > 0, "placement of zero ranks");
        anyhow::ensure!(!nodes.is_empty(), "placement over an empty node set");
        anyhow::ensure!(
            nodes.windows(2).all(|w| w[0] < w[1]),
            "node set must be strictly ascending: {nodes:?}"
        );
        anyhow::ensure!(
            *nodes.last().unwrap() < cluster.nodes,
            "node {} outside the {}-node cluster",
            nodes.last().unwrap(),
            cluster.nodes
        );
        let nodes_needed = gpus.div_ceil(per_node);
        anyhow::ensure!(
            nodes_needed <= nodes.len(),
            "{gpus} ranks need {nodes_needed} nodes but the set has {}",
            nodes.len()
        );
        let endpoints = (0..gpus)
            .map(|r| Endpoint {
                rank: r,
                node: nodes[r / per_node],
                slot: r % per_node,
                kind: EndpointKind::Gpu,
            })
            .collect();
        Ok(Placement { endpoints, slots_per_node: per_node })
    }

    fn block(
        ranks: usize,
        per_node: usize,
        max_nodes: usize,
        kind: EndpointKind,
    ) -> anyhow::Result<Placement> {
        anyhow::ensure!(ranks > 0, "placement of zero ranks");
        let nodes_needed = ranks.div_ceil(per_node);
        anyhow::ensure!(
            nodes_needed <= max_nodes,
            "{ranks} ranks need {nodes_needed} nodes but cluster has {max_nodes}"
        );
        let endpoints = (0..ranks)
            .map(|r| Endpoint { rank: r, node: r / per_node, slot: r % per_node, kind })
            .collect();
        Ok(Placement { endpoints, slots_per_node: per_node })
    }

    pub fn len(&self) -> usize {
        self.endpoints.len()
    }

    pub fn is_empty(&self) -> bool {
        self.endpoints.is_empty()
    }

    pub fn nodes_used(&self) -> usize {
        self.endpoints.last().map_or(0, |e| e.node + 1)
    }

    /// Are two ranks on the same node?
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.endpoints[a].node == self.endpoints[b].node
    }

    /// Do two ranks sit in different racks, by the *cluster's* rack
    /// scalar? NOTE: the engine classifies inter-ToR traffic through the
    /// fabric topology (`Topology::tor_of_node`), which only coincides
    /// with this when `[topology] leaf_ports` is unset — prefer
    /// [`crate::fabric::Comm::crosses_rack`] anywhere a `NetSim` exists.
    pub fn crosses_rack(&self, cluster: &ClusterSpec, a: usize, b: usize) -> bool {
        cluster.rack_of_node(self.endpoints[a].node)
            != cluster.rack_of_node(self.endpoints[b].node)
    }

    /// Ranks grouped by node (for hierarchical collectives). Only
    /// occupied nodes appear — an explicit (sparse) node set must not
    /// hand empty groups to a collective's leader election.
    pub fn by_node(&self) -> Vec<Vec<usize>> {
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.nodes_used()];
        for e in &self.endpoints {
            groups[e.node].push(e.rank);
        }
        groups.retain(|g| !g.is_empty());
        groups
    }

    /// Group an arbitrary subset of ranks by a key of their *node* —
    /// e.g. the topology's ToR or dragonfly-group index. Groups come out
    /// in ascending key order; within a group, ranks keep their input
    /// order. This is what makes leader election topology-aware: the
    /// hierarchical collective groups per-node leaders by
    /// `Topology::tor_of_node` instead of a rack scalar.
    pub fn group_by_node<F: Fn(usize) -> usize>(
        &self,
        ranks: &[usize],
        key: F,
    ) -> Vec<Vec<usize>> {
        let mut map: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for &r in ranks {
            map.entry(key(self.endpoints[r].node)).or_default().push(r);
        }
        map.into_values().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::util::prop;

    #[test]
    fn gpu_block_placement() {
        let c = ClusterSpec::txgaia();
        let p = Placement::gpus(&c, 8).unwrap();
        assert_eq!(p.len(), 8);
        assert_eq!(p.nodes_used(), 4);
        assert!(p.same_node(0, 1));
        assert!(!p.same_node(1, 2));
        assert_eq!(p.endpoints[5].node, 2);
        assert_eq!(p.endpoints[5].slot, 1);
    }

    #[test]
    fn rack_crossing_at_boundary() {
        let c = ClusterSpec::txgaia();
        // 32 nodes/rack * 2 GPUs = 64 GPUs in rack 0.
        let p = Placement::gpus(&c, 128).unwrap();
        assert!(!p.crosses_rack(&c, 0, 63));
        assert!(p.crosses_rack(&c, 63, 64));
    }

    #[test]
    fn core_placement_matches_cfd_geometry() {
        let c = ClusterSpec::txgaia();
        // 1280 cores = 32 nodes = exactly one rack (the Fig 3 plateau).
        let p = Placement::cores(&c, 1280).unwrap();
        assert_eq!(p.nodes_used(), 32);
        assert!(!p.crosses_rack(&c, 0, 1279));
        let p2 = Placement::cores(&c, 2560).unwrap();
        assert!(p2.crosses_rack(&c, 0, 2559));
    }

    #[test]
    fn explicit_node_set_placement() {
        let c = ClusterSpec::txgaia();
        // A contiguous prefix replays block placement bit-identically.
        let block = Placement::gpus(&c, 8).unwrap();
        let explicit = Placement::gpus_on_nodes(&c, &[0, 1, 2, 3], 8).unwrap();
        assert_eq!(block.endpoints, explicit.endpoints);
        // A sparse set keeps physical node ids and only occupied groups.
        let p = Placement::gpus_on_nodes(&c, &[5, 40, 100], 6).unwrap();
        assert_eq!(p.endpoints[0].node, 5);
        assert_eq!(p.endpoints[3].node, 40);
        assert_eq!(p.endpoints[5], Endpoint {
            rank: 5,
            node: 100,
            slot: 1,
            kind: EndpointKind::Gpu
        });
        assert_eq!(p.by_node(), vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
        assert!(p.crosses_rack(&c, 0, 2));
        // Loud failures: unsorted, out of range, too small.
        assert!(Placement::gpus_on_nodes(&c, &[3, 2], 2).is_err());
        assert!(Placement::gpus_on_nodes(&c, &[3, 3], 2).is_err());
        assert!(Placement::gpus_on_nodes(&c, &[448], 1).is_err());
        assert!(Placement::gpus_on_nodes(&c, &[0, 1], 6).is_err());
        assert!(Placement::gpus_on_nodes(&c, &[], 1).is_err());
    }

    #[test]
    fn rejects_oversubscription() {
        let c = ClusterSpec::txgaia();
        assert!(Placement::gpus(&c, 2 * 448 + 1).is_err());
        assert!(Placement::gpus(&c, 0).is_err());
    }

    #[test]
    fn group_by_node_partitions_and_orders() {
        let c = ClusterSpec::txgaia();
        let p = Placement::gpus(&c, 12).unwrap(); // 6 nodes
        // Key = node / 2: three groups of two nodes each.
        let leaders: Vec<usize> = (0..6).map(|n| 2 * n).collect(); // rank 2n on node n
        let groups = p.group_by_node(&leaders, |node| node / 2);
        assert_eq!(groups, vec![vec![0, 2], vec![4, 6], vec![8, 10]]);
        // Subset order within a group follows input order.
        let groups = p.group_by_node(&[10, 0, 4], |node| node / 2);
        assert_eq!(groups, vec![vec![0], vec![4], vec![10]]);
    }

    #[test]
    fn by_node_partitions_all_ranks() {
        let c = ClusterSpec::txgaia();
        prop::forall(11, 64, |r| 1 + r.below(160) as usize, |&n| {
            let p = Placement::gpus(&c, n).unwrap();
            let groups = p.by_node();
            let total: usize = groups.iter().map(|g| g.len()).sum();
            if total != n {
                return Err(format!("partition lost ranks: {total} != {n}"));
            }
            for (node, g) in groups.iter().enumerate() {
                for &r in g {
                    if p.endpoints[r].node != node {
                        return Err(format!("rank {r} in wrong group {node}"));
                    }
                }
            }
            Ok(())
        });
    }
}
