//! Multi-job fleet scheduler: a desired-state/actual-state reconcile loop
//! (the Kubernetes-operator idiom) over a seeded arrival trace of
//! gang-scheduled training jobs.
//!
//! Desired state is the job trace ([`crate::cluster::jobs::job_trace`]):
//! which jobs exist, how many nodes each wants, at what priority. Actual
//! state is the node ledger: which nodes are up, and who owns them. The
//! loop wakes at discrete events — arrivals, projected completions, node
//! failures, repairs — advances every running job's progress linearly,
//! then reconciles: finished jobs release nodes, queued jobs are placed
//! by the configured [`PlacementPolicy`], higher-priority arrivals may
//! preempt strictly-lower-priority jobs (paying a checkpoint-restart
//! cost), and elastic jobs shrink into the space available or grow back
//! to their wanted size.
//!
//! Each placed job's step time comes from the *real* trainer:
//! [`TrainerSim::run_placed`] over the job's node set, with every
//! co-located job's traffic entering the fabric simulation as an
//! attributed per-job tenant flow (`NetSim::add_tenant`) — the
//! shared-tenancy background generators of PR 5 promoted to first-class
//! jobs. Step times are memoized on the (job, node set, neighbor set)
//! key, so a fleet run costs one trainer simulation per distinct
//! co-location pattern, not per event. Node failures double as fabric
//! faults: a node awaiting repair enters every measurement taken during
//! its repair window as a hard NIC-down ([`crate::fabric::FaultEvent`])
//! layered on the configured `[faults]` trace, and the remaining repair
//! time folds into the memo key so faulted prices never alias healthy
//! ones.
//!
//! Determinism contract: the whole simulation is a pure function of
//! `(TrainerSim, FleetSpec, RunSpec)`. A single-job, no-churn fleet
//! ([`FleetSpec::single_job`]) reproduces the standalone trainer
//! bit-for-bit — pinned in `tests/fleet_properties.rs`.

use std::collections::HashMap;

use crate::cluster::jobs::{failure_trace, job_trace, FailureEvent, JobPhase, JobState};
use crate::cluster::Placement;
use crate::config::{FleetSpec, PlacementPolicy, RunSpec, TenancySpec};
use crate::fabric::tenancy::BackgroundTraffic;
use crate::fabric::topology::Topology;
use crate::fabric::{FaultEvent, FaultTarget};
use crate::trainer::TrainerSim;
use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
use crate::util::stats;

/// Odd salt for deriving per-job seeds (same constant the tenancy model
/// uses for epoch salting).
const JOB_SEED_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// Hard cap on reconcile events — a loud backstop against a scheduling
/// livelock, far above anything a valid trace produces.
const MAX_EVENTS: usize = 200_000;

/// Completion slack: a job within this many steps of its budget is done
/// (absorbs float drift from piecewise-linear progress accounting).
const STEP_EPS: f64 = 1e-6;

/// Final record of one job's trip through the fleet.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub id: usize,
    pub arrival: f64,
    pub completion: f64,
    /// Job completion time: `completion - arrival` (queueing included).
    pub jct: f64,
    /// Gang size (nodes) of the final placement.
    pub nodes: usize,
    pub gpus: usize,
    pub steps: usize,
    pub priority: usize,
    /// Involuntary deschedules (priority preemptions + node failures).
    pub preemptions: usize,
    /// Seconds/step on the final placement.
    pub step_time: f64,
}

/// Fleet-wide results.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub jobs: Vec<JobOutcome>,
    /// Last completion (the first arrival is at t = 0).
    pub makespan: f64,
    pub mean_jct: f64,
    pub p99_jct: f64,
    /// Fleet goodput: total images trained / makespan.
    pub images_per_sec: f64,
    /// Total involuntary deschedules across jobs.
    pub preemptions: usize,
    /// Node-failure events applied.
    pub failures: usize,
}

/// The fleet simulator. Borrows a [`TrainerSim`] as the template every
/// placed job runs under (architecture, fabric, transport, tenancy
/// stragglers — everything but placement and co-tenant traffic).
pub struct FleetSim<'a> {
    pub trainer: &'a TrainerSim,
    pub fleet: FleetSpec,
    topo: Topology,
}

/// Mutable simulation state, separated from the borrow of the trainer.
struct Ledger {
    jobs: Vec<JobState>,
    /// Per node: is it up, and which job id owns it (0 = free).
    up: Vec<bool>,
    owner: Vec<usize>,
    failures: Vec<FailureEvent>,
    next_failure: usize,
    /// Pending (time, node) repairs, unordered (scanned, not popped).
    repairs: Vec<(f64, usize)>,
    preemptions: usize,
    failures_applied: usize,
}

impl Ledger {
    fn free_nodes(&self) -> Vec<usize> {
        (0..self.up.len()).filter(|&n| self.up[n] && self.owner[n] == 0).collect()
    }

    fn release(&mut self, job_id: usize) {
        for o in self.owner.iter_mut() {
            if *o == job_id {
                *o = 0;
            }
        }
    }

    fn requeue(&mut self, ji: usize, involuntary: bool) {
        let id = self.jobs[ji].spec.id;
        self.release(id);
        let j = &mut self.jobs[ji];
        j.phase = JobPhase::Queued;
        j.nodes.clear();
        j.step_time = 0.0;
        if involuntary {
            j.preemptions += 1;
            self.preemptions += 1;
        }
    }
}

impl<'a> FleetSim<'a> {
    pub fn new(trainer: &'a TrainerSim, fleet: FleetSpec) -> anyhow::Result<FleetSim<'a>> {
        fleet.validate_for(&trainer.cluster)?;
        let topo = Topology::build(&trainer.fabric.topology, &trainer.fabric, &trainer.cluster)?;
        Ok(FleetSim { trainer, fleet, topo })
    }

    /// Per-job run seed. Job 1 runs at exactly `run.seed` — that is what
    /// makes the single-job fleet reproduce the standalone trainer
    /// bit-for-bit; later jobs derive deterministically.
    fn job_run_seed(&self, run: &RunSpec, id: usize) -> u64 {
        run.seed ^ (id as u64 - 1).wrapping_mul(JOB_SEED_SALT)
    }

    /// Simulate the whole trace; returns per-job outcomes and fleet-wide
    /// throughput/JCT statistics.
    pub fn run(&self, run: &RunSpec) -> anyhow::Result<FleetReport> {
        let specs = job_trace(&self.fleet, run.seed);
        let n_nodes = self.trainer.cluster.nodes;
        let mut st = Ledger {
            jobs: specs.iter().map(|s| JobState::new(*s)).collect(),
            up: vec![true; n_nodes],
            owner: vec![0; n_nodes],
            failures: failure_trace(&self.fleet, n_nodes, run.seed),
            next_failure: 0,
            repairs: Vec::new(),
            preemptions: 0,
            failures_applied: 0,
        };
        let mut memo: HashMap<u64, f64> = HashMap::new();
        let mut t = 0.0;

        for _event in 0..MAX_EVENTS {
            // --- Fire everything due at the current instant. ---
            // 1. Completions release their nodes.
            for ji in 0..st.jobs.len() {
                if st.jobs[ji].phase == JobPhase::Running
                    && st.jobs[ji].steps_left() <= STEP_EPS
                {
                    let id = st.jobs[ji].spec.id;
                    st.release(id);
                    let j = &mut st.jobs[ji];
                    j.phase = JobPhase::Finished;
                    j.completion = Some(t);
                }
            }
            // 2. Node failures take nodes down and evict their owners.
            while st.next_failure < st.failures.len()
                && st.failures[st.next_failure].time <= t + 1e-12
            {
                let ev = st.failures[st.next_failure];
                st.next_failure += 1;
                if !st.up[ev.node] {
                    continue; // already down; the repair in flight covers it
                }
                st.up[ev.node] = false;
                st.repairs.push((t + self.fleet.repair_secs, ev.node));
                st.failures_applied += 1;
                let victim = st.owner[ev.node];
                if victim != 0 {
                    st.requeue(victim - 1, true);
                }
            }
            // 3. Repairs bring nodes back.
            let mut repairs = std::mem::take(&mut st.repairs);
            repairs.retain(|&(rt, node)| {
                if rt <= t + 1e-12 {
                    st.up[node] = true;
                    false
                } else {
                    true
                }
            });
            st.repairs = repairs;

            // 4. Reconcile desired state (the queue) against the ledger.
            self.reconcile(&mut st, t, run, &mut memo)?;

            // --- Pick the next wake-up: the earliest strictly-future
            // arrival, failure, repair, or projected completion. ---
            let mut next = f64::INFINITY;
            for j in &st.jobs {
                match j.phase {
                    JobPhase::Queued if j.spec.arrival > t => next = next.min(j.spec.arrival),
                    JobPhase::Running => next = next.min(j.projected_completion(t)),
                    _ => {}
                }
            }
            if st.next_failure < st.failures.len() {
                next = next.min(st.failures[st.next_failure].time.max(t));
            }
            for &(rt, _) in &st.repairs {
                next = next.min(rt);
            }
            if !next.is_finite() {
                break; // every job finished, nothing pending
            }
            // Advance progress to the wake-up instant.
            for j in st.jobs.iter_mut() {
                j.advance(t, next);
            }
            t = next;
        }

        let unfinished = st.jobs.iter().filter(|j| j.completion.is_none()).count();
        anyhow::ensure!(
            unfinished == 0,
            "fleet livelock: {unfinished} jobs unfinished after {MAX_EVENTS} events"
        );
        self.report(&st)
    }

    /// Place queued jobs (priority first, arrival-order within a level),
    /// preempting strictly-lower-priority work when allowed, then grow
    /// elastic jobs back toward their wanted size. Any membership change
    /// re-prices every running job's step time (memoized).
    fn reconcile(
        &self,
        st: &mut Ledger,
        t: f64,
        run: &RunSpec,
        memo: &mut HashMap<u64, f64>,
    ) -> anyhow::Result<()> {
        let mut changed = false;
        loop {
            let mut queue: Vec<usize> = (0..st.jobs.len())
                .filter(|&ji| {
                    st.jobs[ji].phase == JobPhase::Queued && st.jobs[ji].spec.arrival <= t + 1e-12
                })
                .collect();
            queue.sort_by(|&a, &b| {
                let (ja, jb) = (&st.jobs[a].spec, &st.jobs[b].spec);
                jb.priority
                    .cmp(&ja.priority)
                    .then(ja.arrival.total_cmp(&jb.arrival))
                    .then(ja.id.cmp(&jb.id))
            });
            let mut progressed = false;
            for &ji in &queue {
                if st.jobs[ji].phase != JobPhase::Queued {
                    continue;
                }
                if self.try_place(st, ji, t) {
                    progressed = true;
                    changed = true;
                }
            }
            if !progressed {
                break;
            }
            // Preemption may have requeued jobs: run another pass so they
            // get a shot at the remaining free nodes. Priority strictly
            // decreases along any preemption chain, so this terminates.
        }

        // Elastic growth: a shrunk job takes its full wanted size when
        // the whole gang now fits (its own nodes count as available to
        // itself), paying one checkpoint restart.
        if self.fleet.elastic {
            for ji in 0..st.jobs.len() {
                let (want, have) = (st.jobs[ji].spec.nodes_wanted, st.jobs[ji].nodes.len());
                if st.jobs[ji].phase != JobPhase::Running || have >= want {
                    continue;
                }
                if st.free_nodes().len() + have >= want {
                    let id = st.jobs[ji].spec.id;
                    st.release(id);
                    let picked = pick_nodes(self.fleet.placement, &self.topo, &st.free_nodes(), want)
                        .expect("count checked above");
                    self.assign(st, ji, picked, t);
                    changed = true;
                }
            }
        }

        if changed {
            self.reprice_running(st, t, run, memo)?;
        }
        Ok(())
    }

    /// Try to place queued job `ji` at time `t`. Tries the wanted gang
    /// size on free nodes first, then (if elastic) progressively smaller
    /// sizes down to `min_nodes`, then (if preemption is on) evicts
    /// strictly-lower-priority jobs — cheapest victims first — to make
    /// room for the wanted size.
    fn try_place(&self, st: &mut Ledger, ji: usize, t: f64) -> bool {
        let spec = st.jobs[ji].spec;
        let free = st.free_nodes();
        let mut sizes: Vec<usize> = vec![spec.nodes_wanted];
        if self.fleet.elastic {
            sizes.extend((spec.min_nodes..spec.nodes_wanted).rev());
        }
        for &size in &sizes {
            if let Some(nodes) = pick_nodes(self.fleet.placement, &self.topo, &free, size) {
                self.assign(st, ji, nodes, t);
                return true;
            }
        }
        if !self.fleet.preemption {
            return false;
        }
        // Victims: strictly lower priority, cheapest eviction first
        // (lowest priority, then latest arrival — the least-sunk work).
        let mut victims: Vec<usize> = (0..st.jobs.len())
            .filter(|&vi| {
                st.jobs[vi].phase == JobPhase::Running && st.jobs[vi].spec.priority < spec.priority
            })
            .collect();
        victims.sort_by(|&a, &b| {
            let (ja, jb) = (&st.jobs[a].spec, &st.jobs[b].spec);
            ja.priority.cmp(&jb.priority).then(jb.arrival.total_cmp(&ja.arrival))
        });
        let reclaimable: usize = victims.iter().map(|&vi| st.jobs[vi].nodes.len()).sum();
        if free.len() + reclaimable < spec.nodes_wanted {
            return false;
        }
        let mut have = free.len();
        for &vi in &victims {
            if have >= spec.nodes_wanted {
                break;
            }
            have += st.jobs[vi].nodes.len();
            st.requeue(vi, true);
        }
        let nodes = pick_nodes(self.fleet.placement, &self.topo, &st.free_nodes(), spec.nodes_wanted)
            .expect("freed enough nodes for the wanted gang");
        self.assign(st, ji, nodes, t);
        true
    }

    /// Commit a placement: claim nodes, set the phase, charge the
    /// checkpoint-restart cost on anything but a job's first start.
    fn assign(&self, st: &mut Ledger, ji: usize, nodes: Vec<usize>, t: f64) {
        let id = st.jobs[ji].spec.id;
        for &n in &nodes {
            debug_assert!(st.up[n] && st.owner[n] == 0);
            st.owner[n] = id;
        }
        let j = &mut st.jobs[ji];
        let first = j.first_start.is_none();
        if first {
            j.first_start = Some(t);
        }
        j.phase = JobPhase::Running;
        j.nodes = nodes;
        j.resume_at = if first { t } else { t + self.fleet.checkpoint_restart_secs };
    }

    /// Recompute every running job's step time for the current
    /// co-location pattern, memoized on (job, node set, neighbor sets).
    fn reprice_running(
        &self,
        st: &mut Ledger,
        t: f64,
        run: &RunSpec,
        memo: &mut HashMap<u64, f64>,
    ) -> anyhow::Result<()> {
        let running: Vec<usize> = (0..st.jobs.len())
            .filter(|&ji| st.jobs[ji].phase == JobPhase::Running)
            .collect();
        // Nodes awaiting repair surface to the fabric as hard NIC-down
        // faults for the remainder of their repair window: the failure
        // trace is a *fabric* event, not just a scheduling one. Sorted
        // by node id so the memo key and the fault spec are canonical.
        // Empty when no repair is pending, which folds nothing into the
        // key — healthy repricings keep their pre-fault memo entries.
        let mut down: Vec<(usize, f64)> =
            st.repairs.iter().map(|&(rt, node)| (node, rt - t)).collect();
        down.sort_by(|a, b| a.0.cmp(&b.0));
        for &ji in &running {
            let mut key = FNV_OFFSET;
            key = fnv1a_u64(key, st.jobs[ji].spec.id as u64);
            for &n in &st.jobs[ji].nodes {
                key = fnv1a_u64(key, n as u64);
            }
            key = fnv1a_u64(key, u64::MAX);
            for &ki in &running {
                if ki == ji {
                    continue;
                }
                key = fnv1a_u64(key, st.jobs[ki].spec.id as u64);
                for &n in &st.jobs[ki].nodes {
                    key = fnv1a_u64(key, n as u64);
                }
                key = fnv1a_u64(key, u64::MAX);
            }
            for &(node, remaining) in &down {
                key = fnv1a_u64(key, node as u64);
                key = fnv1a_u64(key, remaining.to_bits());
            }
            let step_time = match memo.get(&key) {
                Some(&v) => v,
                None => {
                    let v = self.measure_step_time(st, ji, &running, run, &down)?;
                    memo.insert(key, v);
                    v
                }
            };
            let j = &mut st.jobs[ji];
            if (j.step_time - step_time).abs() > 0.0 {
                j.step_time = step_time;
                // Progress already earned stays; only the rate changes.
                j.resume_at = j.resume_at.max(t);
            }
        }
        Ok(())
    }

    /// One trainer simulation for job `ji` on its node set, with every
    /// other running job attached as an attributed tenant generator
    /// (shuffle traffic over the neighbor's own nodes at the configured
    /// `neighbor_load`). Single-node neighbors emit nothing — their
    /// training traffic never leaves the node.
    ///
    /// Nodes still awaiting repair (`down`: sorted `(node, remaining)`)
    /// enter the measurement as NIC hard-down fabric faults for the
    /// remainder of their repair window, layered on top of any
    /// configured `[faults]` trace.
    fn measure_step_time(
        &self,
        st: &Ledger,
        ji: usize,
        running: &[usize],
        run: &RunSpec,
        down: &[(usize, f64)],
    ) -> anyhow::Result<f64> {
        let j = &st.jobs[ji];
        let gpus = j.nodes.len() * self.trainer.cluster.gpus_per_node;
        let placement = Placement::gpus_on_nodes(&self.trainer.cluster, &j.nodes, gpus)?;
        let mut tenants: Vec<(usize, BackgroundTraffic)> = Vec::new();
        if self.fleet.neighbor_load > 0.0 {
            for &ki in running {
                let k = &st.jobs[ki];
                if ki == ji || k.nodes.len() < 2 {
                    continue;
                }
                let spec = TenancySpec {
                    seed: self.fleet.seed ^ (k.spec.id as u64).wrapping_mul(JOB_SEED_SALT),
                    ..TenancySpec::shuffle(self.fleet.neighbor_load)
                };
                let bg = BackgroundTraffic::with_node_sets(
                    &spec,
                    &self.trainer.fabric,
                    self.job_run_seed(run, k.spec.id),
                    k.nodes.clone(),
                    k.nodes.clone(),
                )?;
                // Tenant id = job id + 1: never 0 (the observing job) and
                // never 1 (the anonymous generator).
                tenants.push((k.spec.id + 1, bg));
            }
        }
        let inner = RunSpec { seed: self.job_run_seed(run, j.spec.id), ..run.clone() };
        let result = if down.is_empty() {
            // No pending repair: `run_placed` applies `trainer.faults`
            // itself, and the default (inactive) spec is bit-for-bit
            // the pre-fault engine.
            self.trainer.run_placed(&placement, &inner, &tenants)?
        } else {
            let mut faults = self.trainer.faults.clone();
            for &(node, remaining) in down {
                faults.events.push(FaultEvent {
                    target: FaultTarget::Nic(node),
                    at: 0.0,
                    duration: remaining,
                    factor: 0.0,
                });
            }
            self.trainer.run_placed_with_faults(&placement, &inner, &tenants, &faults)?
        };
        Ok(result.step_time_mean)
    }

    fn report(&self, st: &Ledger) -> anyhow::Result<FleetReport> {
        let per_gpu_batch = self.trainer.per_gpu_batch as f64;
        let mut jobs: Vec<JobOutcome> = st
            .jobs
            .iter()
            .map(|j| {
                let completion = j.completion.expect("checked unfinished == 0");
                JobOutcome {
                    id: j.spec.id,
                    arrival: j.spec.arrival,
                    completion,
                    jct: completion - j.spec.arrival,
                    nodes: j.nodes.len(),
                    gpus: j.nodes.len() * self.trainer.cluster.gpus_per_node,
                    steps: j.spec.steps,
                    priority: j.spec.priority,
                    preemptions: j.preemptions,
                    step_time: j.step_time,
                }
            })
            .collect();
        jobs.sort_by_key(|j| j.id);
        let makespan = jobs.iter().map(|j| j.completion).fold(0.0, f64::max);
        let jcts: Vec<f64> = jobs.iter().map(|j| j.jct).collect();
        let images: f64 =
            jobs.iter().map(|j| j.steps as f64 * j.gpus as f64 * per_gpu_batch).sum();
        Ok(FleetReport {
            makespan,
            mean_jct: stats::mean(&jcts),
            p99_jct: stats::percentile(&jcts, 99.0),
            images_per_sec: images / makespan,
            preemptions: st.preemptions,
            failures: st.failures_applied,
            jobs,
        })
    }
}

/// Choose `want` nodes from the free pool (ascending ids) under a
/// placement policy. Returns an ascending node list, or `None` when the
/// pool is too small. Policies differ only in *which* nodes — never in
/// how many — so admission decisions are policy-independent.
pub fn pick_nodes(
    policy: PlacementPolicy,
    topo: &Topology,
    free: &[usize],
    want: usize,
) -> Option<Vec<usize>> {
    if want == 0 || free.len() < want {
        return None;
    }
    let mut out = match policy {
        PlacementPolicy::Pack => free[..want].to_vec(),
        PlacementPolicy::Spread => {
            // Round-robin one node per ToR (ascending ToR order) until
            // the gang is full: maximal ToR span.
            let mut by_tor: Vec<(usize, std::collections::VecDeque<usize>)> = Vec::new();
            for &n in free {
                let tor = topo.tor_of_node(n);
                match by_tor.last_mut() {
                    Some((t, q)) if *t == tor => q.push_back(n),
                    _ => by_tor.push((tor, std::collections::VecDeque::from([n]))),
                }
            }
            let mut out = Vec::with_capacity(want);
            'rr: loop {
                let mut any = false;
                for (_, q) in by_tor.iter_mut() {
                    if let Some(n) = q.pop_front() {
                        out.push(n);
                        any = true;
                        if out.len() == want {
                            break 'rr;
                        }
                    }
                }
                debug_assert!(any, "pool exhausted before want — size was pre-checked");
            }
            out
        }
        PlacementPolicy::TopologyAware => {
            // ToR-packing: if some ToR can hold the whole remainder, take
            // the *tightest* such ToR (best fit — preserves big holes);
            // otherwise drain the fullest ToR and repeat. Minimizes the
            // gang's ToR span, then fragmentation.
            let mut by_tor: Vec<(usize, Vec<usize>)> = Vec::new();
            for &n in free {
                let tor = topo.tor_of_node(n);
                match by_tor.last_mut() {
                    Some((t, v)) if *t == tor => v.push(n),
                    _ => by_tor.push((tor, vec![n])),
                }
            }
            let mut out = Vec::with_capacity(want);
            while out.len() < want {
                let remaining = want - out.len();
                let fits = by_tor
                    .iter()
                    .enumerate()
                    .filter(|(_, (_, v))| v.len() >= remaining)
                    .min_by_key(|(_, (tor, v))| (v.len(), *tor));
                let idx = match fits {
                    Some((i, _)) => i,
                    None => {
                        // No single ToR fits: drain the fullest (tie →
                        // lowest ToR id) and keep going.
                        by_tor
                            .iter()
                            .enumerate()
                            .max_by(|(_, (ta, va)), (_, (tb, vb))| {
                                va.len().cmp(&vb.len()).then(tb.cmp(ta))
                            })
                            .map(|(i, _)| i)
                            .expect("free pool non-empty")
                    }
                };
                let (_, v) = &mut by_tor[idx];
                let take = remaining.min(v.len());
                out.extend(v.drain(..take));
                by_tor.retain(|(_, v)| !v.is_empty());
            }
            out
        }
    };
    out.sort_unstable();
    debug_assert_eq!(out.len(), want);
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::{ClusterSpec, FabricKind, TopologySpec};

    fn topo(nodes: usize, per_tor: usize) -> Topology {
        let mut cluster = ClusterSpec::txgaia();
        cluster.nodes = nodes;
        cluster.nodes_per_rack = per_tor;
        let fabric = fabric(FabricKind::EthernetRoce25);
        let spec = TopologySpec { leaf_ports: Some(per_tor), ..Default::default() };
        Topology::build(&spec, &fabric, &cluster).unwrap()
    }

    #[test]
    fn pack_takes_lowest_ids() {
        let topo = topo(16, 4);
        let free: Vec<usize> = (0..16).collect();
        assert_eq!(pick_nodes(PlacementPolicy::Pack, &topo, &free, 3), Some(vec![0, 1, 2]));
        assert_eq!(pick_nodes(PlacementPolicy::Pack, &topo, &free, 17), None);
        assert_eq!(pick_nodes(PlacementPolicy::Pack, &topo, &free, 0), None);
    }

    #[test]
    fn spread_round_robins_tors() {
        let topo = topo(16, 4);
        let free: Vec<usize> = (0..16).collect();
        // One per ToR first: nodes 0, 4, 8, 12 — then wrap.
        assert_eq!(
            pick_nodes(PlacementPolicy::Spread, &topo, &free, 4),
            Some(vec![0, 4, 8, 12])
        );
        assert_eq!(
            pick_nodes(PlacementPolicy::Spread, &topo, &free, 6),
            Some(vec![0, 1, 4, 5, 8, 12])
        );
    }

    #[test]
    fn topology_aware_minimizes_tor_span_with_best_fit() {
        let topo = topo(16, 4);
        // ToR 0 has 2 free, ToR 1 has 4, ToR 2 has 3.
        let free = vec![0, 1, 4, 5, 6, 7, 8, 9, 10];
        // want 3 → the tightest ToR that fits is ToR 2 (3 free).
        assert_eq!(
            pick_nodes(PlacementPolicy::TopologyAware, &topo, &free, 3),
            Some(vec![8, 9, 10])
        );
        // want 4 → exactly ToR 1.
        assert_eq!(
            pick_nodes(PlacementPolicy::TopologyAware, &topo, &free, 4),
            Some(vec![4, 5, 6, 7])
        );
        // want 6 → no single ToR fits: drain the fullest (ToR 1), then
        // best-fit the remaining 2 into ToR 0 (2 free beats ToR 2's 3).
        assert_eq!(
            pick_nodes(PlacementPolicy::TopologyAware, &topo, &free, 6),
            Some(vec![0, 1, 4, 5, 6, 7])
        );
    }

    #[test]
    fn policies_always_emit_sorted_exact_sets() {
        let topo = topo(32, 8);
        let free: Vec<usize> = (0..32).filter(|n| n % 3 != 0).collect();
        for policy in
            [PlacementPolicy::Pack, PlacementPolicy::Spread, PlacementPolicy::TopologyAware]
        {
            for want in [1, 2, 5, free.len()] {
                let got = pick_nodes(policy, &topo, &free, want).unwrap();
                assert_eq!(got.len(), want, "{policy:?} want={want}");
                assert!(got.windows(2).all(|w| w[0] < w[1]), "{policy:?} unsorted: {got:?}");
                assert!(got.iter().all(|n| free.contains(n)), "{policy:?} invented a node");
            }
            assert!(pick_nodes(policy, &topo, &free, free.len() + 1).is_none());
        }
    }
}
