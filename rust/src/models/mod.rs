//! DNN workload models: layer-level architecture descriptions (parameter
//! counts and FLOPs derived from first principles), the model zoo used in
//! the paper's Figs 4-5 and Table I, and the GPU step-time performance
//! model calibrated against published tf_cnn_benchmarks throughput.

pub mod arch;
pub mod perf;
pub mod zoo;

pub use arch::{Arch, Layer, LayerKind};
pub use perf::{Precision, StepCost};
pub use zoo::{alexnet, inception_v3, paper_models, resnet50, resnet50_v15, vgg16};
