//! The model zoo: the paper's four benchmark networks plus AlexNet
//! (Table I). Parameter counts are asserted against published values in
//! the tests below — the layer algebra must reproduce them from first
//! principles, they are not hard-coded.

use super::arch::{Arch, ArchBuilder, Layer};

/// VGG16 (configuration D, 224x224): 138,357,544 parameters.
pub fn vgg16() -> Arch {
    let mut b = ArchBuilder::new("vgg16", 224, 224, 3);
    let cfg: &[&[usize]] =
        &[&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]];
    for (s, stage) in cfg.iter().enumerate() {
        for (i, &c) in stage.iter().enumerate() {
            b = b.conv(&format!("conv{}_{}", s + 1, i + 1), c, 3, 1, 1, true);
            b = b.relu(&format!("relu{}_{}", s + 1, i + 1));
        }
        b = b.pool(&format!("pool{}", s + 1), 2, 2, 0);
    }
    b = b.fc("fc6", 4096).relu("relu6");
    b = b.fc("fc7", 4096).relu("relu7");
    b = b.fc("fc8", 1000);
    // tf_cnn_benchmarks V100 fp32: ~125 img/s (VGG16 is GEMM-heavy and
    // runs at high MXU/SM efficiency, but 30.9 GFLOPs/image is 4x RN50).
    b.build(125.0)
}

/// AlexNet (torchvision variant): 61,100,840 parameters.
pub fn alexnet() -> Arch {
    ArchBuilder::new("alexnet", 224, 224, 3)
        .conv("conv1", 64, 11, 4, 2, true)
        .relu("relu1")
        .pool("pool1", 3, 2, 0)
        .conv("conv2", 192, 5, 1, 2, true)
        .relu("relu2")
        .pool("pool2", 3, 2, 0)
        .conv("conv3", 384, 3, 1, 1, true)
        .relu("relu3")
        .conv("conv4", 256, 3, 1, 1, true)
        .relu("relu4")
        .conv("conv5", 256, 3, 1, 1, true)
        .relu("relu5")
        .pool("pool5", 3, 2, 0)
        .fc("fc6", 4096)
        .relu("relu6")
        .fc("fc7", 4096)
        .relu("relu7")
        .fc("fc8", 1000)
        .build(2400.0)
}

/// Bottleneck residual block shared by both ResNet50 variants.
///
/// `stride_on_3x3` distinguishes v1 (stride on the first 1x1) from v1.5
/// (stride on the 3x3) — identical parameters, ~12% more FLOPs for v1.5.
fn bottleneck(
    b: ArchBuilder,
    name: &str,
    width: usize,
    stride: usize,
    downsample: bool,
    stride_on_3x3: bool,
) -> ArchBuilder {
    let (h, w, c_in) = b.shape();
    let out_c = width * 4;
    let (s1, s3) = if stride_on_3x3 { (1, stride) } else { (stride, 1) };
    let mut b = b
        .conv(&format!("{name}.conv1"), width, 1, s1, 0, false)
        .bn(&format!("{name}.bn1"))
        .relu(&format!("{name}.relu1"))
        .conv(&format!("{name}.conv2"), width, 3, s3, 1, false)
        .bn(&format!("{name}.bn2"))
        .relu(&format!("{name}.relu2"))
        .conv(&format!("{name}.conv3"), out_c, 1, 1, 0, false)
        .bn(&format!("{name}.bn3"));
    if downsample {
        // Projection shortcut: computed on the block's input shape.
        let side = ArchBuilder::new("side", h, w, c_in)
            .conv(&format!("{name}.downsample.conv"), out_c, 1, stride, 0, false)
            .bn(&format!("{name}.downsample.bn"));
        let layers: Vec<Layer> = side.build(0.0).layers;
        b = b.absorb(layers);
    }
    b.relu(&format!("{name}.relu3"))
}

fn resnet50_variant(name: &str, stride_on_3x3: bool, ref_ips: f64) -> Arch {
    let mut b = ArchBuilder::new(name, 224, 224, 3)
        .conv("stem.conv", 64, 7, 2, 3, false)
        .bn("stem.bn")
        .relu("stem.relu")
        .pool("stem.maxpool", 3, 2, 1);
    let stages: [(usize, usize, usize); 4] =
        [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)];
    for (si, &(width, blocks, stride)) in stages.iter().enumerate() {
        for blk in 0..blocks {
            let s = if blk == 0 { stride } else { 1 };
            let ds = blk == 0; // stage entry always projects (channel change)
            b = bottleneck(
                b,
                &format!("layer{}.{}", si + 1, blk),
                width,
                s,
                ds,
                stride_on_3x3,
            );
        }
    }
    b.global_pool("avgpool").fc("fc", 1000).build(ref_ips)
}

/// ResNet50 v1: 25,557,032 parameters, ~3.86 GFLOPs/image forward.
pub fn resnet50() -> Arch {
    resnet50_variant("resnet50", false, 365.0)
}

/// ResNet50 v1.5: same parameters, stride moved to the 3x3 conv
/// (~4.3 GFLOPs/image forward, a few percent slower in img/s).
pub fn resnet50_v15() -> Arch {
    resnet50_variant("resnet50_v1.5", true, 340.0)
}

/// Basic residual block (ResNet-18/34): two 3x3 convs.
fn basic_block(
    b: ArchBuilder,
    name: &str,
    width: usize,
    stride: usize,
    downsample: bool,
) -> ArchBuilder {
    let (h, w, c_in) = b.shape();
    let mut b = b
        .conv(&format!("{name}.conv1"), width, 3, stride, 1, false)
        .bn(&format!("{name}.bn1"))
        .relu(&format!("{name}.relu1"))
        .conv(&format!("{name}.conv2"), width, 3, 1, 1, false)
        .bn(&format!("{name}.bn2"));
    if downsample {
        let side = ArchBuilder::new("side", h, w, c_in)
            .conv(&format!("{name}.downsample.conv"), width, 1, stride, 0, false)
            .bn(&format!("{name}.downsample.bn"));
        b = b.absorb(side.build(0.0).layers);
    }
    b.relu(&format!("{name}.relu2"))
}

/// Generic torchvision-style ResNet with basic blocks (18/34).
fn resnet_basic(name: &str, blocks: [usize; 4], ref_ips: f64) -> Arch {
    let mut b = ArchBuilder::new(name, 224, 224, 3)
        .conv("stem.conv", 64, 7, 2, 3, false)
        .bn("stem.bn")
        .relu("stem.relu")
        .pool("stem.maxpool", 3, 2, 1);
    let widths = [64usize, 128, 256, 512];
    for (si, (&width, &count)) in widths.iter().zip(&blocks).enumerate() {
        for blk in 0..count {
            let stride = if si > 0 && blk == 0 { 2 } else { 1 };
            let ds = blk == 0 && (si > 0 || width != 64);
            b = basic_block(b, &format!("layer{}.{}", si + 1, blk), width, stride, ds);
        }
    }
    b.global_pool("avgpool").fc("fc", 1000).build(ref_ips)
}

/// Generic bottleneck ResNet of any depth (50/101/152 share the recipe).
fn resnet_bottleneck(name: &str, blocks: [usize; 4], ref_ips: f64) -> Arch {
    let mut b = ArchBuilder::new(name, 224, 224, 3)
        .conv("stem.conv", 64, 7, 2, 3, false)
        .bn("stem.bn")
        .relu("stem.relu")
        .pool("stem.maxpool", 3, 2, 1);
    let widths = [64usize, 128, 256, 512];
    for (si, (&width, &count)) in widths.iter().zip(&blocks).enumerate() {
        for blk in 0..count {
            let stride = if si > 0 && blk == 0 { 2 } else { 1 };
            b = bottleneck(
                b,
                &format!("layer{}.{}", si + 1, blk),
                width,
                stride,
                blk == 0,
                true, // v1.5-style stride placement (torchvision)
            );
        }
    }
    b.global_pool("avgpool").fc("fc", 1000).build(ref_ips)
}

/// ResNet18: 11,689,512 parameters.
pub fn resnet18() -> Arch {
    resnet_basic("resnet18", [2, 2, 2, 2], 1600.0)
}

/// ResNet34: 21,797,672 parameters.
pub fn resnet34() -> Arch {
    resnet_basic("resnet34", [3, 4, 6, 3], 900.0)
}

/// ResNet101: 44,549,160 parameters.
pub fn resnet101() -> Arch {
    resnet_bottleneck("resnet101", [3, 4, 23, 3], 210.0)
}

/// ResNet152: 60,192,808 parameters.
pub fn resnet152() -> Arch {
    resnet_bottleneck("resnet152", [3, 8, 36, 3], 145.0)
}

/// Inception v3 (299x299): ~23.8 M parameters (torchvision, no aux head).
pub fn inception_v3() -> Arch {
    // Helper: a conv-bn-relu unit appended to a detached builder.
    fn unit(
        h: usize,
        w: usize,
        c: usize,
        out_c: usize,
        k: (usize, usize),
        stride: usize,
        pad: (usize, usize),
        name: &str,
    ) -> (Vec<Layer>, (usize, usize, usize)) {
        let b = ArchBuilder::new("u", h, w, c)
            .conv_rect(name, out_c, k, stride, pad, false)
            .bn(&format!("{name}.bn"))
            .relu(&format!("{name}.relu"));
        let shape = b.shape();
        (b.build(0.0).layers, shape)
    }

    let mut layers: Vec<Layer> = Vec::new();
    // Stem.
    let (ls, s) = unit(299, 299, 3, 32, (3, 3), 2, (0, 0), "Conv2d_1a");
    layers.extend(ls);
    let (ls, s) = unit(s.0, s.1, s.2, 32, (3, 3), 1, (0, 0), "Conv2d_2a");
    layers.extend(ls);
    let (ls, s) = unit(s.0, s.1, s.2, 64, (3, 3), 1, (1, 1), "Conv2d_2b");
    layers.extend(ls);
    // maxpool 3/2
    // maxpool 3/2: 147 -> 73
    let (mut h, mut w, mut c);
    h = (s.0 - 3) / 2 + 1;
    w = (s.1 - 3) / 2 + 1;
    c = s.2;
    let (ls, s) = unit(h, w, c, 80, (1, 1), 1, (0, 0), "Conv2d_3b");
    layers.extend(ls);
    let (ls, s) = unit(s.0, s.1, s.2, 192, (3, 3), 1, (0, 0), "Conv2d_4a");
    layers.extend(ls);
    h = (s.0 - 3) / 2 + 1;
    w = (s.1 - 3) / 2 + 1;
    c = s.2; // 35x35x192

    // Inception-A blocks (x3): branches 1x1(64), 5x5(48->64),
    // 3x3dbl(64->96->96), pool-proj(32/64/64).
    for (i, pool_c) in [32usize, 64, 64].iter().enumerate() {
        let n = format!("Mixed_5{}", (b'b' + i as u8) as char);
        let mut out = 0;
        let (ls, _) = unit(h, w, c, 64, (1, 1), 1, (0, 0), &format!("{n}.b1x1"));
        layers.extend(ls);
        out += 64;
        let (ls, s2) = unit(h, w, c, 48, (1, 1), 1, (0, 0), &format!("{n}.b5x5_1"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 64, (5, 5), 1, (2, 2), &format!("{n}.b5x5_2"));
        layers.extend(ls);
        out += 64;
        let (ls, s2) = unit(h, w, c, 64, (1, 1), 1, (0, 0), &format!("{n}.b3x3dbl_1"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, 96, (3, 3), 1, (1, 1), &format!("{n}.b3x3dbl_2"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 96, (3, 3), 1, (1, 1), &format!("{n}.b3x3dbl_3"));
        layers.extend(ls);
        out += 96;
        let (ls, _) = unit(h, w, c, *pool_c, (1, 1), 1, (0, 0), &format!("{n}.bpool"));
        layers.extend(ls);
        out += pool_c;
        c = out; // 256 / 288 / 288
    }

    // Reduction-A (Mixed_6a): 3x3(384)/2 + 3x3dbl(64->96->96/2) + maxpool.
    {
        let n = "Mixed_6a";
        let (ls, s1) = unit(h, w, c, 384, (3, 3), 2, (0, 0), &format!("{n}.b3x3"));
        layers.extend(ls);
        let (ls, s2) = unit(h, w, c, 64, (1, 1), 1, (0, 0), &format!("{n}.b3x3dbl_1"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, 96, (3, 3), 1, (1, 1), &format!("{n}.b3x3dbl_2"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 96, (3, 3), 2, (0, 0), &format!("{n}.b3x3dbl_3"));
        layers.extend(ls);
        h = s1.0;
        w = s1.1;
        c = 384 + 96 + c; // + pooled passthrough (17x17x768)
    }

    // Inception-B blocks (x4) with 7x7 factorization; channel args
    // 128,160,160,192.
    for (i, &mid) in [128usize, 160, 160, 192].iter().enumerate() {
        let n = format!("Mixed_6{}", (b'b' + i as u8) as char);
        let mut out = 0;
        let (ls, _) = unit(h, w, c, 192, (1, 1), 1, (0, 0), &format!("{n}.b1x1"));
        layers.extend(ls);
        out += 192;
        // 1x1 -> 1x7 -> 7x1
        let (ls, s2) = unit(h, w, c, mid, (1, 1), 1, (0, 0), &format!("{n}.b7_1"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, mid, (1, 7), 1, (0, 3), &format!("{n}.b7_2"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 192, (7, 1), 1, (3, 0), &format!("{n}.b7_3"));
        layers.extend(ls);
        out += 192;
        // double 7x7
        let (ls, s2) = unit(h, w, c, mid, (1, 1), 1, (0, 0), &format!("{n}.b7dbl_1"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, mid, (7, 1), 1, (3, 0), &format!("{n}.b7dbl_2"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, mid, (1, 7), 1, (0, 3), &format!("{n}.b7dbl_3"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, mid, (7, 1), 1, (3, 0), &format!("{n}.b7dbl_4"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 192, (1, 7), 1, (0, 3), &format!("{n}.b7dbl_5"));
        layers.extend(ls);
        out += 192;
        let (ls, _) = unit(h, w, c, 192, (1, 1), 1, (0, 0), &format!("{n}.bpool"));
        layers.extend(ls);
        out += 192;
        c = out; // 768
    }

    // Reduction-B (Mixed_7a).
    {
        let n = "Mixed_7a";
        let (ls, s2) = unit(h, w, c, 192, (1, 1), 1, (0, 0), &format!("{n}.b3x3_1"));
        layers.extend(ls);
        let (ls, s1) = unit(s2.0, s2.1, s2.2, 320, (3, 3), 2, (0, 0), &format!("{n}.b3x3_2"));
        layers.extend(ls);
        let (ls, s2) = unit(h, w, c, 192, (1, 1), 1, (0, 0), &format!("{n}.b7x7_1"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, 192, (1, 7), 1, (0, 3), &format!("{n}.b7x7_2"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, 192, (7, 1), 1, (3, 0), &format!("{n}.b7x7_3"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 192, (3, 3), 2, (0, 0), &format!("{n}.b7x7_4"));
        layers.extend(ls);
        h = s1.0;
        w = s1.1;
        c = 320 + 192 + c; // 8x8x1280
    }

    // Inception-C blocks (x2, Mixed_7b/7c).
    for i in 0..2 {
        let n = format!("Mixed_7{}", (b'b' + i as u8) as char);
        let mut out = 0;
        let (ls, _) = unit(h, w, c, 320, (1, 1), 1, (0, 0), &format!("{n}.b1x1"));
        layers.extend(ls);
        out += 320;
        // 3x3 branch: 1x1(384) -> {1x3, 3x1} concat.
        let (ls, s2) = unit(h, w, c, 384, (1, 1), 1, (0, 0), &format!("{n}.b3x3_1"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 384, (1, 3), 1, (0, 1), &format!("{n}.b3x3_2a"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 384, (3, 1), 1, (1, 0), &format!("{n}.b3x3_2b"));
        layers.extend(ls);
        out += 768;
        // dbl branch: 1x1(448) -> 3x3(384) -> {1x3, 3x1}.
        let (ls, s2) = unit(h, w, c, 448, (1, 1), 1, (0, 0), &format!("{n}.b3x3dbl_1"));
        layers.extend(ls);
        let (ls, s2) = unit(s2.0, s2.1, s2.2, 384, (3, 3), 1, (1, 1), &format!("{n}.b3x3dbl_2"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 384, (1, 3), 1, (0, 1), &format!("{n}.b3x3dbl_3a"));
        layers.extend(ls);
        let (ls, _) = unit(s2.0, s2.1, s2.2, 384, (3, 1), 1, (1, 0), &format!("{n}.b3x3dbl_3b"));
        layers.extend(ls);
        out += 768;
        let (ls, _) = unit(h, w, c, 192, (1, 1), 1, (0, 0), &format!("{n}.bpool"));
        layers.extend(ls);
        out += 192;
        c = out; // 2048
    }

    let mut b = ArchBuilder::new("inception_v3", h, w, 0).set_channels(c);
    b = b.absorb(layers);
    b.global_pool("avgpool").fc("fc", 1000).build(240.0)
}

/// The four models of Figs 4-5, in paper display order.
pub fn paper_models() -> Vec<Arch> {
    vec![resnet50(), resnet50_v15(), vgg16(), inception_v3()]
}

/// Look up by CLI name.
pub fn by_name(name: &str) -> Option<Arch> {
    match name.to_ascii_lowercase().as_str() {
        "resnet50" | "rn50" => Some(resnet50()),
        "resnet50_v1.5" | "resnet50_v15" | "rn50v15" => Some(resnet50_v15()),
        "vgg16" => Some(vgg16()),
        "inception_v3" | "inceptionv3" => Some(inception_v3()),
        "alexnet" => Some(alexnet()),
        "resnet18" => Some(resnet18()),
        "resnet34" => Some(resnet34()),
        "resnet101" => Some(resnet101()),
        "resnet152" => Some(resnet152()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(got: f64, want: f64, tol: f64) -> bool {
        (got - want).abs() / want <= tol
    }

    #[test]
    fn vgg16_param_count_exact() {
        assert_eq!(vgg16().total_params(), 138_357_544);
    }

    #[test]
    fn alexnet_param_count_exact() {
        assert_eq!(alexnet().total_params(), 61_100_840);
    }

    #[test]
    fn resnet50_param_count_exact() {
        assert_eq!(resnet50().total_params(), 25_557_032);
    }

    #[test]
    fn resnet50_variants_share_params() {
        assert_eq!(resnet50().total_params(), resnet50_v15().total_params());
    }

    #[test]
    fn resnet50_v15_more_flops() {
        let v1 = resnet50().flops_fwd_per_image();
        let v15 = resnet50_v15().flops_fwd_per_image();
        assert!(v15 > 1.05 * v1, "v1.5 {v15:.3e} !> v1 {v1:.3e}");
        // Published: ~3.86 vs ~4.3 GFLOPs forward (2*MACs).
        assert!(close(v1, 2.0 * 3.86e9, 0.10), "v1 flops {v1:.3e}");
    }

    #[test]
    fn inception_v3_params_close_to_published() {
        let p = inception_v3().total_params() as f64;
        // torchvision (no aux): 23.8 M. Allow 5% for head/count conventions.
        assert!(close(p, 23.8e6, 0.05), "inception params {p}");
    }

    #[test]
    fn vgg16_flops_close_to_published() {
        let f = vgg16().flops_fwd_per_image();
        assert!(close(f, 2.0 * 15.47e9, 0.08), "vgg16 flops {f:.3e}");
    }

    #[test]
    fn alexnet_flops_close_to_published() {
        let f = alexnet().flops_fwd_per_image();
        assert!(close(f, 2.0 * 0.71e9, 0.15), "alexnet flops {f:.3e}");
    }

    #[test]
    fn gradient_bytes_match_params() {
        for a in paper_models() {
            assert_eq!(a.gradient_bytes(), a.total_params() as f64 * 4.0);
            let per_tensor: f64 = a.gradient_tensor_bytes().iter().sum();
            assert!((per_tensor - a.gradient_bytes()).abs() < 1.0);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("resnet50").is_some());
        assert!(by_name("VGG16").is_some());
        assert!(by_name("resnet152").is_some());
        assert!(by_name("resnet999").is_none());
    }

    #[test]
    fn resnet18_param_count_exact() {
        assert_eq!(resnet18().total_params(), 11_689_512);
    }

    #[test]
    fn resnet34_param_count_exact() {
        assert_eq!(resnet34().total_params(), 21_797_672);
    }

    #[test]
    fn resnet101_param_count_exact() {
        assert_eq!(resnet101().total_params(), 44_549_160);
    }

    #[test]
    fn resnet152_param_count_exact() {
        assert_eq!(resnet152().total_params(), 60_192_808);
    }

    #[test]
    fn resnet_family_flops_ordering() {
        let f18 = resnet18().flops_fwd_per_image();
        let f34 = resnet34().flops_fwd_per_image();
        let f50 = resnet50_v15().flops_fwd_per_image();
        let f101 = resnet101().flops_fwd_per_image();
        let f152 = resnet152().flops_fwd_per_image();
        assert!(f18 < f34 && f34 < f50 && f50 < f101 && f101 < f152);
    }
}
