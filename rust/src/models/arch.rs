//! Architecture descriptions: a small layer algebra that tracks spatial
//! shape, trainable parameters and forward FLOPs per image. Gradient
//! tensor sizes (what the all-reduce actually moves) fall out of the same
//! description.

/// One trainable (or shape-changing) layer.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Trainable parameters.
    pub params: u64,
    /// Forward FLOPs per image (1 multiply-add = 2 FLOPs).
    pub flops_fwd: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    Conv2d,
    Fc,
    BatchNorm,
    Pool,
    Act,
}

/// A full architecture with its running shape already resolved.
#[derive(Clone, Debug)]
pub struct Arch {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Reference single-V100 fp32 throughput (img/s) used to calibrate the
    /// efficiency ratio (public tf_cnn_benchmarks numbers; DESIGN.md §6).
    pub v100_fp32_images_per_sec: f64,
}

impl Arch {
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    pub fn flops_fwd_per_image(&self) -> f64 {
        self.layers.iter().map(|l| l.flops_fwd).sum()
    }

    /// Bytes of gradients all-reduced per step (fp32 wire format).
    pub fn gradient_bytes(&self) -> f64 {
        self.total_params() as f64 * 4.0
    }

    /// Per-tensor gradient sizes in forward order (for the fusion buffer).
    pub fn gradient_tensor_bytes(&self) -> Vec<f64> {
        self.layers
            .iter()
            .filter(|l| l.params > 0)
            .map(|l| l.params as f64 * 4.0)
            .collect()
    }
}

/// Builder that threads the activation shape through the network.
pub struct ArchBuilder {
    name: String,
    h: usize,
    w: usize,
    c: usize,
    layers: Vec<Layer>,
}

impl ArchBuilder {
    pub fn new(name: &str, h: usize, w: usize, c: usize) -> Self {
        ArchBuilder { name: name.to_string(), h, w, c, layers: Vec::new() }
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.h, self.w, self.c)
    }

    fn out_dim(dim: usize, k: usize, stride: usize, pad: usize) -> usize {
        (dim + 2 * pad - k) / stride + 1
    }

    /// Convolution; `bias` toggles a bias vector (ResNet-style convs have
    /// none, classic VGG/AlexNet convs do).
    pub fn conv(
        self,
        name: &str,
        out_c: usize,
        k: usize,
        stride: usize,
        pad: usize,
        bias: bool,
    ) -> Self {
        self.conv_rect(name, out_c, (k, k), stride, (pad, pad), bias)
    }

    /// Rectangular-kernel convolution (Inception's 1x7 / 7x1 factorization).
    pub fn conv_rect(
        mut self,
        name: &str,
        out_c: usize,
        k: (usize, usize),
        stride: usize,
        pad: (usize, usize),
        bias: bool,
    ) -> Self {
        let oh = Self::out_dim(self.h, k.0, stride, pad.0);
        let ow = Self::out_dim(self.w, k.1, stride, pad.1);
        let weights = (k.0 * k.1 * self.c * out_c) as u64;
        let params = weights + if bias { out_c as u64 } else { 0 };
        let flops = 2.0 * (k.0 * k.1 * self.c) as f64 * (out_c * oh * ow) as f64;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv2d,
            params,
            flops_fwd: flops,
        });
        self.h = oh;
        self.w = ow;
        self.c = out_c;
        self
    }

    /// Batch norm over the current channel count (gamma + beta trainable).
    pub fn bn(mut self, name: &str) -> Self {
        let c = self.c;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::BatchNorm,
            params: 2 * c as u64,
            // Normalize + scale + shift: ~4 FLOPs/element.
            flops_fwd: 4.0 * (self.h * self.w * c) as f64,
        });
        self
    }

    pub fn relu(mut self, name: &str) -> Self {
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Act,
            params: 0,
            flops_fwd: (self.h * self.w * self.c) as f64,
        });
        self
    }

    pub fn pool(mut self, name: &str, k: usize, stride: usize, pad: usize) -> Self {
        let oh = Self::out_dim(self.h, k, stride, pad);
        let ow = Self::out_dim(self.w, k, stride, pad);
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Pool,
            params: 0,
            flops_fwd: ((k * k) as f64) * (oh * ow * self.c) as f64,
        });
        self.h = oh;
        self.w = ow;
        self
    }

    /// Global average pool to 1x1.
    pub fn global_pool(mut self, name: &str) -> Self {
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Pool,
            params: 0,
            flops_fwd: (self.h * self.w * self.c) as f64,
        });
        self.h = 1;
        self.w = 1;
        self
    }

    /// Flatten + fully-connected (with bias).
    pub fn fc(mut self, name: &str, out: usize) -> Self {
        let inp = self.h * self.w * self.c;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Fc,
            params: (inp * out + out) as u64,
            flops_fwd: 2.0 * (inp * out) as f64,
        });
        self.h = 1;
        self.w = 1;
        self.c = out;
        self
    }

    /// Override the running channel count (after a concat of parallel
    /// branches built separately).
    pub fn set_channels(mut self, c: usize) -> Self {
        self.c = c;
        self
    }

    /// Merge layers built for a parallel branch (shape bookkeeping is the
    /// caller's responsibility via `set_channels`).
    pub fn absorb(mut self, layers: Vec<Layer>) -> Self {
        self.layers.extend(layers);
        self
    }

    pub fn build(self, v100_fp32_images_per_sec: f64) -> Arch {
        Arch { name: self.name, layers: self.layers, v100_fp32_images_per_sec }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_and_params() {
        // 224x224x3, 7x7/2 pad 3 -> 112x112x64 (the ResNet stem).
        let b = ArchBuilder::new("t", 224, 224, 3).conv("stem", 64, 7, 2, 3, false);
        assert_eq!(b.shape(), (112, 112, 64));
        let l = &b.layers[0];
        assert_eq!(l.params, 7 * 7 * 3 * 64);
        let expected_flops = 2.0 * (7.0 * 7.0 * 3.0) * (64.0 * 112.0 * 112.0);
        assert!((l.flops_fwd - expected_flops).abs() < 1.0);
    }

    #[test]
    fn fc_params() {
        let b = ArchBuilder::new("t", 1, 1, 2048).fc("fc", 1000);
        assert_eq!(b.layers[0].params, 2048 * 1000 + 1000);
    }

    #[test]
    fn pool_halves() {
        let b = ArchBuilder::new("t", 112, 112, 64).pool("p", 3, 2, 1);
        assert_eq!(b.shape(), (56, 56, 64));
    }

    #[test]
    fn gradient_tensors_skip_paramless_layers() {
        let a = ArchBuilder::new("t", 8, 8, 3)
            .conv("c", 4, 3, 1, 1, true)
            .relu("r")
            .fc("f", 10)
            .build(100.0);
        assert_eq!(a.gradient_tensor_bytes().len(), 2);
        assert_eq!(a.gradient_bytes(), a.total_params() as f64 * 4.0);
    }

    #[test]
    fn bias_toggle() {
        let with = ArchBuilder::new("t", 8, 8, 3).conv("c", 4, 3, 1, 1, true);
        let without = ArchBuilder::new("t", 8, 8, 3).conv("c", 4, 3, 1, 1, false);
        assert_eq!(with.layers[0].params - without.layers[0].params, 4);
    }
}
