//! GPU step-time performance model.
//!
//! Compute time = FLOPs / (peak x efficiency), where the efficiency ratio
//! is derived from the architecture's *published* single-V100 fp32
//! throughput (tf_cnn_benchmarks) — i.e. we calibrate the model once
//! against known data and then let it extrapolate across batch sizes,
//! precisions and (for Table I) historical GPUs. The same method applied
//! to this machine's real PJRT runs lives in [`crate::calibrate`].

use super::arch::Arch;
use crate::cluster::gpu::GpuModel;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    /// Mixed precision (fp16 math, fp32 master weights).
    Mixed,
}

/// Decomposed per-step cost for one GPU.
#[derive(Clone, Copy, Debug)]
pub struct StepCost {
    /// Forward pass, seconds.
    pub fwd: f64,
    /// Backward pass, seconds (~2x forward).
    pub bwd: f64,
    /// Optimizer update (3 HBM passes over the parameters), seconds.
    pub optimizer: f64,
}

impl StepCost {
    pub fn total(&self) -> f64 {
        self.fwd + self.bwd + self.optimizer
    }
}

/// Backward/forward FLOP ratio (dL/dX and dL/dW each cost ~1 forward).
pub const BWD_OVER_FWD: f64 = 2.0;

/// Efficiency ratio achieved by `arch` on a V100 at fp32, inferred from
/// its published throughput.
pub fn v100_efficiency(arch: &Arch) -> f64 {
    let flops_per_image = arch.flops_fwd_per_image() * (1.0 + BWD_OVER_FWD);
    let v100_peak = crate::cluster::gpu::V100.peak_fp32;
    (flops_per_image * arch.v100_fp32_images_per_sec) / v100_peak
}

/// Per-step compute cost for `batch` images on `gpu`.
///
/// `efficiency_override` replaces the calibrated V100 ratio (used by
/// Table I's historical rows, where period frameworks reached a fraction
/// of today's utilization, and by the calibration path).
pub fn step_cost(
    arch: &Arch,
    gpu: &GpuModel,
    batch: usize,
    precision: Precision,
    efficiency_override: Option<f64>,
) -> StepCost {
    let eff = efficiency_override.unwrap_or_else(|| v100_efficiency(arch));
    let peak = match precision {
        Precision::Fp32 => gpu.peak_fp32,
        // Mixed precision rarely achieves the full tensor-core ratio;
        // empirical speedups are ~2-3x. Model: min(fp16 peak, 3x fp32).
        Precision::Mixed => gpu.peak_fp16.min(3.0 * gpu.peak_fp32),
    };
    let sustained = peak * eff;
    let fwd_flops = arch.flops_fwd_per_image() * batch as f64;
    let fwd = fwd_flops / sustained;
    let bwd = fwd * BWD_OVER_FWD;
    // SGD w/ momentum: read p, read g, read m, write p, write m ~ 5 passes
    // of 4 bytes per parameter through HBM.
    let optimizer = 5.0 * 4.0 * arch.total_params() as f64 / gpu.mem_bw;
    StepCost { fwd, bwd, optimizer }
}

/// Single-GPU throughput implied by the model (sanity: reproduces the
/// calibration input for a V100 at fp32).
pub fn images_per_sec(arch: &Arch, gpu: &GpuModel, batch: usize, precision: Precision) -> f64 {
    batch as f64 / step_cost(arch, gpu, batch, precision, None).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::{P100, V100};
    use crate::models::zoo::{paper_models, resnet50, vgg16};

    #[test]
    fn calibration_roundtrip() {
        // The model must reproduce its own calibration datum (up to the
        // small optimizer term).
        for arch in paper_models() {
            let ips = images_per_sec(&arch, &V100, 64, Precision::Fp32);
            let want = arch.v100_fp32_images_per_sec;
            assert!(
                (ips - want).abs() / want < 0.05,
                "{}: {ips} vs {want}",
                arch.name
            );
        }
    }

    #[test]
    fn efficiency_ratios_plausible() {
        for arch in paper_models() {
            let e = v100_efficiency(&arch);
            assert!((0.1..0.9).contains(&e), "{}: efficiency {e}", arch.name);
        }
    }

    #[test]
    fn mixed_precision_faster() {
        let arch = resnet50();
        let fp32 = images_per_sec(&arch, &V100, 64, Precision::Fp32);
        let amp = images_per_sec(&arch, &V100, 64, Precision::Mixed);
        assert!(amp > 1.5 * fp32);
    }

    #[test]
    fn older_gpu_slower() {
        let arch = vgg16();
        let v100 = images_per_sec(&arch, &V100, 32, Precision::Fp32);
        let p100 = images_per_sec(&arch, &P100, 32, Precision::Fp32);
        assert!(p100 < v100);
        // Ratio tracks peak ratio.
        let ratio = v100 / p100;
        let peak_ratio = V100.peak_fp32 / P100.peak_fp32;
        assert!((ratio - peak_ratio).abs() / peak_ratio < 0.1);
    }

    #[test]
    fn step_cost_scales_linearly_with_batch() {
        let arch = resnet50();
        let c1 = step_cost(&arch, &V100, 32, Precision::Fp32, None);
        let c2 = step_cost(&arch, &V100, 64, Precision::Fp32, None);
        assert!(((c2.fwd + c2.bwd) / (c1.fwd + c1.bwd) - 2.0).abs() < 1e-9);
        assert_eq!(c1.optimizer, c2.optimizer);
    }

    #[test]
    fn efficiency_override_respected() {
        let arch = resnet50();
        let half = step_cost(&arch, &V100, 64, Precision::Fp32, Some(0.15));
        let full = step_cost(&arch, &V100, 64, Precision::Fp32, Some(0.30));
        assert!((half.fwd / full.fwd - 2.0).abs() < 1e-9);
    }
}
