//! 3-D periodic Cartesian mesh partitioning (CartDG partitions into
//! identical blocks so every rank has the same compute and communication
//! pattern — §III.B of the paper).

/// The paper's problem: a 32x32x32 element mesh, DG order p=7 (8^3 nodes
/// per element), 5 conserved fields = 83,886,080 unknowns.
pub const PAPER_MESH: (usize, usize, usize) = (32, 32, 32);
pub const DG_NODES_1D: usize = 8;
pub const FIELDS: usize = 5;

/// Unknowns for a mesh (sanity-checked against the paper's number).
pub fn unknowns(mesh: (usize, usize, usize)) -> u64 {
    (mesh.0 * mesh.1 * mesh.2) as u64 * (DG_NODES_1D * DG_NODES_1D * DG_NODES_1D * FIELDS) as u64
}

/// Near-cubic factorization of `p` into (px, py, pz), px >= py >= pz,
/// minimizing surface area (communication volume).
pub fn factor3(p: usize) -> (usize, usize, usize) {
    assert!(p > 0);
    let mut best = (p, 1, 1);
    let mut best_score = f64::INFINITY;
    let mut i = 1;
    while i * i * i <= p {
        if p % i == 0 {
            let q = p / i;
            let mut j = i;
            while j * j <= q {
                if q % j == 0 {
                    let k = q / j;
                    // dims (k >= j >= i); score = surface of unit-volume box.
                    let (a, b, c) = (k as f64, j as f64, i as f64);
                    let score = a * b + b * c + a * c;
                    if score < best_score {
                        best_score = score;
                        best = (k, j, i);
                    }
                }
                j += 1;
            }
        }
        i += 1;
    }
    best
}

/// A partition of the periodic mesh over `ranks` MPI ranks arranged in a
/// 3-D grid.
#[derive(Clone, Debug)]
pub struct MeshPartition {
    pub mesh: (usize, usize, usize),
    pub grid: (usize, usize, usize),
    pub ranks: usize,
}

impl MeshPartition {
    pub fn new(mesh: (usize, usize, usize), ranks: usize) -> Self {
        MeshPartition { mesh, grid: factor3(ranks), ranks }
    }

    /// Elements per rank along each axis (ceiling division — the paper
    /// kept blocks identical; we keep the max for the critical path).
    pub fn block_dims(&self) -> (usize, usize, usize) {
        (
            self.mesh.0.div_ceil(self.grid.0),
            self.mesh.1.div_ceil(self.grid.1),
            self.mesh.2.div_ceil(self.grid.2),
        )
    }

    pub fn elems_per_rank(&self) -> usize {
        let b = self.block_dims();
        b.0 * b.1 * b.2
    }

    /// Rank id from grid coordinates (x fastest).
    pub fn rank_of(&self, x: usize, y: usize, z: usize) -> usize {
        (z * self.grid.1 + y) * self.grid.0 + x
    }

    pub fn coords_of(&self, rank: usize) -> (usize, usize, usize) {
        let x = rank % self.grid.0;
        let y = (rank / self.grid.0) % self.grid.1;
        let z = rank / (self.grid.0 * self.grid.1);
        (x, y, z)
    }

    /// The six periodic face neighbors of `rank` with the face-message
    /// size in *elements* (face area of the block in the exchanged
    /// direction). Self-neighbors (grid dim 1) are skipped.
    pub fn neighbors(&self, rank: usize) -> Vec<(usize, usize)> {
        let (x, y, z) = self.coords_of(rank);
        let (gx, gy, gz) = self.grid;
        let b = self.block_dims();
        let faces = [
            ((x + gx - 1) % gx, y, z, b.1 * b.2),
            ((x + 1) % gx, y, z, b.1 * b.2),
            (x, (y + gy - 1) % gy, z, b.0 * b.2),
            (x, (y + 1) % gy, z, b.0 * b.2),
            (x, y, (z + gz - 1) % gz, b.0 * b.1),
            (x, y, (z + 1) % gz, b.0 * b.1),
        ];
        faces
            .into_iter()
            .filter_map(|(nx, ny, nz, area)| {
                let n = self.rank_of(nx, ny, nz);
                (n != rank).then_some((n, area))
            })
            .collect()
    }

    /// Bytes per face-element message: one face of DG nodes x fields x f64.
    pub fn face_bytes_per_elem() -> f64 {
        (DG_NODES_1D * DG_NODES_1D * FIELDS * 8) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn paper_unknowns_exact() {
        assert_eq!(unknowns(PAPER_MESH), 83_886_080);
    }

    #[test]
    fn factor3_balanced() {
        assert_eq!(factor3(8), (2, 2, 2));
        assert_eq!(factor3(64), (4, 4, 4));
        let (a, b, c) = factor3(40);
        assert_eq!(a * b * c, 40);
        assert!(a >= b && b >= c);
        // 40 = 5*4*2 is the most cubic factorization.
        assert_eq!((a, b, c), (5, 4, 2));
    }

    #[test]
    fn factor3_primes_degenerate() {
        assert_eq!(factor3(13), (13, 1, 1));
        assert_eq!(factor3(1), (1, 1, 1));
    }

    #[test]
    fn rank_coord_roundtrip() {
        let part = MeshPartition::new(PAPER_MESH, 40);
        for r in 0..40 {
            let (x, y, z) = part.coords_of(r);
            assert_eq!(part.rank_of(x, y, z), r);
        }
    }

    #[test]
    fn neighbors_symmetric() {
        let part = MeshPartition::new(PAPER_MESH, 64);
        for r in 0..64 {
            for (n, _) in part.neighbors(r) {
                let back: Vec<usize> =
                    part.neighbors(n).iter().map(|&(m, _)| m).collect();
                assert!(back.contains(&r), "neighbor graph asymmetric at {r}<->{n}");
            }
        }
    }

    #[test]
    fn elems_per_rank_strong_scales() {
        let p1 = MeshPartition::new(PAPER_MESH, 64).elems_per_rank();
        let p2 = MeshPartition::new(PAPER_MESH, 512).elems_per_rank();
        assert_eq!(p1, 512);
        assert_eq!(p2, 64);
    }

    #[test]
    fn property_neighbor_count() {
        prop::forall(5, 64, |r| 1 + r.below(4096) as usize, |&p| {
            let part = MeshPartition::new(PAPER_MESH, p);
            let expect = {
                let (gx, gy, gz) = part.grid;
                2 * usize::from(gx > 1) + 2 * usize::from(gy > 1) + 2 * usize::from(gz > 1)
            };
            for r in [0, p / 2, p - 1] {
                let n = part.neighbors(r).len();
                // Periodic: with grid dim 2, both directions hit the same
                // neighbor, but they are still two distinct messages —
                // except our filter collapses self only. dim==2 gives the
                // same rank twice (kept, two faces).
                if n > expect || n == 0 && expect != 0 {
                    return Err(format!("p={p} rank={r}: {n} neighbors, expected <= {expect}"));
                }
            }
            Ok(())
        });
    }
}
