//! CartDG substrate: the paper's second benchmark is a Discontinuous-
//! Galerkin compressible Navier-Stokes solver (CartDG) strong-scaled over
//! CPU cores on both fabrics (Fig 3).
//!
//! We build (a) a **real miniature tensor-product DG kernel** — the
//! per-element operator CartDG's cost is dominated by — which runs on this
//! machine to ground the per-element compute cost, and (b) a mesh
//! partitioner + halo-exchange model that reproduces the strong-scaling
//! experiment on the simulated fabrics, including the rack-boundary
//! plateau the paper observed between 1,280 and 2,560 cores.

pub mod dg;
pub mod mesh;
pub mod solver;

pub use dg::DgKernel;
pub use mesh::MeshPartition;
pub use solver::{ScalingPoint, StrongScaling};
