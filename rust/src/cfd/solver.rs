//! CartDG strong-scaling driver (Fig 3): per-iteration compute and
//! communication time vs core count, per fabric.
//!
//! Compute: elements/rank x per-element cost. The per-element cost
//! defaults to the paper's reported efficiency (CartDG sustains >10% of
//! peak on tensor-product operators) applied to TX-GAIA's Xeon 6248
//! cores, and can be grounded with the *measured* cost of the real
//! [`super::dg::DgKernel`] on this machine.
//!
//! Communication: one halo exchange per RK stage — six periodic face
//! messages per rank over the simulated fabric with block placement
//! (40 cores/node, 32 nodes/rack). Inter-rack messages pay switch hops,
//! which is what produces the plateau between 1,280 and 2,560 cores.

use super::dg::DgKernel;
use super::mesh::MeshPartition;
use crate::cluster::Placement;
use crate::config::{ClusterSpec, FabricSpec, TransportOptions};
use crate::fabric::{Comm, NetSim};

/// Xeon Gold 6248 per-core peak (2.5 GHz x AVX-512 FMA = 80 GFLOP/s) and
/// the paper's ">10% of peak" sustained efficiency for CartDG.
pub const CORE_PEAK_FLOPS: f64 = 80.0e9;
pub const CARTDG_EFFICIENCY: f64 = 0.10;

/// The real [`DgKernel`] implements the tensor-product derivative core;
/// a full compressible Navier-Stokes RHS adds flux evaluations, the
/// equation of state and viscous terms on top — roughly an order of
/// magnitude more arithmetic per element (Kirby 2018).
pub const NS_PHYSICS_FACTOR: f64 = 10.0;

/// Fraction of a stage's compute absorbed as straggler wait in
/// MPI_Waitall (OS noise / per-core variation).
pub const IMBALANCE_FRACTION: f64 = 0.03;

/// One point on the strong-scaling curve.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub cores: usize,
    pub compute_time: f64,
    /// Measured (exposed) communication time: CartDG overlaps the halo
    /// exchange with interior compute, so the wire time hidden under the
    /// interior update never shows up in the profile — this is how the
    /// paper can observe near-identical comm times on a 25 Gb/s and a
    /// 100 Gb/s fabric (see DESIGN.md).
    pub comm_time: f64,
    /// Raw wire time of the halo exchange (no overlap), for reference.
    pub comm_wire_time: f64,
    pub elems_per_rank: usize,
    pub inter_rack_messages: u64,
}

impl ScalingPoint {
    pub fn total(&self) -> f64 {
        self.compute_time + self.comm_time
    }
}

/// Strong-scaling experiment configuration.
pub struct StrongScaling {
    pub mesh: (usize, usize, usize),
    pub cluster: ClusterSpec,
    /// Seconds per element per RHS evaluation.
    pub per_elem_seconds: f64,
    /// Runge-Kutta stages per iteration (halo exchange each stage).
    pub rk_stages: usize,
}

impl StrongScaling {
    /// Paper configuration with the analytic per-element cost.
    pub fn paper() -> Self {
        StrongScaling {
            mesh: super::mesh::PAPER_MESH,
            cluster: ClusterSpec::txgaia(),
            per_elem_seconds: NS_PHYSICS_FACTOR * DgKernel::flops_per_elem()
                / (CORE_PEAK_FLOPS * CARTDG_EFFICIENCY),
            rk_stages: 4,
        }
    }

    /// Ground the per-element cost with the real DG kernel measured on
    /// this machine (scaled by the same physics factor).
    pub fn with_measured_kernel(mut self) -> Self {
        let kernel = DgKernel::new();
        self.per_elem_seconds = NS_PHYSICS_FACTOR * kernel.measure_per_elem_seconds(32, 2);
        self
    }

    /// Simulate one iteration at `cores` ranks on `fabric`.
    pub fn run_point(&self, fabric: &FabricSpec, cores: usize) -> anyhow::Result<ScalingPoint> {
        let part = MeshPartition::new(self.mesh, cores);
        let placement = Placement::cores(&self.cluster, cores)?;
        let mut net =
            NetSim::try_new(fabric.clone(), self.cluster.clone(), TransportOptions::default())?;
        // All face messages of a stage form one event-engine batch below,
        // so per-NIC and per-uplink contention is observed, not estimated.

        let elems = part.elems_per_rank();
        let compute_time =
            self.rk_stages as f64 * elems as f64 * self.per_elem_seconds;

        // Halo exchange: all face messages of one stage form one round.
        let mut msgs: Vec<(usize, usize, f64)> = Vec::new();
        for r in 0..cores {
            for (n, face_elems) in part.neighbors(r) {
                let bytes = face_elems as f64 * MeshPartition::face_bytes_per_elem();
                msgs.push((r, n, bytes));
            }
        }
        let mut comm = Comm::new(&mut net, &placement);
        comm.round(&msgs);
        let wire_per_stage = comm.max_time();

        // Computation-communication overlap: CartDG posts non-blocking
        // halo sends and overlaps them with the stage's element updates
        // (that design is how it scaled to a million ranks on Mira). Wire
        // time up to one stage's compute window is hidden; what remains
        // exposed is the per-message MPI software overhead (pack, post,
        // wait, completion) plus any wire time exceeding the window.
        let interior_window = elems as f64 * self.per_elem_seconds;
        let msgs_per_rank = part.neighbors(0).len() as f64;
        let sync_overhead = msgs_per_rank
            * (fabric.per_msg_overhead + fabric.latency)
            // Inter-rack traffic pays the switch hops on the wait path.
            + if net.stats.inter_rack_messages > 0 { 2.0 * fabric.switch_hop_latency } else { 0.0 };
        // Straggler wait: MPI_Waitall also absorbs per-rank compute jitter
        // (OS noise, cache effects) — a few percent of the stage compute.
        // Fabric-independent, shrinks with strong scaling: this is the
        // dominant measured "communication time" at low core counts and
        // why the paper's comm bars decrease with scale identically on
        // both fabrics.
        let imbalance = IMBALANCE_FRACTION * interior_window;
        let exposed_per_stage =
            (wire_per_stage - interior_window).max(0.0) + sync_overhead + imbalance;

        Ok(ScalingPoint {
            cores,
            compute_time,
            comm_time: self.rk_stages as f64 * exposed_per_stage,
            comm_wire_time: self.rk_stages as f64 * wire_per_stage,
            elems_per_rank: elems,
            inter_rack_messages: net.stats.inter_rack_messages,
        })
    }

    /// Full strong-scaling sweep.
    pub fn sweep(
        &self,
        fabric: &FabricSpec,
        core_counts: &[usize],
    ) -> anyhow::Result<Vec<ScalingPoint>> {
        core_counts.iter().map(|&c| self.run_point(fabric, c)).collect()
    }

    /// The paper's core counts (40-core nodes, up to ~12.8k cores).
    pub fn paper_core_counts() -> Vec<usize> {
        vec![40, 80, 160, 320, 640, 1280, 2560, 5120, 10240, 12800]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::FabricKind;

    #[test]
    fn compute_strong_scales() {
        let s = StrongScaling::paper();
        let f = fabric(FabricKind::OmniPath100);
        let p40 = s.run_point(&f, 40).unwrap();
        let p640 = s.run_point(&f, 640).unwrap();
        let speedup = p40.compute_time / p640.compute_time;
        assert!(speedup > 10.0, "compute speedup {speedup} at 16x cores");
    }

    #[test]
    fn comm_time_nearly_identical_across_fabrics() {
        // The paper's headline CFD observation.
        let s = StrongScaling::paper();
        let eth = fabric(FabricKind::EthernetRoce25);
        let opa = fabric(FabricKind::OmniPath100);
        for cores in [160, 1280, 5120] {
            let te = s.run_point(&eth, cores).unwrap().comm_time;
            let to = s.run_point(&opa, cores).unwrap().comm_time;
            let ratio = te / to;
            assert!(
                (0.8..2.5).contains(&ratio),
                "cores={cores}: eth/opa comm ratio {ratio}"
            );
        }
    }

    #[test]
    fn rack_boundary_visible() {
        let s = StrongScaling::paper();
        let f = fabric(FabricKind::EthernetRoce25);
        let p1280 = s.run_point(&f, 1280).unwrap();
        let p2560 = s.run_point(&f, 2560).unwrap();
        // 1,280 cores = 32 nodes = one rack (no inter-rack traffic);
        // 2,560 cores = 2 racks.
        assert_eq!(p1280.inter_rack_messages, 0);
        assert!(p2560.inter_rack_messages > 0);
    }

    #[test]
    fn compute_dominates_at_low_core_counts() {
        let s = StrongScaling::paper();
        let f = fabric(FabricKind::OmniPath100);
        let p = s.run_point(&f, 40).unwrap();
        assert!(
            p.compute_time > 5.0 * p.comm_time,
            "compute {} comm {}",
            p.compute_time,
            p.comm_time
        );
    }

    #[test]
    fn measured_kernel_cost_same_order_as_model() {
        let model = StrongScaling::paper().per_elem_seconds;
        let measured = StrongScaling::paper().with_measured_kernel().per_elem_seconds;
        let ratio = measured / model;
        // This container's cores differ from Xeon 6248 + production flags;
        // same order of magnitude is the claim.
        assert!((0.05..50.0).contains(&ratio), "measured/model ratio {ratio}");
    }

    #[test]
    fn sweep_produces_monotone_elems() {
        let s = StrongScaling::paper();
        let f = fabric(FabricKind::OmniPath100);
        let pts = s.sweep(&f, &[40, 320, 2560]).unwrap();
        assert!(pts[0].elems_per_rank > pts[1].elems_per_rank);
        assert!(pts[1].elems_per_rank > pts[2].elems_per_rank);
    }
}
