//! A real miniature tensor-product DG kernel.
//!
//! CartDG's per-element cost is dominated by applying the 1-D collocation
//! differentiation matrix along each dimension of each field — a batch of
//! small dense matrix products. We implement exactly that (8x8 matrix,
//! 8^3 nodes, 5 fields), both to *be* the substrate (tests integrate an
//! actual advection step) and to measure a grounded per-element cost on
//! this machine for the scaling model.

use super::mesh::{DG_NODES_1D as N, FIELDS};

const N3: usize = N * N * N;

/// Differentiation matrix + element storage for one DG element.
pub struct DgKernel {
    /// 1-D differentiation matrix (row-major NxN). A real solver builds
    /// this from Gauss-Lobatto points; we use a skew-symmetric stencil
    /// that keeps the integration-by-parts structure.
    d: [f64; N * N],
}

impl Default for DgKernel {
    fn default() -> Self {
        Self::new()
    }
}

impl DgKernel {
    pub fn new() -> Self {
        let mut d = [0.0; N * N];
        // Central-difference-flavoured dense matrix with decaying
        // off-diagonal weights (spectral differentiation matrices are
        // dense; the exact entries don't change the FLOP count).
        for i in 0..N {
            for j in 0..N {
                if i != j {
                    let diff = i as f64 - j as f64;
                    d[i * N + j] = if (i + j) % 2 == 0 { 1.0 } else { -1.0 } / diff;
                }
            }
        }
        DgKernel { d }
    }

    /// FLOPs per element per derivative evaluation (3 dims x fields x
    /// matrix-apply): the number the scaling model uses.
    pub fn flops_per_elem() -> f64 {
        // Each dimension: N3 rows of length-N dot products, 2 FLOPs each.
        3.0 * FIELDS as f64 * (N3 * N) as f64 * 2.0
    }

    /// Apply d/dx, d/dy, d/dz to `u` (FIELDS x N^3, field-major) and
    /// accumulate into `out` (same layout): one advection RHS evaluation.
    pub fn rhs(&self, u: &[f64], out: &mut [f64]) {
        assert_eq!(u.len(), FIELDS * N3);
        assert_eq!(out.len(), FIELDS * N3);
        for f in 0..FIELDS {
            let uf = &u[f * N3..(f + 1) * N3];
            let of = &mut out[f * N3..(f + 1) * N3];
            // d/dx: contiguous fastest index.
            for z in 0..N {
                for y in 0..N {
                    let base = (z * N + y) * N;
                    for i in 0..N {
                        let mut acc = 0.0;
                        let drow = &self.d[i * N..(i + 1) * N];
                        for j in 0..N {
                            acc += drow[j] * uf[base + j];
                        }
                        of[base + i] = acc;
                    }
                }
            }
            // d/dy.
            for z in 0..N {
                for x in 0..N {
                    for i in 0..N {
                        let mut acc = 0.0;
                        for j in 0..N {
                            acc += self.d[i * N + j] * uf[(z * N + j) * N + x];
                        }
                        of[(z * N + i) * N + x] += acc;
                    }
                }
            }
            // d/dz.
            for y in 0..N {
                for x in 0..N {
                    for i in 0..N {
                        let mut acc = 0.0;
                        for j in 0..N {
                            acc += self.d[i * N + j] * uf[(j * N + y) * N + x];
                        }
                        of[(i * N + y) * N + x] += acc;
                    }
                }
            }
        }
    }

    /// Explicit Euler advection step over `elems` elements; returns the
    /// max |u| afterwards (so the work cannot be optimized away).
    pub fn step_elements(&self, u: &mut [f64], dt: f64) -> f64 {
        assert_eq!(u.len() % (FIELDS * N3), 0);
        let elems = u.len() / (FIELDS * N3);
        let mut rhs = vec![0.0; FIELDS * N3];
        let mut maxabs = 0.0f64;
        for e in 0..elems {
            let ue = &mut u[e * FIELDS * N3..(e + 1) * FIELDS * N3];
            rhs.iter_mut().for_each(|r| *r = 0.0);
            self.rhs(ue, &mut rhs);
            for (x, r) in ue.iter_mut().zip(&rhs) {
                *x -= dt * r;
                maxabs = maxabs.max(x.abs());
            }
        }
        maxabs
    }

    /// Measure the per-element wall time of the real kernel on this
    /// machine (used to ground the Fig 3 compute-time scale).
    pub fn measure_per_elem_seconds(&self, elems: usize, iters: usize) -> f64 {
        let mut u = vec![0.0f64; elems * FIELDS * N3];
        for (i, x) in u.iter_mut().enumerate() {
            *x = ((i % 97) as f64 - 48.0) / 97.0;
        }
        let start = std::time::Instant::now();
        let mut sink = 0.0;
        for _ in 0..iters {
            sink += self.step_elements(&mut u, 1e-6);
        }
        let dt = start.elapsed().as_secs_f64();
        std::hint::black_box(sink);
        dt / (elems * iters) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derivative_of_constant_is_zero() {
        let k = DgKernel::new();
        let u = vec![3.5; FIELDS * N3];
        let mut out = vec![0.0; FIELDS * N3];
        k.rhs(&u, &mut out);
        // Skew stencil rows sum to ~0 for interior symmetry; allow small
        // boundary residue relative to the field magnitude.
        let max = out.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()));
        assert!(max < 10.0, "constant field derivative too large: {max}");
    }

    #[test]
    fn derivative_is_linear() {
        let k = DgKernel::new();
        let u1: Vec<f64> = (0..FIELDS * N3).map(|i| (i % 13) as f64).collect();
        let u2: Vec<f64> = (0..FIELDS * N3).map(|i| ((i * 7) % 11) as f64).collect();
        let sum: Vec<f64> = u1.iter().zip(&u2).map(|(a, b)| a + b).collect();
        let mut o1 = vec![0.0; FIELDS * N3];
        let mut o2 = vec![0.0; FIELDS * N3];
        let mut os = vec![0.0; FIELDS * N3];
        k.rhs(&u1, &mut o1);
        k.rhs(&u2, &mut o2);
        k.rhs(&sum, &mut os);
        for ((a, b), s) in o1.iter().zip(&o2).zip(&os) {
            assert!((a + b - s).abs() < 1e-9);
        }
    }

    #[test]
    fn step_keeps_field_finite() {
        let k = DgKernel::new();
        let mut u: Vec<f64> = (0..2 * FIELDS * N3).map(|i| ((i % 7) as f64) * 0.1).collect();
        for _ in 0..10 {
            let m = k.step_elements(&mut u, 1e-4);
            assert!(m.is_finite());
        }
    }

    #[test]
    fn flops_count_matches_structure() {
        // 3 dims * 5 fields * 512 nodes * 8-wide dot * 2 = 122,880.
        assert_eq!(DgKernel::flops_per_elem(), 122_880.0);
    }

    #[test]
    fn measured_per_elem_cost_sane() {
        let k = DgKernel::new();
        let t = k.measure_per_elem_seconds(8, 3);
        // A 123 kFLOP element should take 1us..10ms on any CPU.
        assert!(t > 1e-7 && t < 1e-2, "per-element time {t}");
    }
}
