//! Calibration: measure the real MiniCNN train-step through PJRT on this
//! machine, derive achieved FLOP/s, and report the efficiency ratio — the
//! same method the perf model applies to published V100 numbers
//! (DESIGN.md §6). Results land in results/calibration.json.

use crate::runtime::engine::{Engine, Input};
use crate::trainer::data::SyntheticDataset;
use crate::util::json::{arr, num, obj, s, Json};
use anyhow::Result;
use std::time::Instant;

/// Analytic forward FLOPs per image of the MiniCNN (mirrors
/// python/compile/model.py: conv 3x3x3->8 @16x16, conv 3x3x8->16 @8x8,
/// fc 256->128, fc 128->10; 2 FLOPs per MAC).
pub fn minicnn_flops_fwd_per_image() -> f64 {
    let conv1 = 2.0 * (3.0 * 3.0 * 3.0) * (8.0 * 16.0 * 16.0);
    let conv2 = 2.0 * (3.0 * 3.0 * 8.0) * (16.0 * 8.0 * 8.0);
    let fc1 = 2.0 * 256.0 * 128.0;
    let fc2 = 2.0 * 128.0 * 10.0;
    conv1 + conv2 + fc1 + fc2
}

#[derive(Clone, Debug)]
pub struct Calibration {
    pub steps: usize,
    pub batch: usize,
    pub wall_per_step: f64,
    pub achieved_flops: f64,
    pub images_per_sec: f64,
}

impl Calibration {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s("minicnn")),
            ("steps", num(self.steps as f64)),
            ("batch", num(self.batch as f64)),
            ("wall_per_step_sec", num(self.wall_per_step)),
            ("achieved_flops", num(self.achieved_flops)),
            ("images_per_sec", num(self.images_per_sec)),
            ("method", s("real PJRT train_step, fwd+bwd approximated as 3x fwd FLOPs")),
            ("shapes", arr(vec![num(16.0), num(16.0), num(3.0)])),
        ])
    }
}

/// Run `steps` real train-steps and time them.
pub fn run(engine: &Engine, steps: usize) -> Result<Calibration> {
    let train_step = engine.compile("train_step")?;
    let manifest = &engine.manifest;
    let params = manifest.load_init_params(&engine.dir)?;
    let shapes: Vec<Vec<usize>> = manifest.params.iter().map(|p| p.shape.clone()).collect();
    let dataset = SyntheticDataset::new(1, 0.25);
    let batch = manifest.batch;
    let img_shape = [batch, manifest.image[0], manifest.image[1], manifest.image[2]];
    let label_shape = [batch];

    // Warmup (compile caches, allocator).
    let (x, y) = dataset.batch(0, 0, 1, batch);
    let mut inputs: Vec<Input> = params
        .iter()
        .zip(&shapes)
        .map(|(p, sh)| Input::F32(p, sh))
        .collect();
    inputs.push(Input::F32(&x, &img_shape));
    inputs.push(Input::I32(&y, &label_shape));
    train_step.run(&inputs)?;

    let start = Instant::now();
    for step in 0..steps {
        let (x, y) = dataset.batch(step as u64 + 1, 0, 1, batch);
        let mut inputs: Vec<Input> = params
            .iter()
            .zip(&shapes)
            .map(|(p, sh)| Input::F32(p, sh))
            .collect();
        inputs.push(Input::F32(&x, &img_shape));
        inputs.push(Input::I32(&y, &label_shape));
        let out = train_step.run(&inputs)?;
        std::hint::black_box(out[0][0]);
    }
    let wall = start.elapsed().as_secs_f64();
    let per_step = wall / steps as f64;
    let flops_per_step = minicnn_flops_fwd_per_image() * batch as f64 * 3.0;
    Ok(Calibration {
        steps,
        batch,
        wall_per_step: per_step,
        achieved_flops: flops_per_step / per_step,
        images_per_sec: batch as f64 / per_step,
    })
}

/// Save to results/calibration.json.
pub fn save(cal: &Calibration, dir: &std::path::Path) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join("calibration.json");
    std::fs::write(&path, cal.to_json().to_string())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formula() {
        // conv1 54*2048=110,592... assert exact structure.
        let f = minicnn_flops_fwd_per_image();
        assert_eq!(f, 110_592.0 + 147_456.0 + 65_536.0 + 2_560.0);
    }

    #[test]
    fn calibration_runs_if_artifacts_present() {
        let Some(dir) = crate::runtime::artifacts_dir() else { return };
        let engine = Engine::load(&dir).unwrap();
        let cal = run(&engine, 3).unwrap();
        assert!(cal.wall_per_step > 0.0);
        assert!(cal.achieved_flops > 0.0);
        assert!(cal.images_per_sec > 0.0);
        let j = cal.to_json().to_string();
        assert!(j.contains("achieved_flops"));
    }
}
