//! Workload IR: a DAG of compute and communication ops.
//!
//! The paper benchmarks fabrics for bucketed data-parallel allreduce,
//! but "which fabric do I need" is a property of the workload's
//! compute/communication dependency graph (Shi et al.'s DAG model of
//! synchronous SGD). This module promotes the scheduler's `CommOp`
//! record/replay layer to that graph: a [`WorkloadGraph`] is a list of
//! [`IrNode`]s — compute spans, collectives, or point-to-point sends —
//! with explicit dependency edges, executed by
//! [`crate::trainer::scheduler::execute`] over the unchanged fluid
//! event engine.
//!
//! # Node/edge model
//!
//! * Every node carries a `stream` id. Nodes sharing a stream execute
//!   **in node-index order** (the stream is a virtual command queue with
//!   per-rank clocks, exactly the multi-stream scheduler's channels);
//!   nodes on different streams run concurrently and their engine
//!   batches merge within
//!   [`crate::trainer::scheduler::STREAM_MERGE_WINDOW`].
//! * `deps` are cross-node happens-before edges: a node begins only
//!   after every dependency has finished, and its stream's clocks are
//!   raised to the dependency's per-rank finish clocks. Same-stream
//!   ordering needs no edges (the queue serializes); an edge pointing
//!   *forward* on the same stream is rejected by [`WorkloadGraph::validate`]
//!   because it can never be satisfied.
//! * `ready` is an optional per-rank external readiness floor (gradient
//!   availability during backprop); empty means zero for every rank.
//! * `launch` marks a fresh collective launch that pays the
//!   coordination cycle (Horovod negotiation + NCCL launch); follow-on
//!   chunks of one logical launch leave it false.
//!
//! # Lowering contract
//!
//! [`lower_dp`] compiles the trainer's fusion buckets into the IR such
//! that executing the graph is **bit-for-bit identical** to the
//! pre-refactor coordinator at any stream count: one `Allreduce` node
//! per chunk, no edges, round-robin stream assignment, the same
//! split/launch flags ([`crate::trainer::scheduler`] pins this with
//! verbatim copies of the legacy paths). [`lower_zero`],
//! [`lower_pipeline`] and [`lower_moe`] emit ZeRO-style sharded steps,
//! a 1F1B pipeline schedule and MoE all-to-all on top of the same
//! executor.

use crate::collectives::chunk_ranges;
use crate::trainer::scheduler::{split_chunks, BucketWork};
use crate::util::hash::{fnv1a_str, fnv1a_u64 as fnv_step};

/// Collective kinds a [`IrOp::Collective`] node can request. `Allreduce`
/// runs the session's configured [`crate::collectives::Collective`]
/// strategy; the others run the library's ring primitives
/// ([`crate::collectives::primitives`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollKind {
    Allreduce,
    ReduceScatter,
    AllGather,
    AllToAll,
}

impl CollKind {
    pub fn name(&self) -> &'static str {
        match self {
            CollKind::Allreduce => "allreduce",
            CollKind::ReduceScatter => "reduce-scatter",
            CollKind::AllGather => "all-gather",
            CollKind::AllToAll => "all-to-all",
        }
    }
}

/// One IR operation.
#[derive(Clone, Debug)]
pub enum IrOp {
    /// A compute span: rank `r` is busy for `secs` seconds (sparse —
    /// ranks not listed are untouched). Engine-free.
    Compute { secs: Vec<(usize, f64)> },
    /// A collective over `group` (`None` = all ranks) moving `elems`
    /// f32 elements per rank.
    Collective { kind: CollKind, elems: usize, group: Option<Vec<usize>> },
    /// A point-to-point transfer (pipeline stage edge), in bytes.
    Send { src: usize, dst: usize, bytes: f64 },
}

/// One node of the workload graph (see the module docs for the field
/// semantics).
#[derive(Clone, Debug)]
pub struct IrNode {
    pub op: IrOp,
    /// Indices of nodes that must finish before this node begins.
    pub deps: Vec<usize>,
    /// Per-rank readiness floor; empty = 0.0 everywhere.
    pub ready: Vec<f64>,
    /// Virtual command queue this node executes on.
    pub stream: usize,
    /// Fresh collective launch: pays the coordination cycle.
    pub launch: bool,
}

/// A DAG workload over `world` ranks.
#[derive(Clone, Debug)]
pub struct WorkloadGraph {
    pub world: usize,
    pub nodes: Vec<IrNode>,
}

impl WorkloadGraph {
    /// Structural sanity: indices in range, readiness vectors sized,
    /// groups within the world, acyclic, and no same-stream forward
    /// edge (which the in-order stream queues could never satisfy).
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.nodes.len();
        anyhow::ensure!(self.world >= 1, "workload graph over an empty world");
        for (i, node) in self.nodes.iter().enumerate() {
            anyhow::ensure!(
                node.ready.is_empty() || node.ready.len() == self.world,
                "node {i}: ready has {} entries for a {}-rank world",
                node.ready.len(),
                self.world
            );
            for &d in &node.deps {
                anyhow::ensure!(d < n, "node {i}: dep {d} out of range ({n} nodes)");
                anyhow::ensure!(d != i, "node {i}: depends on itself");
                anyhow::ensure!(
                    self.nodes[d].stream != node.stream || d < i,
                    "node {i}: same-stream dep {d} comes later in queue order"
                );
            }
            match &node.op {
                IrOp::Compute { secs } => {
                    for &(r, dur) in secs {
                        anyhow::ensure!(r < self.world, "node {i}: compute rank {r} out of range");
                        anyhow::ensure!(dur >= 0.0, "node {i}: negative compute span");
                    }
                }
                IrOp::Collective { group, .. } => {
                    if let Some(g) = group {
                        anyhow::ensure!(!g.is_empty(), "node {i}: empty collective group");
                        for &r in g {
                            anyhow::ensure!(r < self.world, "node {i}: group rank {r} out of range");
                        }
                        let mut seen = vec![false; self.world];
                        for &r in g {
                            anyhow::ensure!(!seen[r], "node {i}: duplicate group rank {r}");
                            seen[r] = true;
                        }
                    }
                }
                IrOp::Send { src, dst, bytes } => {
                    anyhow::ensure!(src != dst, "node {i}: send to self");
                    anyhow::ensure!(
                        *src < self.world && *dst < self.world,
                        "node {i}: send endpoint out of range"
                    );
                    anyhow::ensure!(*bytes >= 0.0, "node {i}: negative send size");
                }
            }
        }
        // Kahn's algorithm: every node must be reachable once its deps
        // resolve — leftovers mean a dependency cycle.
        let mut indeg: Vec<usize> = self.nodes.iter().map(|x| x.deps.len()).collect();
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for &d in &node.deps {
                dependents[d].push(i);
            }
        }
        let mut frontier: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut done = 0;
        while let Some(i) = frontier.pop() {
            done += 1;
            for &j in &dependents[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    frontier.push(j);
                }
            }
        }
        anyhow::ensure!(done == n, "workload graph has a dependency cycle ({done}/{n} sorted)");
        Ok(())
    }

    /// Structural signature of the graph (FNV-1a over every node's op,
    /// edges, streams and launch flags — `ready` floors excluded, they
    /// vary per step). This identifies the *shape* a schedule was built
    /// for; the executor's pattern tier keys remain per-collective
    /// (algorithm, elems, group, world), so two graphs sharing nodes
    /// share cache entries.
    pub fn signature(&self) -> u64 {
        let mut h = fnv_step(fnv1a_str("workload-graph"), self.world as u64);
        for node in &self.nodes {
            h = match &node.op {
                IrOp::Compute { secs } => {
                    let mut x = fnv_step(h, 1);
                    for &(r, dur) in secs {
                        x = fnv_step(fnv_step(x, r as u64), dur.to_bits());
                    }
                    x
                }
                IrOp::Collective { kind, elems, group } => {
                    let mut x = fnv_step(fnv_step(h, 2), fnv1a_str(kind.name()));
                    x = fnv_step(x, *elems as u64);
                    if let Some(g) = group {
                        x = fnv_step(x, g.len() as u64);
                        for &r in g {
                            x = fnv_step(x, r as u64);
                        }
                    }
                    x
                }
                IrOp::Send { src, dst, bytes } => {
                    let x = fnv_step(fnv_step(h, 3), ((*src as u64) << 24) ^ *dst as u64);
                    fnv_step(x, bytes.to_bits())
                }
            };
            for &d in &node.deps {
                h = fnv_step(h, 0xD00 ^ d as u64);
            }
            h = fnv_step(h, ((node.stream as u64) << 1) | node.launch as u64);
        }
        h
    }

    /// If this graph is a pure serialized-DP step — only full-world
    /// `Allreduce` nodes, no edges, explicit ready floors — return the
    /// equivalent `(BucketWork, launch)` list so the executor can take
    /// the serialized coordinator path (and its timing-cache tier)
    /// unchanged. Anything else returns `None`.
    pub(crate) fn serial_dp_works(&self) -> Option<Vec<(BucketWork, bool)>> {
        let mut works = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let IrOp::Collective { kind: CollKind::Allreduce, elems, group: None } = &node.op
            else {
                return None;
            };
            if !node.deps.is_empty() || node.ready.len() != self.world {
                return None;
            }
            works.push((
                BucketWork {
                    elems: *elems,
                    bytes: *elems as f64 * crate::collectives::BYTES_PER_ELEM,
                    ready: node.ready.clone(),
                },
                node.launch,
            ));
        }
        Some(works)
    }
}

/// Round-robin stream count for `n` work items over `num_streams`
/// channels (the multi-stream scheduler's rule, kept verbatim).
fn stream_count(num_streams: usize, items: usize) -> usize {
    num_streams.min(items.max(1))
}

/// Lower bucketed data-parallel allreduce to the IR: one `Allreduce`
/// node per chunk, buckets assigned round-robin to streams, chunking and
/// launch flags exactly as [`crate::trainer::scheduler::split_chunks`]
/// produces them. Executing this graph is bit-for-bit the pre-refactor
/// coordinator path at any stream count.
pub fn lower_dp(
    buckets: &[BucketWork],
    world: usize,
    num_streams: usize,
    chunk_bytes: Option<f64>,
) -> WorkloadGraph {
    let s_count = stream_count(num_streams, buckets.len());
    let mut nodes = Vec::with_capacity(buckets.len());
    for (b, bucket) in buckets.iter().enumerate() {
        for (chunk, launch) in split_chunks(std::slice::from_ref(bucket), chunk_bytes) {
            nodes.push(IrNode {
                op: IrOp::Collective { kind: CollKind::Allreduce, elems: chunk.elems, group: None },
                deps: Vec::new(),
                ready: chunk.ready,
                stream: b % s_count,
                launch,
            });
        }
    }
    WorkloadGraph { world, nodes }
}

/// Lower a ZeRO-style sharded step: per bucket, reduce-scatter the
/// gradients, run the bucket's optimizer shard (1/world of the work) on
/// every rank, then all-gather the updated parameters. Chunk-pipelining
/// does not apply (the RS/AG pair is already segmented by rank);
/// `optimizer_secs` is the *full* (unsharded) optimizer time, divided
/// across buckets by element share and across ranks by the world size.
pub fn lower_zero(
    buckets: &[BucketWork],
    world: usize,
    optimizer_secs: f64,
    num_streams: usize,
) -> WorkloadGraph {
    let s_count = stream_count(num_streams, buckets.len());
    let total_elems: usize = buckets.iter().map(|b| b.elems).sum();
    let mut nodes = Vec::with_capacity(3 * buckets.len());
    for (b, bucket) in buckets.iter().enumerate() {
        let stream = b % s_count;
        let frac = if total_elems > 0 { bucket.elems as f64 / total_elems as f64 } else { 0.0 };
        let shard_secs = optimizer_secs * frac / world as f64;
        let rs = nodes.len();
        nodes.push(IrNode {
            op: IrOp::Collective {
                kind: CollKind::ReduceScatter,
                elems: bucket.elems,
                group: None,
            },
            deps: Vec::new(),
            ready: bucket.ready.clone(),
            stream,
            launch: true,
        });
        let opt = nodes.len();
        nodes.push(IrNode {
            op: IrOp::Compute { secs: (0..world).map(|r| (r, shard_secs)).collect() },
            deps: vec![rs],
            ready: Vec::new(),
            stream,
            launch: false,
        });
        nodes.push(IrNode {
            op: IrOp::Collective { kind: CollKind::AllGather, elems: bucket.elems, group: None },
            deps: vec![opt],
            ready: Vec::new(),
            stream,
            launch: true,
        });
    }
    WorkloadGraph { world, nodes }
}

/// Lower a 1F1B pipeline-parallel step. The world is split into
/// `world / stages` data-parallel replicas of a `stages`-deep pipeline
/// (rank `w * stages + s` holds replica `w`'s stage `s`); each replica
/// runs `microbatches` microbatches through the classic 1F1B schedule
/// (warmup of `min(M, stages - s)` forwards, then alternating
/// backward/forward, then the backward drain), with `activation_bytes`
/// moving over a point-to-point stage edge per microbatch boundary.
/// Stage edges ride the compute stream without a negotiation cycle
/// (`launch = false`); when there is more than one replica, each stage's
/// gradient shard (`grad_elems / stages` elements) is allreduced across
/// replicas on its own stream after that stage's last backward.
///
/// `fwd`/`bwd` are the per-rank *full-model* compute times; each
/// microbatch stage span costs `1 / (stages * microbatches)` of them.
pub fn lower_pipeline(
    world: usize,
    stages: usize,
    microbatches: usize,
    fwd: &[f64],
    bwd: &[f64],
    activation_bytes: f64,
    grad_elems: usize,
) -> anyhow::Result<WorkloadGraph> {
    anyhow::ensure!(stages >= 2, "pipeline needs at least 2 stages, got {stages}");
    anyhow::ensure!(microbatches >= 1, "pipeline needs at least 1 microbatch");
    anyhow::ensure!(
        world % stages == 0 && world >= stages,
        "world {world} not divisible into {stages} pipeline stages"
    );
    anyhow::ensure!(fwd.len() == world && bwd.len() == world, "per-rank cost vectors sized wrong");
    let replicas = world / stages;
    let rank = |w: usize, s: usize| w * stages + s;
    let m_count = microbatches;

    // Pass 1: emit node protos per stream (in 1F1B queue order) with
    // symbolic dep keys; pass 2 resolves keys to indices. Cross-stream
    // edges may point forward (the executor blocks the stream), but keys
    // must exist by the time we resolve — emitting all streams first
    // guarantees that.
    #[derive(Clone, Copy, PartialEq, Eq, Hash)]
    enum Key {
        F(usize, usize, usize),
        B(usize, usize, usize),
        SendF(usize, usize, usize),
        SendB(usize, usize, usize),
    }
    let mut index: std::collections::HashMap<Key, usize> = std::collections::HashMap::new();
    let mut protos: Vec<(IrOp, Vec<Key>, usize, bool)> = Vec::new();
    for w in 0..replicas {
        for s in 0..stages {
            let r = rank(w, s);
            let f_cost = fwd[r] / (stages * m_count) as f64;
            let b_cost = bwd[r] / (stages * m_count) as f64;
            let mut emit_f = |protos: &mut Vec<_>, index: &mut std::collections::HashMap<_, _>,
                              m: usize| {
                let deps = if s > 0 { vec![Key::SendF(w, s - 1, m)] } else { Vec::new() };
                index.insert(Key::F(w, s, m), protos.len());
                protos.push((IrOp::Compute { secs: vec![(r, f_cost)] }, deps, r, false));
                if s + 1 < stages {
                    index.insert(Key::SendF(w, s, m), protos.len());
                    protos.push((
                        IrOp::Send { src: r, dst: rank(w, s + 1), bytes: activation_bytes },
                        vec![Key::F(w, s, m)],
                        r,
                        false,
                    ));
                }
            };
            let mut emit_b = |protos: &mut Vec<_>, index: &mut std::collections::HashMap<_, _>,
                              m: usize| {
                let deps = if s + 1 < stages {
                    vec![Key::SendB(w, s + 1, m)]
                } else {
                    vec![Key::F(w, s, m)]
                };
                index.insert(Key::B(w, s, m), protos.len());
                protos.push((IrOp::Compute { secs: vec![(r, b_cost)] }, deps, r, false));
                if s > 0 {
                    index.insert(Key::SendB(w, s, m), protos.len());
                    protos.push((
                        IrOp::Send { src: r, dst: rank(w, s - 1), bytes: activation_bytes },
                        vec![Key::B(w, s, m)],
                        r,
                        false,
                    ));
                }
            };
            // 1F1B: warmup forwards, steady-state one-backward-one-forward,
            // backward drain.
            let warmup = m_count.min(stages - s);
            let mut nf = 0;
            let mut nb = 0;
            while nf < warmup {
                emit_f(&mut protos, &mut index, nf);
                nf += 1;
            }
            while nb < m_count {
                emit_b(&mut protos, &mut index, nb);
                nb += 1;
                if nf < m_count {
                    emit_f(&mut protos, &mut index, nf);
                    nf += 1;
                }
            }
        }
    }
    let mut nodes: Vec<IrNode> = protos
        .into_iter()
        .map(|(op, deps, stream, launch)| IrNode {
            op,
            deps: deps.iter().map(|k| index[k]).collect(),
            ready: Vec::new(),
            stream,
            launch,
        })
        .collect();
    if replicas > 1 {
        let shard = chunk_ranges(grad_elems, stages);
        for s in 0..stages {
            let group: Vec<usize> = (0..replicas).map(|w| rank(w, s)).collect();
            let deps: Vec<usize> =
                (0..replicas).map(|w| index[&Key::B(w, s, m_count - 1)]).collect();
            nodes.push(IrNode {
                op: IrOp::Collective {
                    kind: CollKind::Allreduce,
                    elems: shard[s].len(),
                    group: Some(group),
                },
                deps,
                ready: Vec::new(),
                stream: world + s,
                launch: true,
            });
        }
    }
    Ok(WorkloadGraph { world, nodes })
}

/// Lower an MoE step: the forward and backward passes are each split
/// into `layers + 1` compute segments with a dispatch + combine
/// all-to-all pair (`a2a_elems` elements per rank each) at every MoE
/// layer boundary, all serialized on stream 0 (expert compute is folded
/// into the following segment); the dense gradients then allreduce as
/// usual, one bucket per stream round-robin, gated on the last backward
/// segment (no intra-backward overlap — the A2A chain owns the wire
/// during backprop).
pub fn lower_moe(
    world: usize,
    fwd: &[f64],
    bwd: &[f64],
    bucket_elems: &[usize],
    layers: usize,
    a2a_elems: usize,
    num_streams: usize,
) -> anyhow::Result<WorkloadGraph> {
    anyhow::ensure!(layers >= 1, "moe needs at least one expert layer");
    anyhow::ensure!(fwd.len() == world && bwd.len() == world, "per-rank cost vectors sized wrong");
    let segs = layers + 1;
    let mut nodes: Vec<IrNode> = Vec::new();
    let mut chain = |cost: &[f64], nodes: &mut Vec<IrNode>| {
        for seg in 0..segs {
            nodes.push(IrNode {
                op: IrOp::Compute {
                    secs: (0..world).map(|r| (r, cost[r] / segs as f64)).collect(),
                },
                deps: Vec::new(),
                ready: Vec::new(),
                stream: 0,
                launch: false,
            });
            if seg + 1 < segs {
                for _ in 0..2 {
                    // Dispatch to experts, then combine back.
                    nodes.push(IrNode {
                        op: IrOp::Collective {
                            kind: CollKind::AllToAll,
                            elems: a2a_elems,
                            group: None,
                        },
                        deps: Vec::new(),
                        ready: Vec::new(),
                        stream: 0,
                        launch: true,
                    });
                }
            }
        }
    };
    chain(fwd, &mut nodes);
    chain(bwd, &mut nodes);
    let last_bwd = nodes.len() - 1;
    let s_count = stream_count(num_streams, bucket_elems.len());
    for (b, &elems) in bucket_elems.iter().enumerate() {
        nodes.push(IrNode {
            op: IrOp::Collective { kind: CollKind::Allreduce, elems, group: None },
            deps: vec![last_bwd],
            ready: Vec::new(),
            stream: b % s_count,
            launch: true,
        });
    }
    Ok(WorkloadGraph { world, nodes })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bucket(elems: usize, ready: f64, world: usize) -> BucketWork {
        BucketWork {
            elems,
            bytes: elems as f64 * crate::collectives::BYTES_PER_ELEM,
            ready: vec![ready; world],
        }
    }

    #[test]
    fn validate_rejects_structural_nonsense() {
        let ar = |deps: Vec<usize>, stream: usize| IrNode {
            op: IrOp::Collective { kind: CollKind::Allreduce, elems: 10, group: None },
            deps,
            ready: Vec::new(),
            stream,
            launch: true,
        };
        // Dep out of range.
        let g = WorkloadGraph { world: 4, nodes: vec![ar(vec![7], 0)] };
        assert!(g.validate().is_err());
        // Self-dependency.
        let g = WorkloadGraph { world: 4, nodes: vec![ar(vec![0], 0)] };
        assert!(g.validate().is_err());
        // Same-stream forward edge: queue order can never satisfy it.
        let g = WorkloadGraph { world: 4, nodes: vec![ar(vec![1], 0), ar(vec![], 0)] };
        assert!(g.validate().is_err());
        // Cross-stream forward edge is fine (the stream blocks).
        let g = WorkloadGraph { world: 4, nodes: vec![ar(vec![1], 0), ar(vec![], 1)] };
        g.validate().unwrap();
        // Cycle over two streams.
        let g = WorkloadGraph { world: 4, nodes: vec![ar(vec![1], 0), ar(vec![0], 1)] };
        assert!(g.validate().is_err());
        // Group rank out of range / duplicated.
        let grp = |group: Vec<usize>| WorkloadGraph {
            world: 4,
            nodes: vec![IrNode {
                op: IrOp::Collective { kind: CollKind::Allreduce, elems: 10, group: Some(group) },
                deps: Vec::new(),
                ready: Vec::new(),
                stream: 0,
                launch: true,
            }],
        };
        assert!(grp(vec![0, 4]).validate().is_err());
        assert!(grp(vec![1, 1]).validate().is_err());
        grp(vec![1, 3]).validate().unwrap();
        // Send to self / out of range; ready vector sized wrong.
        let send = IrNode {
            op: IrOp::Send { src: 2, dst: 2, bytes: 1.0 },
            deps: Vec::new(),
            ready: Vec::new(),
            stream: 0,
            launch: false,
        };
        assert!(WorkloadGraph { world: 4, nodes: vec![send] }.validate().is_err());
        let mut short = ar(vec![], 0);
        short.ready = vec![0.0; 3];
        assert!(WorkloadGraph { world: 4, nodes: vec![short] }.validate().is_err());
    }

    #[test]
    fn lower_dp_mirrors_the_scheduler_rules() {
        let world = 8;
        let buckets = vec![bucket(1000, 0.0, world), bucket(500, 0.001, world)];
        let g = lower_dp(&buckets, world, 2, None);
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 2);
        assert_eq!(g.nodes[0].stream, 0);
        assert_eq!(g.nodes[1].stream, 1);
        assert!(g.nodes.iter().all(|n| n.launch && n.deps.is_empty()));
        // Chunking expands a bucket in place, first chunk owns the launch.
        let g = lower_dp(&buckets[..1], world, 2, Some(1000.0));
        assert_eq!(g.nodes.len(), 4);
        let launches: Vec<bool> = g.nodes.iter().map(|n| n.launch).collect();
        assert_eq!(launches, vec![true, false, false, false]);
        assert!(g.nodes.iter().all(|n| n.stream == 0), "chunks stay on the bucket's stream");
        let total: usize = g
            .nodes
            .iter()
            .map(|n| match &n.op {
                IrOp::Collective { elems, .. } => *elems,
                _ => 0,
            })
            .sum();
        assert_eq!(total, 1000);
        // Round-trip back to the serialized coordinator's work list.
        let g = lower_dp(&buckets, world, 1, None);
        let works = g.serial_dp_works().unwrap();
        assert_eq!(works.len(), 2);
        assert_eq!(works[0].0.elems, 1000);
        assert_eq!(works[1].0.ready, buckets[1].ready);
    }

    #[test]
    fn serial_dp_rejects_non_dp_graphs() {
        let world = 4;
        let buckets = vec![bucket(100, 0.0, world)];
        let zero = lower_zero(&buckets, world, 0.01, 1);
        assert!(zero.serial_dp_works().is_none());
        let moe = lower_moe(world, &[0.1; 4], &[0.2; 4], &[100], 1, 64, 1).unwrap();
        assert!(moe.serial_dp_works().is_none());
    }

    #[test]
    fn lower_zero_chains_rs_opt_ag() {
        let world = 8;
        let buckets = vec![bucket(3000, 0.002, world), bucket(1000, 0.004, world)];
        let g = lower_zero(&buckets, world, 0.008, 2);
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 6);
        for b in 0..2 {
            let (rs, opt, ag) = (3 * b, 3 * b + 1, 3 * b + 2);
            assert!(matches!(
                g.nodes[rs].op,
                IrOp::Collective { kind: CollKind::ReduceScatter, .. }
            ));
            assert!(matches!(g.nodes[ag].op, IrOp::Collective { kind: CollKind::AllGather, .. }));
            assert_eq!(g.nodes[opt].deps, vec![rs]);
            assert_eq!(g.nodes[ag].deps, vec![opt]);
            assert!(g.nodes[rs].launch && g.nodes[ag].launch);
        }
        // The optimizer shards sum to optimizer / world on every rank.
        let mut per_rank = vec![0.0; world];
        for n in &g.nodes {
            if let IrOp::Compute { secs } = &n.op {
                for &(r, d) in secs {
                    per_rank[r] += d;
                }
            }
        }
        for d in per_rank {
            assert!((d - 0.008 / world as f64).abs() < 1e-15, "shard sum {d}");
        }
    }

    #[test]
    fn lower_pipeline_emits_1f1b() {
        let world = 8;
        let stages = 4;
        let m = 6;
        let fwd = vec![0.04; world];
        let bwd = vec![0.08; world];
        let g = lower_pipeline(world, stages, m, &fwd, &bwd, 2e6, 25_000_000).unwrap();
        g.validate().unwrap();
        // Per replica: m F + m B per stage, a forward send per non-last
        // stage and a backward send per non-first stage, plus one grad
        // allreduce per stage across the 2 replicas.
        let computes =
            g.nodes.iter().filter(|n| matches!(n.op, IrOp::Compute { .. })).count();
        let sends = g.nodes.iter().filter(|n| matches!(n.op, IrOp::Send { .. })).count();
        let ars = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, IrOp::Collective { kind: CollKind::Allreduce, .. }))
            .count();
        assert_eq!(computes, 2 * stages * m * 2);
        assert_eq!(sends, 2 * 2 * (stages - 1) * m);
        assert_eq!(ars, stages);
        // Grad allreduces are grouped per stage across replicas and the
        // shards partition the gradient.
        let mut shard_total = 0;
        for n in &g.nodes {
            if let IrOp::Collective { kind: CollKind::Allreduce, elems, group } = &n.op {
                let g = group.as_ref().expect("stage allreduce must be grouped");
                assert_eq!(g.len(), 2);
                assert_eq!(g[1] - g[0], stages);
                shard_total += elems;
            }
        }
        assert_eq!(shard_total, 25_000_000);
        // Single replica: pure pipeline, no gradient exchange.
        let solo = lower_pipeline(stages, stages, m, &fwd[..stages], &bwd[..stages], 2e6, 100)
            .unwrap();
        solo.validate().unwrap();
        assert!(!solo
            .nodes
            .iter()
            .any(|n| matches!(n.op, IrOp::Collective { .. })));
        // Invalid shapes are loud.
        assert!(lower_pipeline(6, 4, m, &[0.0; 6], &[0.0; 6], 1.0, 10).is_err());
        assert!(lower_pipeline(4, 1, m, &[0.0; 4], &[0.0; 4], 1.0, 10).is_err());
    }

    #[test]
    fn lower_moe_interleaves_a2a() {
        let world = 4;
        let g = lower_moe(world, &[0.1; 4], &[0.2; 4], &[900, 100], 2, 4096, 2).unwrap();
        g.validate().unwrap();
        // Per pass: 3 compute segments + 2 boundaries x 2 a2a = 7 nodes.
        let a2a = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, IrOp::Collective { kind: CollKind::AllToAll, .. }))
            .count();
        assert_eq!(a2a, 2 * 2 * 2);
        let ars: Vec<&IrNode> = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, IrOp::Collective { kind: CollKind::Allreduce, .. }))
            .collect();
        assert_eq!(ars.len(), 2);
        assert_eq!(ars[0].stream, 0);
        assert_eq!(ars[1].stream, 1);
        // Both gradient allreduces gate on the final backward segment.
        assert_eq!(ars[0].deps, ars[1].deps);
        assert_eq!(ars[0].deps.len(), 1);
        assert!(matches!(g.nodes[ars[0].deps[0]].op, IrOp::Compute { .. }));
    }

    #[test]
    fn signature_discriminates_structure() {
        let world = 8;
        let buckets = vec![bucket(1000, 0.0, world), bucket(500, 0.001, world)];
        let a = lower_dp(&buckets, world, 2, None);
        let b = lower_dp(&buckets, world, 2, None);
        assert_eq!(a.signature(), b.signature(), "deterministic");
        let c = lower_dp(&buckets, world, 1, None);
        assert_ne!(a.signature(), c.signature(), "stream layout is structural");
        let z = lower_zero(&buckets, world, 0.01, 2);
        assert_ne!(a.signature(), z.signature());
        // Ready floors are per-step data, not structure.
        let mut shifted = buckets.clone();
        shifted[0].ready = vec![0.5; world];
        let d = lower_dp(&shifted, world, 2, None);
        assert_eq!(a.signature(), d.signature());
    }
}
