//! Collective communication library — the NCCL/Horovod stand-in.
//!
//! Every algorithm is implemented with **real f32 arithmetic** over a
//! [`Buffers`] abstraction: tests drive [`RealBuffers`] and verify the
//! all-reduced values bit-for-bit against a naive sum, while large-scale
//! timing experiments drive [`NullBuffers`] (same control flow and message
//! schedule, no 50 GB allocations for 512 ranks x 25 M parameters).
//!
//! Timing comes from the [`crate::fabric::Comm`] the algorithm runs over,
//! so the same code path answers both "is the math right?" and "how long
//! does it take on this fabric?" — the property the paper's benchmarks
//! rely on.

pub mod fusion;
pub mod hierarchical;
pub mod primitives;
pub mod recursive;
pub mod ring;
pub mod tree;

use crate::fabric::Comm;
use std::ops::Range;

pub use fusion::{fuse, Bucket};
pub use hierarchical::Hierarchical;
pub use primitives::{allgather, alltoall, broadcast, reduce_scatter, PipelinedRing};
pub use recursive::RecursiveHalvingDoubling;
pub use ring::RingAllreduce;
pub use tree::BinomialTree;

/// Data plane abstraction: one logical buffer per rank.
pub trait Buffers {
    /// Elements per rank buffer (all ranks equal).
    fn elems(&self) -> usize;
    /// `buf[dst][range] += buf[src][range]`.
    fn reduce_chunk(&mut self, dst: usize, src: usize, range: Range<usize>);
    /// `buf[dst][range] = buf[src][range]`.
    fn copy_chunk(&mut self, dst: usize, src: usize, range: Range<usize>);
}

/// Real data plane: verifiable arithmetic.
pub struct RealBuffers {
    pub data: Vec<Vec<f32>>,
}

impl RealBuffers {
    pub fn new(data: Vec<Vec<f32>>) -> Self {
        assert!(!data.is_empty());
        let n = data[0].len();
        assert!(data.iter().all(|b| b.len() == n), "ragged buffers");
        RealBuffers { data }
    }

    /// Pair of mutable/shared references to distinct rank buffers.
    fn pair(&mut self, dst: usize, src: usize) -> (&mut [f32], &[f32]) {
        assert_ne!(dst, src);
        if dst < src {
            let (lo, hi) = self.data.split_at_mut(src);
            (&mut lo[dst], &hi[0])
        } else {
            let (lo, hi) = self.data.split_at_mut(dst);
            (&mut hi[0], &lo[src])
        }
    }
}

impl Buffers for RealBuffers {
    fn elems(&self) -> usize {
        self.data[0].len()
    }

    fn reduce_chunk(&mut self, dst: usize, src: usize, range: Range<usize>) {
        let (d, s) = self.pair(dst, src);
        let (d, s) = (&mut d[range.clone()], &s[range]);
        // Hot path (§Perf): 8-wide unrolled accumulate. The explicit
        // fixed-size chunks let LLVM emit packed adds without a scalar
        // prologue on every call; measured +60% over the naive zip loop
        // on this machine (see EXPERIMENTS.md §Perf).
        let mut dc = d.chunks_exact_mut(8);
        let mut sc = s.chunks_exact(8);
        for (dv, sv) in (&mut dc).zip(&mut sc) {
            for i in 0..8 {
                dv[i] += sv[i];
            }
        }
        for (x, y) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
            *x += *y;
        }
    }

    fn copy_chunk(&mut self, dst: usize, src: usize, range: Range<usize>) {
        let (d, s) = self.pair(dst, src);
        d[range.clone()].copy_from_slice(&s[range]);
    }
}

/// Timing-only data plane.
pub struct NullBuffers {
    pub elems: usize,
}

impl Buffers for NullBuffers {
    fn elems(&self) -> usize {
        self.elems
    }

    fn reduce_chunk(&mut self, _dst: usize, _src: usize, _range: Range<usize>) {}

    fn copy_chunk(&mut self, _dst: usize, _src: usize, _range: Range<usize>) {}
}

/// Bytes per f32 element on the wire.
pub const BYTES_PER_ELEM: f64 = 4.0;

use crate::util::hash::fnv1a_str;

/// A sum-allreduce algorithm. After `allreduce` returns, every rank's
/// buffer holds the elementwise sum of all ranks' original buffers, and
/// the communicator's clocks reflect the communication schedule. Returns
/// the completion time (max over ranks).
pub trait Collective {
    fn name(&self) -> &'static str;

    /// Discriminator for schedule memoization
    /// ([`crate::trainer::scheduler::ScheduleCache`]): two instances with
    /// equal signatures MUST emit identical message schedules for the
    /// same (elems, placement, topology). The default hashes the name,
    /// which is correct only for field-less strategies — any strategy
    /// with parameters that shape its schedule (e.g.
    /// [`PipelinedRing::segments`]) must fold them in.
    fn schedule_signature(&self) -> u64 {
        fnv1a_str(self.name())
    }

    fn allreduce(&self, comm: &mut Comm, bufs: &mut dyn Buffers) -> f64;
}

/// The paper's three all-reduce strategies (Fig 5), in display order.
pub fn paper_strategies() -> Vec<Box<dyn Collective>> {
    vec![
        Box::new(RingAllreduce),
        Box::new(RecursiveHalvingDoubling),
        Box::new(Hierarchical::default()),
    ]
}

/// Split `elems` into `parts` contiguous chunk ranges (first chunks one
/// element longer when not divisible).
pub fn chunk_ranges(elems: usize, parts: usize) -> Vec<Range<usize>> {
    assert!(parts > 0);
    let base = elems / parts;
    let extra = elems % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, elems);
    out
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::cluster::Placement;
    use crate::config::presets::fabric;
    use crate::config::spec::{ClusterSpec, FabricKind, TransportOptions};
    use crate::fabric::NetSim;
    use crate::util::rng::Rng;

    pub fn gpu_world(ranks: usize, kind: FabricKind) -> (NetSim, Placement) {
        let cluster = ClusterSpec::txgaia();
        let placement = Placement::gpus(&cluster, ranks).unwrap();
        let net = NetSim::new(fabric(kind), cluster, TransportOptions::default());
        (net, placement)
    }

    pub fn random_buffers(ranks: usize, elems: usize, seed: u64) -> RealBuffers {
        let mut rng = Rng::new(seed);
        RealBuffers::new(
            (0..ranks)
                .map(|_| (0..elems).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect())
                .collect(),
        )
    }

    pub fn naive_sum(bufs: &RealBuffers) -> Vec<f32> {
        let n = bufs.elems();
        let mut out = vec![0.0f32; n];
        for b in &bufs.data {
            for (o, x) in out.iter_mut().zip(b) {
                *o += *x;
            }
        }
        out
    }

    /// Assert an allreduce result matches the naive sum within float
    /// reassociation tolerance.
    pub fn check_allreduce(algo: &dyn Collective, ranks: usize, elems: usize, seed: u64) {
        let (mut net, placement) = gpu_world(ranks, FabricKind::OmniPath100);
        let mut bufs = random_buffers(ranks, elems, seed);
        let expect = naive_sum(&bufs);
        let mut comm = Comm::new(&mut net, &placement);
        let t = algo.allreduce(&mut comm, &mut bufs);
        assert!(t > 0.0 || ranks == 1, "{}: no time elapsed", algo.name());
        for (r, buf) in bufs.data.iter().enumerate() {
            for (i, (got, want)) in buf.iter().zip(&expect).enumerate() {
                let tol = 1e-4 * want.abs().max(1.0);
                assert!(
                    (got - want).abs() <= tol,
                    "{}: rank {r} elem {i}: {got} vs {want} (p={ranks}, n={elems})",
                    algo.name()
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_partition() {
        for (elems, parts) in [(10, 3), (7, 7), (5, 8), (0, 2), (100, 1)] {
            let ranges = chunk_ranges(elems, parts);
            assert_eq!(ranges.len(), parts);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, elems);
            // Contiguous and ordered.
            let mut pos = 0;
            for r in &ranges {
                assert_eq!(r.start, pos);
                pos = r.end;
            }
        }
    }

    #[test]
    fn real_buffers_reduce_and_copy() {
        let mut b = RealBuffers::new(vec![vec![1.0, 2.0], vec![10.0, 20.0]]);
        b.reduce_chunk(0, 1, 0..2);
        assert_eq!(b.data[0], vec![11.0, 22.0]);
        b.copy_chunk(1, 0, 1..2);
        assert_eq!(b.data[1], vec![10.0, 22.0]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_buffers_rejected() {
        RealBuffers::new(vec![vec![1.0], vec![1.0, 2.0]]);
    }
}
