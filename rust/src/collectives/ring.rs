//! Ring allreduce (the bandwidth-optimal workhorse; NCCL's default and
//! the paper's baseline strategy).
//!
//! `p-1` reduce-scatter rounds followed by `p-1` allgather rounds over
//! chunks of `n/p` elements: every rank sends `2 n (p-1)/p` elements total
//! regardless of `p`, at the cost of `2(p-1)` latency terms.

use super::{chunk_ranges, Buffers, Collective, BYTES_PER_ELEM};
use crate::fabric::Comm;

pub struct RingAllreduce;

impl Collective for RingAllreduce {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn allreduce(&self, comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
        let p = comm.size();
        if p <= 1 {
            return comm.max_time();
        }
        let n = bufs.elems();
        let chunks = chunk_ranges(n, p);
        // Concurrency is observed by the event engine per round (one flow
        // per member NIC at any instant); nothing to declare up front.

        // Reduce-scatter: round k, rank i sends chunk (i - k) mod p to
        // i+1, which accumulates it. All sends in a round are concurrent.
        for k in 0..p - 1 {
            let msgs: Vec<(usize, usize, f64)> = (0..p)
                .map(|i| {
                    let c = (i + p - k % p) % p;
                    (i, (i + 1) % p, chunks[c].len() as f64 * BYTES_PER_ELEM)
                })
                .collect();
            comm.round(&msgs);
            for i in 0..p {
                let c = (i + p - k % p) % p;
                bufs.reduce_chunk((i + 1) % p, i, chunks[c].clone());
            }
        }
        // Allgather: round k, rank i sends its completed chunk
        // (i + 1 - k) mod p onward.
        for k in 0..p - 1 {
            let msgs: Vec<(usize, usize, f64)> = (0..p)
                .map(|i| {
                    let c = (i + 1 + p - k % p) % p;
                    (i, (i + 1) % p, chunks[c].len() as f64 * BYTES_PER_ELEM)
                })
                .collect();
            comm.round(&msgs);
            for i in 0..p {
                let c = (i + 1 + p - k % p) % p;
                bufs.copy_chunk((i + 1) % p, i, chunks[c].clone());
            }
        }
        comm.max_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::testutil::check_allreduce;
    use crate::collectives::{NullBuffers, RealBuffers};
    use crate::config::spec::FabricKind;
    use crate::util::prop;

    #[test]
    fn correct_for_various_world_sizes() {
        for p in [2, 3, 4, 5, 8, 13, 16] {
            check_allreduce(&RingAllreduce, p, 101, 42 + p as u64);
        }
    }

    #[test]
    fn correct_for_tiny_buffers() {
        // Fewer elements than ranks: some chunks are empty.
        check_allreduce(&RingAllreduce, 8, 3, 7);
        check_allreduce(&RingAllreduce, 8, 1, 8);
    }

    #[test]
    fn single_rank_is_noop() {
        let (mut net, placement) =
            crate::collectives::testutil::gpu_world(1, FabricKind::OmniPath100);
        let mut bufs = RealBuffers::new(vec![vec![1.0, 2.0]]);
        let mut comm = Comm::new(&mut net, &placement);
        let t = RingAllreduce.allreduce(&mut comm, &mut bufs);
        assert_eq!(t, 0.0);
        assert_eq!(bufs.data[0], vec![1.0, 2.0]);
    }

    #[test]
    fn property_random_worlds() {
        prop::forall(99, 12, |r| {
            (2 + r.below(12) as usize, 1 + r.below(64) as usize, r.next_u64())
        }, |&(p, n, seed)| {
            // check_allreduce panics on mismatch; wrap for Result.
            check_allreduce(&RingAllreduce, p, n, seed);
            Ok(())
        });
    }

    #[test]
    fn bandwidth_term_matches_analytic_model() {
        // Large buffer, many ranks: time ~ 2 * S * (p-1)/p / bw.
        let p = 16usize; // 8 nodes
        let elems = 8_000_000usize; // 32 MB
        let (mut net, placement) =
            crate::collectives::testutil::gpu_world(p, FabricKind::EthernetRoce25);
        let bw = net.fabric.effective_bandwidth().min(net.cluster.pcie_bw);
        let mut comm = Comm::new(&mut net, &placement);
        let mut bufs = NullBuffers { elems };
        let t = RingAllreduce.allreduce(&mut comm, &mut bufs);
        let s = elems as f64 * BYTES_PER_ELEM;
        let model = 2.0 * s * (p as f64 - 1.0) / p as f64 / bw;
        // Within 2x of the ideal (local hops are cheaper; latency adds).
        assert!(t > 0.5 * model && t < 2.0 * model, "t={t} model={model}");
    }

    #[test]
    fn ethernet_slower_than_opa_for_large_reduce() {
        let elems = 4_000_000usize;
        let run = |kind| {
            let (mut net, placement) = crate::collectives::testutil::gpu_world(16, kind);
            let mut comm = Comm::new(&mut net, &placement);
            RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems })
        };
        let te = run(FabricKind::EthernetRoce25);
        let to = run(FabricKind::OmniPath100);
        assert!(te > to, "eth {te} !> opa {to}");
    }
}
