//! Hierarchical (NCCL-style) allreduce: intra-node reduce to a per-node
//! leader over PCIe, ring allreduce among leaders over the fabric, then
//! intra-node broadcast. With 2 GPUs/node (TX-GAIA) this halves the
//! number of NIC flows vs a flat ring and keeps the PCIe hops off the
//! wire path — the configuration Horovod+NCCL used in the paper.

use super::{Buffers, Collective, BYTES_PER_ELEM};
use crate::fabric::Comm;

#[derive(Default)]
pub struct Hierarchical {
    // Inner algorithm is currently always ring (NCCL-like). Kept as a
    // struct so ablations can extend it.
}

impl Collective for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn allreduce(&self, comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
        let p = comm.size();
        if p <= 1 {
            return comm.max_time();
        }
        let n = bufs.elems();
        let bytes = n as f64 * BYTES_PER_ELEM;
        let groups = comm.placement.by_node();
        let leaders: Vec<usize> = groups.iter().map(|g| g[0]).collect();

        // Phase 1: intra-node reduce to the leader.
        for g in &groups {
            let leader = g[0];
            for &r in &g[1..] {
                comm.p2p(r, leader, bytes);
                bufs.reduce_chunk(leader, r, 0..n);
            }
        }

        // Phase 2: ring among leaders. Build a sub-communicator view by
        // running ring manually over leader indices.
        if leaders.len() > 1 {
            ring_over_subset(comm, bufs, &leaders, n);
        }

        // Phase 3: intra-node broadcast from the leader.
        for g in &groups {
            let leader = g[0];
            for &r in &g[1..] {
                comm.p2p(leader, r, bytes);
                bufs.copy_chunk(r, leader, 0..n);
            }
        }
        comm.max_time()
    }
}

/// Ring allreduce restricted to `members` (global rank ids).
fn ring_over_subset(comm: &mut Comm, bufs: &mut dyn Buffers, members: &[usize], n: usize) {
    let p = members.len();
    let chunks = super::chunk_ranges(n, p);
    for k in 0..p - 1 {
        let msgs: Vec<(usize, usize, f64)> = (0..p)
            .map(|idx| {
                let c = (idx + p - k) % p;
                (
                    members[idx],
                    members[(idx + 1) % p],
                    chunks[c].len() as f64 * BYTES_PER_ELEM,
                )
            })
            .collect();
        comm.round(&msgs);
        for idx in 0..p {
            let c = (idx + p - k) % p;
            bufs.reduce_chunk(members[(idx + 1) % p], members[idx], chunks[c].clone());
        }
    }
    for k in 0..p - 1 {
        let msgs: Vec<(usize, usize, f64)> = (0..p)
            .map(|idx| {
                let c = (idx + 1 + p - k) % p;
                (
                    members[idx],
                    members[(idx + 1) % p],
                    chunks[c].len() as f64 * BYTES_PER_ELEM,
                )
            })
            .collect();
        comm.round(&msgs);
        for idx in 0..p {
            let c = (idx + 1 + p - k) % p;
            bufs.copy_chunk(members[(idx + 1) % p], members[idx], chunks[c].clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::RingAllreduce;
    use crate::collectives::testutil::{check_allreduce, gpu_world};
    use crate::collectives::NullBuffers;
    use crate::config::spec::FabricKind;
    use crate::util::prop;

    #[test]
    fn correct_for_various_world_sizes() {
        // Even counts exercise 2-GPU nodes; odd counts leave a lone GPU on
        // the last node.
        for p in [2, 3, 4, 6, 8, 9, 16] {
            check_allreduce(&Hierarchical::default(), p, 88, 900 + p as u64);
        }
    }

    #[test]
    fn property_random_worlds() {
        prop::forall(66, 12, |r| {
            (2 + r.below(14) as usize, 1 + r.below(96) as usize, r.next_u64())
        }, |&(p, n, seed)| {
            check_allreduce(&Hierarchical::default(), p, n, seed);
            Ok(())
        });
    }

    #[test]
    fn beats_flat_ring_when_latency_bound() {
        // 64 GPUs on 32 nodes, small buffer: hierarchical's 2*(32-1)
        // network rounds beat the flat ring's 2*(64-1); the PCIe
        // reduce/bcast is cheap at this size.
        let elems = 20_000; // 80 KB
        let t_h = {
            let (mut net, placement) = gpu_world(64, FabricKind::EthernetRoce25);
            let mut comm = Comm::new(&mut net, &placement);
            Hierarchical::default().allreduce(&mut comm, &mut NullBuffers { elems })
        };
        let t_flat = {
            let (mut net, placement) = gpu_world(64, FabricKind::EthernetRoce25);
            let mut comm = Comm::new(&mut net, &placement);
            RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems })
        };
        assert!(t_h < t_flat, "hierarchical {t_h} !< flat {t_flat}");
    }

    #[test]
    fn flat_ring_competitive_on_large_buffers() {
        // Bandwidth-bound regime: the flat ring pipelines its intra-node
        // hops with the wire, while hierarchical pays the full-buffer PCIe
        // reduce/bcast serially. Both stay within 2x of each other (this
        // is the regime trade-off NCCL navigates with its own tuning).
        let elems = 2_000_000;
        let t_h = {
            let (mut net, placement) = gpu_world(64, FabricKind::EthernetRoce25);
            let mut comm = Comm::new(&mut net, &placement);
            Hierarchical::default().allreduce(&mut comm, &mut NullBuffers { elems })
        };
        let t_flat = {
            let (mut net, placement) = gpu_world(64, FabricKind::EthernetRoce25);
            let mut comm = Comm::new(&mut net, &placement);
            RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems })
        };
        let ratio = t_h / t_flat;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio} out of band");
    }

    #[test]
    fn single_node_stays_on_pcie() {
        // 2 GPUs on one node: no network messages at all.
        let (mut net, placement) = gpu_world(2, FabricKind::EthernetRoce25);
        let mut comm = Comm::new(&mut net, &placement);
        Hierarchical::default().allreduce(&mut comm, &mut NullBuffers { elems: 1000 });
        assert_eq!(net.stats.inter_node_messages, 0);
    }
}
