//! Hierarchical (NCCL-style) allreduce with topology-aware leader
//! election: intra-node reduce to a per-node leader over PCIe, ring
//! allreduce among node leaders **within each ToR** (the logically
//! parallel per-ToR rings batch their rounds together so they contend
//! realistically at the leaf tier), a ring among per-ToR leaders across
//! the spine tier, a fan-out back to the node leaders, and an intra-node
//! broadcast. ToR membership comes from the fabric's
//! [`crate::fabric::topology::Topology`], not from a rack scalar — so
//! placements that span several leaf switches only cross the
//! oversubscribed uplinks during the (short) inter-ToR phase.
//!
//! With every rank under a single ToR this degenerates to exactly the
//! pre-topology algorithm: intra-node reduce, one ring over node
//! leaders, intra-node broadcast — the configuration Horovod+NCCL used
//! in the paper (2 GPUs/node on TX-GAIA).

use super::{Buffers, Collective, BYTES_PER_ELEM};
use crate::fabric::Comm;

#[derive(Default)]
pub struct Hierarchical {
    // Inner algorithm is currently always ring (NCCL-like). Kept as a
    // struct so ablations can extend it.
}

impl Collective for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn allreduce(&self, comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
        let p = comm.size();
        if p <= 1 {
            return comm.max_time();
        }
        let n = bufs.elems();
        let bytes = n as f64 * BYTES_PER_ELEM;
        let groups = comm.placement.by_node();
        let leaders: Vec<usize> = groups.iter().map(|g| elect(comm, g)).collect();

        // Phase 1: intra-node reduce to the leader (PCIe, point-to-point
        // links — no shared fabric resources).
        for (gi, g) in groups.iter().enumerate() {
            let leader = leaders[gi];
            for &r in g {
                if r == leader {
                    continue;
                }
                comm.p2p(r, leader, bytes);
                bufs.reduce_chunk(leader, r, 0..n);
            }
        }

        if leaders.len() > 1 {
            // Phase 2a: ring allreduce among node leaders within each
            // ToR. The per-ToR rings are logically parallel; their
            // rounds are submitted as merged batches so same-tier links
            // contend realistically. After this, every node leader holds
            // its ToR's partial sum.
            let tors: Vec<Vec<usize>> = {
                let topo = &comm.net.topology;
                comm.placement.group_by_node(&leaders, |node| topo.tor_of_node(node))
            };
            ring_over_groups(comm, bufs, &tors, n);

            if tors.len() > 1 {
                // Phase 2b: ring among the per-ToR leaders — the only
                // phase whose flows cross the (possibly oversubscribed)
                // leaf->spine uplinks. A ToR whose first leader's node
                // is down on the fault timeline re-elects (first
                // surviving member), so a leader death degrades the
                // step instead of wedging it.
                let tor_leaders: Vec<usize> = tors.iter().map(|g| elect(comm, g)).collect();
                ring_over_groups(comm, bufs, std::slice::from_ref(&tor_leaders), n);

                // Phase 2c: fan the global sum back out to the other
                // node leaders, all ToRs in one concurrent round.
                let mut msgs = Vec::new();
                let mut copies = Vec::new();
                for (ti, g) in tors.iter().enumerate() {
                    let leader = tor_leaders[ti];
                    for &r in g {
                        if r == leader {
                            continue;
                        }
                        msgs.push((leader, r, bytes));
                        copies.push((r, leader));
                    }
                }
                if !msgs.is_empty() {
                    comm.round(&msgs);
                    for (dst, src) in copies {
                        bufs.copy_chunk(dst, src, 0..n);
                    }
                }
            }
        }

        // Phase 3: intra-node broadcast from the leader.
        for (gi, g) in groups.iter().enumerate() {
            let leader = leaders[gi];
            for &r in g {
                if r == leader {
                    continue;
                }
                comm.p2p(leader, r, bytes);
                bufs.copy_chunk(r, leader, 0..n);
            }
        }
        comm.max_time()
    }
}

/// Pick a group's leader: the first member whose node is alive on the
/// attached fault timeline through the step's current horizon, so a
/// leader whose NIC is hard-down mid-step is replaced by the first
/// surviving member instead of wedging the collective. On a healthy
/// fabric (no timeline — the `faults = none` contract) this is exactly
/// the pre-fault choice `g[0]`, bit-for-bit; it is also the fallback
/// when every member's node is down (the flows then ride the transport
/// retry/failure accounting).
fn elect(comm: &Comm, g: &[usize]) -> usize {
    match comm.net.fault_timeline() {
        None => g[0],
        Some(tl) => {
            let at = comm.net.fault_clock() + comm.max_time();
            g.iter()
                .copied()
                .find(|&r| tl.node_alive(comm.placement.endpoints[r].node, at))
                .unwrap_or(g[0])
        }
    }
}

/// Ring allreduce (reduce-scatter + allgather) run over several disjoint
/// member groups in lockstep: round `k` of every group that still has a
/// round `k` is submitted as ONE communication round, so the logically
/// parallel rings share links instead of serializing. A single group is
/// exactly the classic ring over that subset.
fn ring_over_groups(comm: &mut Comm, bufs: &mut dyn Buffers, groups: &[Vec<usize>], n: usize) {
    let max_p = groups.iter().map(|g| g.len()).max().unwrap_or(0);
    if max_p < 2 {
        return;
    }
    // Chunk tables depend only on (n, group size): compute them once
    // ahead of the round loops, exactly as the old single-ring code did.
    let chunk_tables: Vec<Vec<std::ops::Range<usize>>> =
        groups.iter().map(|g| super::chunk_ranges(n, g.len().max(1))).collect();
    // Reduce-scatter rounds.
    for k in 0..max_p - 1 {
        let mut msgs: Vec<(usize, usize, f64)> = Vec::new();
        let mut reduces: Vec<(usize, usize, std::ops::Range<usize>)> = Vec::new();
        for (members, chunks) in groups.iter().zip(&chunk_tables) {
            let p = members.len();
            if p < 2 || k >= p - 1 {
                continue;
            }
            for idx in 0..p {
                let c = (idx + p - k) % p;
                msgs.push((
                    members[idx],
                    members[(idx + 1) % p],
                    chunks[c].len() as f64 * BYTES_PER_ELEM,
                ));
                reduces.push((members[(idx + 1) % p], members[idx], chunks[c].clone()));
            }
        }
        comm.round(&msgs);
        for (dst, src, range) in reduces {
            bufs.reduce_chunk(dst, src, range);
        }
    }
    // Allgather rounds.
    for k in 0..max_p - 1 {
        let mut msgs: Vec<(usize, usize, f64)> = Vec::new();
        let mut copies: Vec<(usize, usize, std::ops::Range<usize>)> = Vec::new();
        for (members, chunks) in groups.iter().zip(&chunk_tables) {
            let p = members.len();
            if p < 2 || k >= p - 1 {
                continue;
            }
            for idx in 0..p {
                let c = (idx + 1 + p - k) % p;
                msgs.push((
                    members[idx],
                    members[(idx + 1) % p],
                    chunks[c].len() as f64 * BYTES_PER_ELEM,
                ));
                copies.push((members[(idx + 1) % p], members[idx], chunks[c].clone()));
            }
        }
        comm.round(&msgs);
        for (dst, src, range) in copies {
            bufs.copy_chunk(dst, src, range);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Placement;
    use crate::collectives::testutil::{check_allreduce, gpu_world};
    use crate::collectives::{NullBuffers, RingAllreduce};
    use crate::config::presets::fabric;
    use crate::config::spec::{ClusterSpec, FabricKind, TransportOptions};
    use crate::fabric::NetSim;
    use crate::util::prop;

    #[test]
    fn correct_for_various_world_sizes() {
        // Even counts exercise 2-GPU nodes; odd counts leave a lone GPU on
        // the last node.
        for p in [2, 3, 4, 6, 8, 9, 16] {
            check_allreduce(&Hierarchical::default(), p, 88, 900 + p as u64);
        }
    }

    #[test]
    fn property_random_worlds() {
        prop::forall(66, 12, |r| {
            (2 + r.below(14) as usize, 1 + r.below(96) as usize, r.next_u64())
        }, |&(p, n, seed)| {
            check_allreduce(&Hierarchical::default(), p, n, seed);
            Ok(())
        });
    }

    #[test]
    fn beats_flat_ring_when_latency_bound() {
        // 64 GPUs on 32 nodes, small buffer: hierarchical's 2*(32-1)
        // network rounds beat the flat ring's 2*(64-1); the PCIe
        // reduce/bcast is cheap at this size.
        let elems = 20_000; // 80 KB
        let t_h = {
            let (mut net, placement) = gpu_world(64, FabricKind::EthernetRoce25);
            let mut comm = Comm::new(&mut net, &placement);
            Hierarchical::default().allreduce(&mut comm, &mut NullBuffers { elems })
        };
        let t_flat = {
            let (mut net, placement) = gpu_world(64, FabricKind::EthernetRoce25);
            let mut comm = Comm::new(&mut net, &placement);
            RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems })
        };
        assert!(t_h < t_flat, "hierarchical {t_h} !< flat {t_flat}");
    }

    #[test]
    fn flat_ring_competitive_on_large_buffers() {
        // Bandwidth-bound regime: the flat ring pipelines its intra-node
        // hops with the wire, while hierarchical pays the full-buffer PCIe
        // reduce/bcast serially. Both stay within 2x of each other (this
        // is the regime trade-off NCCL navigates with its own tuning).
        let elems = 2_000_000;
        let t_h = {
            let (mut net, placement) = gpu_world(64, FabricKind::EthernetRoce25);
            let mut comm = Comm::new(&mut net, &placement);
            Hierarchical::default().allreduce(&mut comm, &mut NullBuffers { elems })
        };
        let t_flat = {
            let (mut net, placement) = gpu_world(64, FabricKind::EthernetRoce25);
            let mut comm = Comm::new(&mut net, &placement);
            RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems })
        };
        let ratio = t_h / t_flat;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio} out of band");
    }

    #[test]
    fn single_node_stays_on_pcie() {
        // 2 GPUs on one node: no network messages at all.
        let (mut net, placement) = gpu_world(2, FabricKind::EthernetRoce25);
        let mut comm = Comm::new(&mut net, &placement);
        Hierarchical::default().allreduce(&mut comm, &mut NullBuffers { elems: 1000 });
        assert_eq!(net.stats.inter_node_messages, 0);
    }

    /// Cluster with tiny racks so modest rank counts span several ToRs.
    fn small_rack_world(ranks: usize) -> (NetSim, Placement) {
        let mut cluster = ClusterSpec::txgaia();
        cluster.nodes_per_rack = 2; // 4 GPUs per ToR
        let placement = Placement::gpus(&cluster, ranks).unwrap();
        let net = NetSim::new(
            fabric(FabricKind::EthernetRoce25),
            cluster,
            TransportOptions::default(),
        );
        (net, placement)
    }

    #[test]
    fn tor_aware_election_crosses_uplinks_less_than_flat_ring() {
        // 24 GPUs on 12 nodes over 6 two-node ToRs: the flat ring crosses
        // a ToR boundary ~6 times per round for 2*(12-1) leader rounds;
        // the ToR-aware hierarchy confines uplink crossings to the short
        // inter-ToR-leader ring.
        let elems = 50_000;
        let (mut net_h, placement_h) = small_rack_world(24);
        {
            let mut comm = Comm::new(&mut net_h, &placement_h);
            Hierarchical::default().allreduce(&mut comm, &mut NullBuffers { elems });
        }
        let (mut net_f, placement_f) = small_rack_world(24);
        {
            let mut comm = Comm::new(&mut net_f, &placement_f);
            RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems });
        }
        assert!(net_h.stats.inter_rack_messages > 0, "multi-ToR world must cross uplinks");
        assert!(
            net_h.stats.inter_rack_messages < net_f.stats.inter_rack_messages,
            "hierarchical {} !< flat ring {}",
            net_h.stats.inter_rack_messages,
            net_f.stats.inter_rack_messages
        );
    }

    #[test]
    fn dead_leader_node_is_re_elected_off_the_uplinks() {
        // Node 0 hosts the default leader of the first node AND the
        // first ToR. With its NIC hard-down for the whole run, ToR 0's
        // leadership must move to a surviving node: no inter-rack
        // message may touch node 0 (its unavoidable intra-ToR ring
        // flows still pay the transport retry/failure accounting), and
        // the allreduce still sums correctly — the step degrades, it
        // does not wedge.
        use crate::collectives::testutil::naive_sum;
        use crate::fabric::faults::{FaultEvent, FaultTarget};
        use crate::fabric::FaultSpec;
        let ranks = 12;
        let (mut net, placement) = small_rack_world(ranks);
        let spec = FaultSpec {
            events: vec![FaultEvent {
                target: FaultTarget::Nic(0),
                at: 0.0,
                duration: 1e6,
                factor: 0.0,
            }],
            ..FaultSpec::default()
        };
        net.set_faults(&spec).unwrap();
        net.enable_trace();
        let mut bufs = crate::collectives::testutil::random_buffers(ranks, 64, 42);
        let expect = naive_sum(&bufs);
        let t = {
            let mut comm = Comm::new(&mut net, &placement);
            Hierarchical::default().allreduce(&mut comm, &mut bufs)
        };
        assert!(t.is_finite() && t > 0.0);
        let trace = net.trace.as_ref().unwrap();
        assert!(
            trace
                .events
                .iter()
                .filter(|e| e.inter_rack)
                .all(|e| e.src_node != 0 && e.dst_node != 0),
            "a dead node kept ToR leadership across the uplinks"
        );
        assert!(net.stats.failed_flows > 0, "node 0's intra-ToR flows must fail loudly");
        for buf in &bufs.data {
            for (i, (got, want)) in buf.iter().zip(&expect).enumerate() {
                let tol = 1e-4 * want.abs().max(1.0);
                assert!((got - want).abs() <= tol, "elem {i}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn multi_tor_hierarchy_still_correct() {
        // Same oracle as check_allreduce but over the small-rack cluster,
        // so leader election genuinely goes multi-tier (2..=5 ToRs).
        use crate::collectives::testutil::naive_sum;
        for ranks in [5usize, 8, 12, 17] {
            let (mut net, placement) = small_rack_world(ranks);
            let mut bufs =
                crate::collectives::testutil::random_buffers(ranks, 97, 7 + ranks as u64);
            let expect = naive_sum(&bufs);
            let mut comm = Comm::new(&mut net, &placement);
            let t = Hierarchical::default().allreduce(&mut comm, &mut bufs);
            assert!(t > 0.0);
            for (r, buf) in bufs.data.iter().enumerate() {
                for (i, (got, want)) in buf.iter().zip(&expect).enumerate() {
                    let tol = 1e-4 * want.abs().max(1.0);
                    assert!(
                        (got - want).abs() <= tol,
                        "rank {r} elem {i}: {got} vs {want} (ranks={ranks})"
                    );
                }
            }
        }
    }
}
