//! Horovod-style gradient fusion: small gradient tensors are coalesced
//! into fixed-capacity fusion buffers before the allreduce, amortizing
//! per-message latency. Buckets are built in *backward order* (the order
//! gradients become available during backprop), which is what makes
//! compute/communication overlap possible in the trainer.

/// One fused allreduce message.
#[derive(Clone, Debug, PartialEq)]
pub struct Bucket {
    /// Indices into the tensor list (backward order).
    pub tensors: Vec<usize>,
    pub bytes: f64,
    /// Fraction of the backward pass completed when this bucket is ready
    /// (set by the trainer; 0.0 here).
    pub ready_frac: f64,
}

/// Greedily pack `tensor_bytes` (given in *forward* layer order) into
/// buckets of at most `max_bytes`, walking backward like backprop does.
/// A tensor larger than `max_bytes` gets its own bucket.
pub fn fuse(tensor_bytes: &[f64], max_bytes: f64) -> Vec<Bucket> {
    assert!(max_bytes > 0.0);
    let mut buckets = Vec::new();
    let mut cur = Bucket { tensors: Vec::new(), bytes: 0.0, ready_frac: 0.0 };
    for (idx, &b) in tensor_bytes.iter().enumerate().rev() {
        assert!(b >= 0.0, "negative tensor size");
        if !cur.tensors.is_empty() && cur.bytes + b > max_bytes {
            buckets.push(std::mem::replace(
                &mut cur,
                Bucket { tensors: Vec::new(), bytes: 0.0, ready_frac: 0.0 },
            ));
        }
        cur.tensors.push(idx);
        cur.bytes += b;
    }
    if !cur.tensors.is_empty() {
        buckets.push(cur);
    }
    // Annotate readiness: bucket i is ready once the backward pass has
    // produced all its tensors; approximate by cumulative byte fraction.
    let total: f64 = tensor_bytes.iter().sum();
    if total > 0.0 {
        let mut done = 0.0;
        for b in buckets.iter_mut() {
            done += b.bytes;
            b.ready_frac = done / total;
        }
    }
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn respects_capacity() {
        let sizes = vec![10.0, 20.0, 30.0, 40.0, 50.0];
        let buckets = fuse(&sizes, 60.0);
        for b in &buckets {
            if b.tensors.len() > 1 {
                assert!(b.bytes <= 60.0, "bucket over capacity: {b:?}");
            }
        }
    }

    #[test]
    fn oversize_tensor_gets_own_bucket() {
        let buckets = fuse(&[100.0, 5.0], 50.0);
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[1].tensors, vec![0]);
        assert_eq!(buckets[1].bytes, 100.0);
    }

    #[test]
    fn backward_order() {
        let buckets = fuse(&[1.0, 1.0, 1.0], 10.0);
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].tensors, vec![2, 1, 0]);
    }

    #[test]
    fn ready_frac_monotone_to_one() {
        let sizes = vec![8.0, 16.0, 32.0, 4.0, 4.0];
        let buckets = fuse(&sizes, 20.0);
        let mut last = 0.0;
        for b in &buckets {
            assert!(b.ready_frac > last);
            last = b.ready_frac;
        }
        assert!((last - 1.0).abs() < 1e-12);
    }

    #[test]
    fn property_partition_preserved() {
        prop::forall(88, 128, |r: &mut Rng| {
            let n = 1 + r.below(40) as usize;
            let sizes: Vec<f64> = (0..n).map(|_| r.uniform_in(0.0, 1000.0)).collect();
            let cap = r.uniform_in(1.0, 2000.0);
            (sizes, cap)
        }, |(sizes, cap)| {
            let buckets = fuse(sizes, *cap);
            let mut seen: Vec<usize> = buckets.iter().flat_map(|b| b.tensors.clone()).collect();
            seen.sort_unstable();
            if seen != (0..sizes.len()).collect::<Vec<_>>() {
                return Err("buckets are not a partition".into());
            }
            let total: f64 = buckets.iter().map(|b| b.bytes).sum();
            let want: f64 = sizes.iter().sum();
            if (total - want).abs() > 1e-6 * want.max(1.0) {
                return Err(format!("bytes not preserved: {total} vs {want}"));
            }
            for b in &buckets {
                if b.tensors.len() > 1 && b.bytes > *cap + 1e-9 {
                    return Err(format!("over capacity: {b:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_byte_tensors_fuse_without_dividing_by_zero() {
        // All-zero tensor list: one bucket holding every index, and the
        // ready_frac annotation must not produce NaN (total == 0 skips
        // the cumulative-fraction pass).
        let buckets = fuse(&[0.0, 0.0, 0.0], 10.0);
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].tensors, vec![2, 1, 0]);
        assert_eq!(buckets[0].bytes, 0.0);
        assert_eq!(buckets[0].ready_frac, 0.0, "zero total must not yield NaN");

        // Zero-byte tensors ride along with real ones for free.
        let mixed = fuse(&[0.0, 50.0, 0.0], 50.0);
        assert_eq!(mixed.len(), 1);
        assert_eq!(mixed[0].tensors, vec![2, 1, 0]);
        assert!((mixed[0].ready_frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_oversize_tensor_is_one_bucket() {
        // One tensor bigger than the cap: never split, never dropped.
        let buckets = fuse(&[1e9], 64.0);
        assert_eq!(buckets.len(), 1);
        assert_eq!(buckets[0].tensors, vec![0]);
        assert_eq!(buckets[0].bytes, 1e9);
        assert!((buckets[0].ready_frac - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exact_boundary_fills_the_bucket() {
        // A tensor that lands exactly on the capacity boundary still
        // joins the open bucket: the check is `> max_bytes`, not `>=`.
        let buckets = fuse(&[30.0, 30.0, 40.0], 60.0);
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[0].tensors, vec![2]);
        assert_eq!(buckets[1].tensors, vec![1, 0], "30 + 30 == 60 must fuse");
        assert_eq!(buckets[1].bytes, 60.0);
    }

    #[test]
    fn order_preserved_within_and_across_buckets() {
        // Backward (descending-index) order both inside each bucket and
        // across the bucket sequence — the trainer's overlap model
        // depends on it.
        let sizes: Vec<f64> = (0..17).map(|i| (i % 5 + 1) as f64).collect();
        let buckets = fuse(&sizes, 7.0);
        let flat: Vec<usize> = buckets.iter().flat_map(|b| b.tensors.clone()).collect();
        let want: Vec<usize> = (0..sizes.len()).rev().collect();
        assert_eq!(flat, want, "concatenated buckets must be exactly reverse order");
    }

    #[test]
    fn fewer_buckets_with_bigger_capacity() {
        let sizes: Vec<f64> = (0..64).map(|i| (i % 7 + 1) as f64 * 1e6).collect();
        let small = fuse(&sizes, 4e6).len();
        let large = fuse(&sizes, 64e6).len();
        assert!(large < small);
    }
}
