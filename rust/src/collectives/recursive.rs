//! Recursive halving-doubling allreduce (Rabenseifner's algorithm) —
//! latency-optimal `2 log2 p` rounds, bandwidth-comparable to ring for
//! power-of-two worlds. Non-power-of-two worlds fold the excess ranks
//! into the nearest power of two first (full-buffer pre-reduce +
//! post-broadcast), which is exactly why real MPI implementations show a
//! penalty at awkward world sizes.

use super::{Buffers, Collective, BYTES_PER_ELEM};
use crate::fabric::Comm;
use std::ops::Range;

pub struct RecursiveHalvingDoubling;

impl Collective for RecursiveHalvingDoubling {
    fn name(&self) -> &'static str {
        "rhd"
    }

    fn allreduce(&self, comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
        let p = comm.size();
        if p <= 1 {
            return comm.max_time();
        }
        let n = bufs.elems();
        let full_bytes = n as f64 * BYTES_PER_ELEM;

        // Largest power of two <= p.
        let p2 = usize::BITS as usize - 1 - p.leading_zeros() as usize;
        let p2 = 1usize << p2;
        let rem = p - p2;

        // Fold: ranks p2..p send their whole buffer into ranks 0..rem —
        // all transfers are concurrent, so they form one engine round.
        if rem > 0 {
            let msgs: Vec<(usize, usize, f64)> =
                (0..rem).map(|i| (p2 + i, i, full_bytes)).collect();
            comm.round(&msgs);
            for i in 0..rem {
                bufs.reduce_chunk(i, p2 + i, 0..n);
            }
        }

        // Recursive halving (reduce-scatter) among ranks 0..p2: each rank
        // tracks the segment it is responsible for. Every exchange of one
        // distance level happens simultaneously (as real MPI pairwise
        // exchanges do), so each level is one communication round.
        let mut seg: Vec<Range<usize>> = (0..p2).map(|_| 0..n).collect();
        let mut dist = p2 / 2;
        while dist >= 1 {
            let mut msgs: Vec<(usize, usize, f64)> = Vec::with_capacity(p2);
            let mut updates: Vec<(usize, usize, Range<usize>, Range<usize>)> =
                Vec::with_capacity(p2 / 2);
            for i in 0..p2 {
                let partner = i ^ dist;
                if partner < i {
                    continue; // handle each pair once
                }
                // Split the (identical) segment; lower rank keeps the
                // lower half.
                let s = seg[i].clone();
                debug_assert_eq!(seg[i], seg[partner]);
                let mid = s.start + (s.len() + 1) / 2;
                let lower = s.start..mid;
                let upper = mid..s.end;
                let (keep_i, keep_p) = if i & dist == 0 {
                    (lower.clone(), upper.clone())
                } else {
                    (upper.clone(), lower.clone())
                };
                // Each sends the half the partner keeps.
                msgs.push((i, partner, keep_p.len() as f64 * BYTES_PER_ELEM));
                msgs.push((partner, i, keep_i.len() as f64 * BYTES_PER_ELEM));
                updates.push((i, partner, keep_i, keep_p));
            }
            comm.round(&msgs);
            for (i, partner, keep_i, keep_p) in updates {
                bufs.reduce_chunk(partner, i, keep_p.clone());
                bufs.reduce_chunk(i, partner, keep_i.clone());
                seg[i] = keep_i;
                seg[partner] = keep_p;
            }
            dist /= 2;
        }

        // Recursive doubling (allgather): mirror image, one round per
        // distance level.
        let mut dist = 1;
        while dist < p2 {
            let mut msgs: Vec<(usize, usize, f64)> = Vec::with_capacity(p2);
            let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(p2 / 2);
            for i in 0..p2 {
                let partner = i ^ dist;
                if partner < i {
                    continue;
                }
                msgs.push((i, partner, seg[i].len() as f64 * BYTES_PER_ELEM));
                msgs.push((partner, i, seg[partner].len() as f64 * BYTES_PER_ELEM));
                pairs.push((i, partner));
            }
            comm.round(&msgs);
            for (i, partner) in pairs {
                bufs.copy_chunk(partner, i, seg[i].clone());
                bufs.copy_chunk(i, partner, seg[partner].clone());
                // Both now own the union (contiguous by construction).
                let lo = seg[i].start.min(seg[partner].start);
                let hi = seg[i].end.max(seg[partner].end);
                seg[i] = lo..hi;
                seg[partner] = lo..hi;
            }
            dist *= 2;
        }

        // Unfold: results back to the folded ranks, again as one round.
        if rem > 0 {
            let msgs: Vec<(usize, usize, f64)> =
                (0..rem).map(|i| (i, p2 + i, full_bytes)).collect();
            comm.round(&msgs);
            for i in 0..rem {
                bufs.copy_chunk(p2 + i, i, 0..n);
            }
        }
        comm.max_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::testutil::{check_allreduce, gpu_world};
    use crate::collectives::NullBuffers;
    use crate::config::spec::FabricKind;
    use crate::util::prop;

    #[test]
    fn correct_for_power_of_two_worlds() {
        for p in [2, 4, 8, 16, 32] {
            check_allreduce(&RecursiveHalvingDoubling, p, 97, p as u64);
        }
    }

    #[test]
    fn correct_for_non_power_of_two_worlds() {
        for p in [3, 5, 6, 7, 9, 12, 15] {
            check_allreduce(&RecursiveHalvingDoubling, p, 64, 100 + p as u64);
        }
    }

    #[test]
    fn correct_for_odd_sizes() {
        check_allreduce(&RecursiveHalvingDoubling, 8, 1, 1);
        check_allreduce(&RecursiveHalvingDoubling, 4, 3, 2);
        check_allreduce(&RecursiveHalvingDoubling, 16, 1023, 3);
    }

    #[test]
    fn property_random_worlds() {
        prop::forall(77, 12, |r| {
            (2 + r.below(14) as usize, 1 + r.below(100) as usize, r.next_u64())
        }, |&(p, n, seed)| {
            check_allreduce(&RecursiveHalvingDoubling, p, n, seed);
            Ok(())
        });
    }

    #[test]
    fn fewer_rounds_than_ring_for_small_buffers() {
        // Latency-bound regime: RHD's 2 log p rounds beat ring's 2(p-1).
        let p = 64;
        let elems = 256; // 1 KiB
        let (mut net, placement) = gpu_world(p, FabricKind::EthernetRoce25);
        let mut comm = Comm::new(&mut net, &placement);
        let t_rhd =
            RecursiveHalvingDoubling.allreduce(&mut comm, &mut NullBuffers { elems });
        let (mut net2, placement2) = gpu_world(p, FabricKind::EthernetRoce25);
        let mut comm2 = Comm::new(&mut net2, &placement2);
        let t_ring =
            crate::collectives::RingAllreduce.allreduce(&mut comm2, &mut NullBuffers { elems });
        assert!(t_rhd < t_ring, "rhd {t_rhd} !< ring {t_ring}");
    }

    #[test]
    fn non_pow2_fold_costs_extra() {
        let elems = 1_000_000;
        let run = |p| {
            let (mut net, placement) = gpu_world(p, FabricKind::OmniPath100);
            let mut comm = Comm::new(&mut net, &placement);
            RecursiveHalvingDoubling.allreduce(&mut comm, &mut NullBuffers { elems })
        };
        // 17 ranks folds one full buffer both ways; 16 doesn't.
        assert!(run(17) > run(16));
    }
}
