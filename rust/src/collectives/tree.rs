//! Binomial-tree reduce + broadcast allreduce. Latency-friendly
//! (`2 log2 p` rounds) but moves the **full buffer** every round, so it
//! loses badly to ring/RHD at gradient sizes — which is why it exists
//! here: it is the "wrong algorithm" curve in the strategy comparison.

use super::{Buffers, Collective, BYTES_PER_ELEM};
use crate::fabric::Comm;

pub struct BinomialTree;

impl Collective for BinomialTree {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn allreduce(&self, comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
        let p = comm.size();
        if p <= 1 {
            return comm.max_time();
        }
        let n = bufs.elems();
        let bytes = n as f64 * BYTES_PER_ELEM;

        // Reduce to rank 0: in round j, ranks with bit j set send their
        // partial sum to rank (i - 2^j) and go idle. All sends of one
        // level are concurrent — one engine round per level.
        let mut dist = 1;
        while dist < p {
            // `i % dist == 0` keeps only still-active ranks (multiples of
            // the current distance).
            let senders: Vec<usize> =
                (0..p).filter(|i| i & dist != 0 && i % dist == 0).collect();
            let msgs: Vec<(usize, usize, f64)> =
                senders.iter().map(|&i| (i, i - dist, bytes)).collect();
            comm.round(&msgs);
            for &i in &senders {
                bufs.reduce_chunk(i - dist, i, 0..n);
            }
            dist *= 2;
        }

        // Broadcast from rank 0 down the same tree, reversed.
        let mut dist = dist / 2;
        while dist >= 1 {
            let receivers: Vec<usize> =
                (0..p).filter(|i| i & dist != 0 && i % dist == 0).collect();
            let msgs: Vec<(usize, usize, f64)> =
                receivers.iter().map(|&i| (i - dist, i, bytes)).collect();
            comm.round(&msgs);
            for &i in &receivers {
                bufs.copy_chunk(i, i - dist, 0..n);
            }
            if dist == 1 {
                break;
            }
            dist /= 2;
        }
        comm.max_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::testutil::{check_allreduce, gpu_world};
    use crate::collectives::NullBuffers;
    use crate::config::spec::FabricKind;
    use crate::util::prop;

    #[test]
    fn correct_for_various_world_sizes() {
        for p in [2, 3, 4, 5, 7, 8, 11, 16] {
            check_allreduce(&BinomialTree, p, 77, 500 + p as u64);
        }
    }

    #[test]
    fn property_random_worlds() {
        prop::forall(55, 12, |r| {
            (2 + r.below(14) as usize, 1 + r.below(80) as usize, r.next_u64())
        }, |&(p, n, seed)| {
            check_allreduce(&BinomialTree, p, n, seed);
            Ok(())
        });
    }

    #[test]
    fn loses_to_ring_on_large_buffers() {
        let elems = 4_000_000; // 16 MB
        let p = 16;
        let t_tree = {
            let (mut net, placement) = gpu_world(p, FabricKind::OmniPath100);
            let mut comm = Comm::new(&mut net, &placement);
            BinomialTree.allreduce(&mut comm, &mut NullBuffers { elems })
        };
        let t_ring = {
            let (mut net, placement) = gpu_world(p, FabricKind::OmniPath100);
            let mut comm = Comm::new(&mut net, &placement);
            crate::collectives::RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems })
        };
        assert!(t_tree > 1.5 * t_ring, "tree {t_tree} !>> ring {t_ring}");
    }
}
