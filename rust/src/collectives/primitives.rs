//! Standalone collective primitives (broadcast, allgather,
//! reduce-scatter) and a segmented/pipelined ring allreduce — NCCL
//! exposes all of these and tf_cnn_benchmarks lets you pick between
//! allreduce/allgather-based variable updates, so the framework ships
//! them as first-class, tested operations.

use super::{chunk_ranges, Buffers, Collective, BYTES_PER_ELEM};
use crate::fabric::Comm;

/// Binomial broadcast from `root`: after it returns, every rank's buffer
/// equals `root`'s.
pub fn broadcast(comm: &mut Comm, bufs: &mut dyn Buffers, root: usize) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return comm.max_time();
    }
    let n = bufs.elems();
    let bytes = n as f64 * BYTES_PER_ELEM;
    // Relabel ranks so the tree is rooted at `root`.
    let rel = |v: usize| (v + root) % p;
    let mut dist = 1;
    while dist < p {
        dist *= 2;
    }
    let mut d = dist / 2;
    while d >= 1 {
        // Every transfer of one tree level is concurrent: one round.
        let level: Vec<(usize, usize)> = (0..p)
            .filter(|i| i & d != 0 && i % d == 0)
            .map(|i| (rel(i - d), rel(i)))
            .collect();
        let msgs: Vec<(usize, usize, f64)> =
            level.iter().map(|&(src, dst)| (src, dst, bytes)).collect();
        comm.round(&msgs);
        for &(src, dst) in &level {
            bufs.copy_chunk(dst, src, 0..n);
        }
        if d == 1 {
            break;
        }
        d /= 2;
    }
    comm.max_time()
}

/// Ring allgather: rank r contributes chunk r; afterwards every rank has
/// every chunk. (Chunks are positional slices of the buffer; callers lay
/// out their contribution in slice `chunks[r]`.)
pub fn allgather(comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return comm.max_time();
    }
    let n = bufs.elems();
    let chunks = chunk_ranges(n, p);
    for k in 0..p - 1 {
        let msgs: Vec<(usize, usize, f64)> = (0..p)
            .map(|i| {
                let c = (i + p - k) % p;
                (i, (i + 1) % p, chunks[c].len() as f64 * BYTES_PER_ELEM)
            })
            .collect();
        comm.round(&msgs);
        for i in 0..p {
            let c = (i + p - k) % p;
            bufs.copy_chunk((i + 1) % p, i, chunks[c].clone());
        }
    }
    comm.max_time()
}

/// Ring reduce-scatter: afterwards rank r's chunk r holds the sum of all
/// ranks' chunk r (other chunks hold partial garbage, as in MPI).
pub fn reduce_scatter(comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return comm.max_time();
    }
    let n = bufs.elems();
    let chunks = chunk_ranges(n, p);
    for k in 0..p - 1 {
        let msgs: Vec<(usize, usize, f64)> = (0..p)
            .map(|i| {
                let c = (i + p - k) % p;
                (i, (i + 1) % p, chunks[c].len() as f64 * BYTES_PER_ELEM)
            })
            .collect();
        comm.round(&msgs);
        for i in 0..p {
            let c = (i + p - k) % p;
            bufs.reduce_chunk((i + 1) % p, i, chunks[c].clone());
        }
    }
    comm.max_time()
}

/// Pairwise-exchange all-to-all: rank i's chunk j ends up on rank j (as
/// chunk i). `p-1` rounds; in round `k` every rank `i` sends its chunk
/// for `(i + k) % p` directly to that rank — the classic MPI pairwise
/// schedule, and what NCCL does for MoE expert dispatch.
///
/// Timing-only: the [`Buffers`] trait moves *positional* slices (chunk
/// `c` of the source lands in chunk `c` of the destination), but
/// all-to-all transposes chunk indices, so the data movement is not
/// expressible through it. Only the wire schedule matters for the fabric
/// benchmark; callers pass [`super::NullBuffers`].
pub fn alltoall(comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return comm.max_time();
    }
    let n = bufs.elems();
    let chunks = chunk_ranges(n, p);
    for k in 1..p {
        let msgs: Vec<(usize, usize, f64)> = (0..p)
            .map(|i| {
                let dst = (i + k) % p;
                (i, dst, chunks[dst].len() as f64 * BYTES_PER_ELEM)
            })
            .collect();
        comm.round(&msgs);
    }
    comm.max_time()
}

/// Segmented (pipelined) ring allreduce: the buffer is cut into
/// `segments` independent ring allreduces executed back-to-back on the
/// communication stream, letting chunk `s+1`'s reduce-scatter overlap
/// chunk `s`'s allgather in wire time — NCCL's pipelining trick. With
/// `segments == 1` it degenerates to the plain ring.
pub struct PipelinedRing {
    pub segments: usize,
}

impl Default for PipelinedRing {
    fn default() -> Self {
        PipelinedRing { segments: 4 }
    }
}

impl Collective for PipelinedRing {
    fn name(&self) -> &'static str {
        "ring-pipelined"
    }

    /// `segments` changes the message schedule, so it must discriminate
    /// cache entries (see [`Collective::schedule_signature`]).
    fn schedule_signature(&self) -> u64 {
        (super::fnv1a_str(self.name()) ^ self.segments as u64)
            .wrapping_mul(0x0000_0100_0000_01B3)
    }

    fn allreduce(&self, comm: &mut Comm, bufs: &mut dyn Buffers) -> f64 {
        let p = comm.size();
        if p <= 1 {
            return comm.max_time();
        }
        let n = bufs.elems();
        let segs = self.segments.max(1).min(n.max(1));
        let seg_ranges = chunk_ranges(n, segs);
        for seg in seg_ranges {
            if seg.is_empty() {
                continue;
            }
            // Plain ring over the segment: chunk ranges offset into it.
            let m = seg.len();
            let chunks: Vec<std::ops::Range<usize>> = chunk_ranges(m, p)
                .into_iter()
                .map(|r| seg.start + r.start..seg.start + r.end)
                .collect();
            for k in 0..p - 1 {
                let msgs: Vec<(usize, usize, f64)> = (0..p)
                    .map(|i| {
                        let c = (i + p - k) % p;
                        (i, (i + 1) % p, chunks[c].len() as f64 * BYTES_PER_ELEM)
                    })
                    .collect();
                comm.round(&msgs);
                for i in 0..p {
                    let c = (i + p - k) % p;
                    bufs.reduce_chunk((i + 1) % p, i, chunks[c].clone());
                }
            }
            for k in 0..p - 1 {
                let msgs: Vec<(usize, usize, f64)> = (0..p)
                    .map(|i| {
                        let c = (i + 1 + p - k) % p;
                        (i, (i + 1) % p, chunks[c].len() as f64 * BYTES_PER_ELEM)
                    })
                    .collect();
                comm.round(&msgs);
                for i in 0..p {
                    let c = (i + 1 + p - k) % p;
                    bufs.copy_chunk((i + 1) % p, i, chunks[c].clone());
                }
            }
        }
        comm.max_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::testutil::{check_allreduce, gpu_world, naive_sum, random_buffers};
    use crate::collectives::NullBuffers;
    use crate::config::spec::FabricKind;
    use crate::util::prop;

    #[test]
    fn schedule_signature_discriminates_parameters() {
        use crate::collectives::{Collective, RingAllreduce};
        let a = PipelinedRing { segments: 4 };
        let b = PipelinedRing { segments: 8 };
        assert_eq!(a.name(), b.name(), "same name is the aliasing hazard");
        assert_ne!(
            a.schedule_signature(),
            b.schedule_signature(),
            "segments must discriminate schedule-cache entries"
        );
        assert_ne!(a.schedule_signature(), RingAllreduce.schedule_signature());
        assert_eq!(a.schedule_signature(), PipelinedRing { segments: 4 }.schedule_signature());
    }

    #[test]
    fn broadcast_replicates_root() {
        for root in [0, 3, 7] {
            let (mut net, placement) = gpu_world(8, FabricKind::OmniPath100);
            let mut bufs = random_buffers(8, 33, 42 + root as u64);
            let want = bufs.data[root].clone();
            let mut comm = Comm::new(&mut net, &placement);
            let t = broadcast(&mut comm, &mut bufs, root);
            assert!(t > 0.0);
            for (r, b) in bufs.data.iter().enumerate() {
                assert_eq!(b, &want, "rank {r} differs from root {root}");
            }
        }
    }

    #[test]
    fn allgather_distributes_chunks() {
        let p = 6;
        let n = 25;
        let (mut net, placement) = gpu_world(p, FabricKind::OmniPath100);
        let mut bufs = random_buffers(p, n, 7);
        // Expected: chunk c (positional) of every rank ends equal to chunk
        // c of rank c.
        let chunks = chunk_ranges(n, p);
        let expect: Vec<Vec<f32>> =
            (0..p).map(|c| bufs.data[c][chunks[c].clone()].to_vec()).collect();
        let mut comm = Comm::new(&mut net, &placement);
        allgather(&mut comm, &mut bufs);
        for r in 0..p {
            for c in 0..p {
                assert_eq!(
                    &bufs.data[r][chunks[c].clone()],
                    &expect[c][..],
                    "rank {r} chunk {c}"
                );
            }
        }
    }

    #[test]
    fn reduce_scatter_sums_own_chunk() {
        let p = 5;
        let n = 23;
        let (mut net, placement) = gpu_world(p, FabricKind::OmniPath100);
        let mut bufs = random_buffers(p, n, 9);
        let want = naive_sum(&bufs);
        let chunks = chunk_ranges(n, p);
        let mut comm = Comm::new(&mut net, &placement);
        reduce_scatter(&mut comm, &mut bufs);
        for r in 0..p {
            // Rank r's *completed* chunk after p-1 rounds is (r+1) mod p.
            let c = (r + 1) % p;
            for (i, idx) in chunks[c].clone().enumerate() {
                let got = bufs.data[r][idx];
                let w = want[idx];
                assert!(
                    (got - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "rank {r} chunk {c} elem {i}: {got} vs {w}"
                );
            }
        }
    }

    #[test]
    fn alltoall_pairwise_schedule_covers_every_pair_once() {
        // Record the wire schedule: p-1 rounds, and across them every
        // ordered rank pair (i, j != i) appears exactly once, carrying
        // rank i's chunk-j bytes.
        let p = 6;
        let n = 25;
        let (mut net, placement) = gpu_world(p, FabricKind::EthernetRoce25);
        let mut rec = Comm::recorder(&mut net, &placement);
        alltoall(&mut rec, &mut NullBuffers { elems: n });
        let ops = rec.take_record().unwrap();
        let rounds: Vec<_> = ops
            .iter()
            .filter_map(|op| match op {
                crate::fabric::mpi::CommOp::Round(msgs) => Some(msgs.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(rounds.len(), p - 1, "pairwise exchange is p-1 rounds");
        let chunks = chunk_ranges(n, p);
        let mut seen = vec![vec![0u32; p]; p];
        for msgs in &rounds {
            assert_eq!(msgs.len(), p, "every rank sends each round");
            for &(src, dst, bytes) in msgs {
                assert_ne!(src, dst);
                seen[src][dst] += 1;
                let want = chunks[dst].len() as f64 * BYTES_PER_ELEM;
                assert_eq!(bytes.to_bits(), want.to_bits(), "{src}->{dst} bytes");
            }
        }
        for i in 0..p {
            for j in 0..p {
                let want = u32::from(i != j);
                assert_eq!(seen[i][j], want, "pair ({i}, {j}) count");
            }
        }
    }

    #[test]
    fn alltoall_advances_clocks_and_degenerates_solo() {
        let (mut net, placement) = gpu_world(4, FabricKind::EthernetRoce25);
        let mut comm = Comm::new(&mut net, &placement);
        let t = alltoall(&mut comm, &mut NullBuffers { elems: 4096 });
        assert!(t > 0.0, "all-to-all moved no time");

        let (mut net1, placement1) = gpu_world(1, FabricKind::EthernetRoce25);
        let mut solo = Comm::new(&mut net1, &placement1);
        let t1 = alltoall(&mut solo, &mut NullBuffers { elems: 4096 });
        assert_eq!(t1, 0.0, "single rank has nothing to exchange");
    }

    #[test]
    fn pipelined_ring_is_correct() {
        for segments in [1, 2, 4, 7] {
            check_allreduce(&PipelinedRing { segments }, 6, 101, 50 + segments as u64);
        }
    }

    #[test]
    fn pipelined_ring_property() {
        prop::forall(123, 10, |r| {
            (
                2 + r.below(8) as usize,
                1 + r.below(64) as usize,
                1 + r.below(6) as usize,
                r.next_u64(),
            )
        }, |&(p, n, segs, seed)| {
            check_allreduce(&PipelinedRing { segments: segs }, p, n, seed);
            Ok(())
        });
    }

    #[test]
    fn pipelining_helps_latency_hiding_at_scale() {
        // Large buffer over many ranks: segmented ring should not be
        // slower than the plain ring by more than the extra latency terms.
        let (mut net, placement) = gpu_world(32, FabricKind::EthernetRoce25);
        let mut comm = Comm::new(&mut net, &placement);
        let t_plain = crate::collectives::RingAllreduce
            .allreduce(&mut comm, &mut NullBuffers { elems: 4_000_000 });
        let (mut net2, placement2) = gpu_world(32, FabricKind::EthernetRoce25);
        let mut comm2 = Comm::new(&mut net2, &placement2);
        let t_seg = PipelinedRing { segments: 4 }
            .allreduce(&mut comm2, &mut NullBuffers { elems: 4_000_000 });
        assert!(t_seg < 1.3 * t_plain, "seg {t_seg} vs plain {t_plain}");
    }
}
