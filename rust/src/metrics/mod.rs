//! Result recording: every experiment saves its table as CSV under
//! results/ (and the CLI prints markdown), so EXPERIMENTS.md numbers have
//! on-disk provenance.

use crate::util::table::Table;
use std::path::{Path, PathBuf};

/// Where experiment results are written.
pub struct Recorder {
    pub dir: PathBuf,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Recorder { dir: PathBuf::from("results") }
    }

    pub fn at(dir: &Path) -> Self {
        Recorder { dir: dir.to_path_buf() }
    }

    /// Save a table as CSV; returns the path written.
    pub fn save(&self, name: &str, table: &Table) -> std::io::Result<PathBuf> {
        table.save_csv(&self.dir, name)
    }

    /// Print markdown and save CSV in one call.
    pub fn emit(&self, name: &str, table: &Table) {
        println!("{}", table.to_markdown());
        match self.save(name, table) {
            Ok(path) => println!("[saved {}]", path.display()),
            Err(e) => eprintln!("warning: could not save {name}.csv: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_roundtrip() {
        let dir = std::env::temp_dir().join("fabricbench_metrics_test");
        let rec = Recorder::at(&dir);
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into()]);
        let path = rec.save("demo", &t).unwrap();
        assert!(std::fs::read_to_string(path).unwrap().contains('1'));
    }
}
