//! The what-if simulation service (ROADMAP item 3): a long-running,
//! dependency-free HTTP/1.1 server that answers the paper's core
//! question — *how long does training job X take on fabric Y under load
//! Z?* — as a query against shared caches instead of a cold process
//! launch per config.
//!
//! ```text
//! POST /v1/whatif       {"config": "<run-config TOML>"}
//!                       → one canonical JSON result document
//! POST /v1/batch        {"cells": ["<TOML>", ...]}
//!                       → NDJSON, one chunk per cell, in cell order
//! GET  /v1/health       liveness + version
//! GET  /v1/cache/stats  hits / misses / coalesced / evictions / entries
//! ```
//!
//! Layering:
//!
//! * [`http`] — minimal HTTP/1.1 codec over `std::net` (no tokio; the
//!   container is offline and the `util/pool.rs` scoped-thread pool is
//!   the only concurrency primitive the codebase uses).
//! * [`whatif`] — the scenario parser/runner/serializer shared with the
//!   `run --config` CLI; a `/v1/whatif` response is byte-for-bit the
//!   `run --config <file> --json` output for the same config.
//! * [`cache`] — the shared LRU result store with single-flight
//!   coalescing, keyed by [`whatif::Scenario::signature`]. Identical
//!   concurrent queries run one simulation; repeats are served from
//!   memory; capacity is enforced by true LRU eviction (`GET
//!   /v1/cache/stats` exposes the counters).
//!
//! Accept model: the listener is non-blocking and shared by N worker
//! threads ([`crate::util::pool::run_workers`]); each worker accepts,
//! then handles one `Connection: close` request synchronously — a
//! simulation is CPU-bound for milliseconds-to-seconds, so thread-per-
//! request with a small fixed pool is the right shape, not an event
//! loop. Batch cells additionally fan out over the existing
//! [`crate::experiments::sweeps::Runner`] machinery, every cell passing
//! through the same shared cache (so two overlapping batches, or a
//! batch racing single queries, coalesce per cell).

pub mod cache;
pub mod http;
pub mod whatif;

use crate::experiments::sweeps::Runner;
use crate::util::json::{self, Json};
use cache::ResultCache;
use http::{read_request, write_response, ChunkedWriter, Request};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use whatif::Scenario;

const JSON_CT: &str = "application/json";
const NDJSON_CT: &str = "application/x-ndjson";
/// Per-connection socket timeout: a stalled client must not pin a
/// worker forever (simulations themselves run after the request is
/// fully read, so this bounds only I/O).
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Everything a request handler needs, shared across workers.
pub struct ServiceState {
    pub cache: ResultCache,
    /// Worker threads for `/v1/batch` cell fan-out.
    pub jobs: usize,
}

impl ServiceState {
    pub fn new(cache_entries: usize, jobs: usize) -> ServiceState {
        ServiceState { cache: ResultCache::new(cache_entries), jobs: jobs.max(1) }
    }
}

/// A background server instance (tests and embedders). Shuts down and
/// joins its threads on `stop()` or drop.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub state: Arc<ServiceState>,
}

impl ServerHandle {
    /// Bind `127.0.0.1:port` (0 = OS-assigned) and serve on `threads`
    /// background workers until dropped.
    pub fn start(
        port: u16,
        threads: usize,
        cache_entries: usize,
    ) -> anyhow::Result<ServerHandle> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let state = Arc::new(ServiceState::new(cache_entries, threads));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (st, sd) = (Arc::clone(&state), Arc::clone(&shutdown));
        let join = std::thread::spawn(move || accept_loops(&listener, threads, &st, &sd));
        Ok(ServerHandle { addr, shutdown, join: Some(join), state })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(self) {
        // Drop does the work; consuming self just makes intent explicit.
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The foreground entry point behind the `serve` CLI command: bind,
/// announce the resolved address (port 0 reports the real port), serve
/// until the process is killed.
pub fn serve_blocking(port: u16, threads: usize, cache_entries: usize) -> anyhow::Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    println!(
        "fabricbench what-if service listening on http://{addr} \
         ({threads} threads, cache {cache_entries} entries)"
    );
    let state = Arc::new(ServiceState::new(cache_entries, threads));
    let never = AtomicBool::new(false);
    accept_loops(&listener, threads, &state, &never);
    Ok(())
}

/// N workers share one non-blocking listener; each polls accept and
/// handles one whole connection at a time.
fn accept_loops(
    listener: &TcpListener,
    threads: usize,
    state: &Arc<ServiceState>,
    shutdown: &AtomicBool,
) {
    crate::util::pool::run_workers(threads, |_| {
        while !shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    // Connection-level I/O errors (client hung up
                    // mid-response) are that client's problem only.
                    let _ = handle_conn(state, stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
    });
}

fn handle_conn(state: &ServiceState, stream: TcpStream) -> std::io::Result<()> {
    // Accepted sockets may inherit the listener's non-blocking flag;
    // request handling wants plain blocking reads with a deadline.
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    match read_request(&mut reader) {
        Ok(req) => route(state, &req, &mut writer),
        Err(e) => error_response(&mut writer, 400, &format!("bad request: {e:#}")),
    }
}

fn route<W: Write>(state: &ServiceState, req: &Request, w: &mut W) -> std::io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/health") => write_response(w, 200, JSON_CT, health_body().as_bytes()),
        ("GET", "/v1/cache/stats") => {
            write_response(w, 200, JSON_CT, stats_body(&state.cache).as_bytes())
        }
        ("POST", "/v1/whatif") => whatif_route(state, req, w),
        ("POST", "/v1/batch") => batch_route(state, req, w),
        (_, "/v1/health" | "/v1/cache/stats" | "/v1/whatif" | "/v1/batch") => {
            error_response(w, 405, &format!("method {} not allowed here", req.method))
        }
        _ => error_response(w, 404, &format!("no route for '{}'", req.path)),
    }
}

fn health_body() -> String {
    format!(
        "{}\n",
        json::obj(vec![
            ("schema", json::s("fabricbench-health-v1")),
            ("service", json::s("fabricbench-whatif")),
            ("status", json::s("ok")),
            ("version", json::s(env!("CARGO_PKG_VERSION"))),
        ])
    )
}

fn stats_body(cache: &ResultCache) -> String {
    let s = cache.stats();
    format!(
        "{}\n",
        json::obj(vec![
            ("schema", json::s("fabricbench-cache-stats-v1")),
            ("capacity", json::num(s.capacity as f64)),
            ("entries", json::num(s.entries as f64)),
            ("hits", json::num(s.hits as f64)),
            ("misses", json::num(s.misses as f64)),
            ("coalesced", json::num(s.coalesced as f64)),
            ("evictions", json::num(s.evictions as f64)),
        ])
    )
}

fn error_response<W: Write>(w: &mut W, status: u16, msg: &str) -> std::io::Result<()> {
    let body = format!("{}\n", json::obj(vec![("error", json::s(msg))]));
    write_response(w, status, JSON_CT, body.as_bytes())
}

/// Parse one `{"config": "<toml>"}` request into a scenario + cache key.
fn parse_cell(cfg: &str) -> anyhow::Result<(Scenario, u64)> {
    let scenario = Scenario::from_toml_text(cfg)?;
    let sig = scenario.signature()?;
    Ok((scenario, sig))
}

fn whatif_route<W: Write>(state: &ServiceState, req: &Request, w: &mut W) -> std::io::Result<()> {
    let parsed = match std::str::from_utf8(&req.body)
        .map_err(anyhow::Error::from)
        .and_then(|text| Json::parse(text).map_err(anyhow::Error::from))
    {
        Ok(j) => j,
        Err(e) => return error_response(w, 400, &format!("request body is not JSON: {e:#}")),
    };
    let Some(cfg) = parsed.get("config").and_then(|x| x.as_str()) else {
        return error_response(w, 400, "body must be {\"config\": \"<run-config TOML>\"}");
    };
    let (scenario, sig) = match parse_cell(cfg) {
        Ok(x) => x,
        Err(e) => return error_response(w, 400, &format!("bad config: {e:#}")),
    };
    match state.cache.get_or_compute(sig, || scenario.response_body()) {
        Ok(payload) => write_response(w, 200, JSON_CT, payload.as_bytes()),
        Err(e) => error_response(w, 500, &format!("simulation failed: {e:#}")),
    }
}

/// `/v1/batch`: validate every cell up front (bad configs 400 before
/// any output), fan the grid out over the sweeps `Runner` with each
/// cell passing through the shared cache, then emit one NDJSON chunk
/// per cell in cell order. A cell whose *simulation* fails becomes an
/// `{"cell": i, "error": ...}` line rather than aborting its siblings.
fn batch_route<W: Write>(state: &ServiceState, req: &Request, w: &mut W) -> std::io::Result<()> {
    let parsed = match std::str::from_utf8(&req.body)
        .map_err(anyhow::Error::from)
        .and_then(|text| Json::parse(text).map_err(anyhow::Error::from))
    {
        Ok(j) => j,
        Err(e) => return error_response(w, 400, &format!("request body is not JSON: {e:#}")),
    };
    let Some(cells) = parsed.get("cells").and_then(|x| x.as_arr()) else {
        return error_response(w, 400, "body must be {\"cells\": [\"<TOML>\", ...]}");
    };
    let mut specs: Vec<(Scenario, u64)> = Vec::with_capacity(cells.len());
    for (i, cell) in cells.iter().enumerate() {
        let Some(cfg) = cell.as_str() else {
            return error_response(w, 400, &format!("cell {i} must be a TOML config string"));
        };
        match parse_cell(cfg) {
            Ok(x) => specs.push(x),
            Err(e) => return error_response(w, 400, &format!("cell {i}: {e:#}")),
        }
    }
    let runner = Runner::new(state.jobs);
    let results: Vec<Result<Arc<String>, String>> = runner.map(&specs, |_, (scenario, sig)| {
        state
            .cache
            .get_or_compute(*sig, || scenario.response_body())
            .map_err(|e| format!("{e:#}"))
    });
    let mut cw = ChunkedWriter::new(w, NDJSON_CT);
    for (i, r) in results.iter().enumerate() {
        match r {
            Ok(payload) => cw.chunk(payload.as_bytes())?,
            Err(msg) => {
                let line = format!(
                    "{}\n",
                    json::obj(vec![("cell", json::num(i as f64)), ("error", json::s(msg))])
                );
                cw.chunk(line.as_bytes())?;
            }
        }
    }
    cw.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_and_stats_bodies_are_valid_json_lines() {
        let h = health_body();
        assert!(h.ends_with('\n'));
        let j = Json::parse(h.trim_end()).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));

        let cache = ResultCache::new(8);
        cache.get_or_compute(1, || Ok("x".into())).unwrap();
        cache.get_or_compute(1, || Ok("x".into())).unwrap();
        let s = stats_body(&cache);
        let j = Json::parse(s.trim_end()).unwrap();
        assert_eq!(j.get("hits").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("misses").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("capacity").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn routes_reject_wrong_method_and_unknown_path() {
        let state = ServiceState::new(4, 1);
        let req = |method: &str, path: &str| Request {
            method: method.into(),
            path: path.into(),
            headers: Vec::new(),
            body: Vec::new(),
        };
        let mut out = Vec::new();
        route(&state, &req("POST", "/v1/health"), &mut out).unwrap();
        assert!(String::from_utf8(out).unwrap().starts_with("HTTP/1.1 405"));
        let mut out = Vec::new();
        route(&state, &req("GET", "/nope"), &mut out).unwrap();
        assert!(String::from_utf8(out).unwrap().starts_with("HTTP/1.1 404"));
        let mut out = Vec::new();
        route(&state, &req("POST", "/v1/whatif"), &mut out).unwrap();
        assert!(String::from_utf8(out).unwrap().starts_with("HTTP/1.1 400"));
    }
}
