//! Minimal HTTP/1.1 codec for the what-if service — request parsing and
//! response writing over `std::io` streams, no external crates (the
//! container is offline; tokio/hyper are unavailable by design).
//!
//! Scope is deliberately narrow: one request per connection
//! (`Connection: close`), `Content-Length` bodies on input, and either
//! fixed-length or chunked (`Transfer-Encoding: chunked`, used for the
//! batch endpoint's NDJSON stream) bodies on output. That covers curl,
//! python's `urllib`/`http.client`, and the in-repo test client; it is
//! not a general web server.

use std::io::{BufRead, Write};

/// Caps keep a malformed or hostile client from ballooning memory: the
/// request line + headers and the body are each bounded.
const MAX_HEAD_BYTES: usize = 64 * 1024;
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Header names lowercased at parse time (HTTP headers are
    /// case-insensitive); values are trimmed verbatim.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Parse one request from the stream. Errors are protocol violations
/// the caller should answer with 400 and close on.
pub fn read_request<R: BufRead>(r: &mut R) -> anyhow::Result<Request> {
    let mut line = String::new();
    let mut head_bytes = 0usize;
    read_line_capped(r, &mut line, &mut head_bytes)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request line missing path"))?
        .to_string();
    let version = parts.next().unwrap_or("");
    anyhow::ensure!(
        version.starts_with("HTTP/1."),
        "unsupported protocol version '{version}'"
    );
    let mut headers = Vec::new();
    loop {
        line.clear();
        read_line_capped(r, &mut line, &mut head_bytes)?;
        if line.is_empty() {
            break;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("malformed header line"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let req = Request { method, path, headers, body: Vec::new() };
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad content-length '{v}'"))?,
    };
    anyhow::ensure!(
        len <= MAX_BODY_BYTES,
        "request body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte limit"
    );
    let mut body = vec![0u8; len];
    std::io::Read::read_exact(r, &mut body)?;
    Ok(Request { body, ..req })
}

/// Read one CRLF (or bare-LF) terminated line, enforcing the head cap.
fn read_line_capped<R: BufRead>(
    r: &mut R,
    line: &mut String,
    total: &mut usize,
) -> anyhow::Result<()> {
    let n = r.read_line(line)?;
    anyhow::ensure!(n > 0, "connection closed mid-request");
    *total += n;
    anyhow::ensure!(
        *total <= MAX_HEAD_BYTES,
        "request head exceeds the {MAX_HEAD_BYTES}-byte limit"
    );
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(())
}

pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Fixed-length response; the body is written verbatim, so cached and
/// freshly-computed payloads stay byte-identical on the wire.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        status_text(status),
        content_type,
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()
}

/// Chunked streaming writer for the batch endpoint: one chunk per
/// finished cell, so clients see results as they land instead of after
/// the whole grid.
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
    started: bool,
    content_type: &'static str,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    pub fn new(w: &'a mut W, content_type: &'static str) -> ChunkedWriter<'a, W> {
        ChunkedWriter { w, started: false, content_type }
    }

    fn start(&mut self) -> std::io::Result<()> {
        if !self.started {
            write!(
                self.w,
                "HTTP/1.1 200 OK\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
                self.content_type
            )?;
            self.started = true;
        }
        Ok(())
    }

    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(()); // a zero-length chunk would terminate the stream
        }
        self.start()?;
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    pub fn finish(mut self) -> std::io::Result<()> {
        self.start()?;
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/whatif HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\r\nhello world";
        let req = read_request(&mut BufReader::new(&raw[..])).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/whatif");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"hello world");
    }

    #[test]
    fn parses_get_without_body_and_case_insensitive_headers() {
        let raw = b"GET /v1/health HTTP/1.1\r\ncOnTeNt-TyPe: application/json\r\n\r\n";
        let req = read_request(&mut BufReader::new(&raw[..])).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert_eq!(req.header("content-type"), Some("application/json"));
    }

    #[test]
    fn rejects_protocol_garbage() {
        for raw in [
            &b"\r\n\r\n"[..],
            &b"GET\r\n\r\n"[..],
            &b"GET / SPDY/9\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n"[..],
        ] {
            assert!(read_request(&mut BufReader::new(raw)).is_err(), "{raw:?}");
        }
    }

    #[test]
    fn truncated_body_is_an_error_not_a_hang() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort";
        assert!(read_request(&mut BufReader::new(&raw[..])).is_err());
    }

    #[test]
    fn response_and_chunked_framing() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{\"a\":1}\n").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 8\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"a\":1}\n"), "{text}");

        let mut out = Vec::new();
        let mut cw = ChunkedWriter::new(&mut out, "application/x-ndjson");
        cw.chunk(b"line one\n").unwrap();
        cw.chunk(b"line two\n").unwrap();
        cw.finish().unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Transfer-Encoding: chunked"), "{text}");
        assert!(text.contains("9\r\nline one\n\r\n"), "{text}");
        assert!(text.ends_with("0\r\n\r\n"), "{text}");
    }
}
