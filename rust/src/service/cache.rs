//! The shared result-cache tier of the what-if service: a true-LRU
//! bounded store plus single-flight request coalescing.
//!
//! Two layers, separable on purpose:
//!
//! * [`LruCache`] — a small, dependency-free, deterministic LRU map.
//!   Backed by a flat `Vec` with a logical access clock; capacities in
//!   this codebase are tens-to-hundreds of entries, where a linear scan
//!   beats hash-map + intrusive-list bookkeeping and keeps the code
//!   auditable. Both [`crate::trainer::scheduler::ScheduleCache`] tiers
//!   and the service's [`ResultCache`] evict through this one
//!   implementation (previously the schedule cache *cleared itself* at
//!   capacity, throwing away the whole working set whenever a sweep
//!   crossed `MAX_PATTERNS`).
//! * [`ResultCache`] — the concurrency-safe cross-request memo keyed by
//!   the 64-bit scenario signature ([`crate::service::whatif`]): a
//!   `Mutex<LruCache>` plus a single-flight table, so N identical
//!   in-flight queries run **one** simulation and share the same
//!   `Arc<String>` payload. Hit/miss/coalesce/evict counters feed
//!   `GET /v1/cache/stats`.
//!
//! Correctness note: values are the final serialized response bytes of
//! deterministic simulations, so serving a cached `Arc` is byte-for-byte
//! what recomputation would produce — caching is a pure speedup, never a
//! semantic change (the same contract the per-sim caches already pin).

use std::sync::{Arc, Condvar, Mutex};

/// Deterministic LRU map over a flat vec (see module docs for why not a
/// hash map). `get` and `insert` both count as a "use".
pub struct LruCache<K, V> {
    entries: Vec<(K, V, u64)>,
    /// Logical access clock; strictly increasing, so last-use ticks are
    /// unique and eviction order is total.
    tick: u64,
    cap: usize,
    /// Total entries evicted to make room (never counts replacements).
    pub evictions: u64,
}

impl<K: PartialEq, V> LruCache<K, V> {
    /// `cap` is clamped to at least 1 — a zero-capacity cache would turn
    /// every insert into an immediate silent eviction.
    pub fn new(cap: usize) -> LruCache<K, V> {
        LruCache { entries: Vec::new(), tick: 0, cap: cap.max(1), evictions: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn get(&mut self, key: &K) -> Option<&V> {
        self.get_with(|k| k == key)
    }

    /// Predicate lookup for callers whose key is expensive to build (the
    /// schedule cache's timing tier compares borrowed bit slices without
    /// allocating a key). Marks the entry used on a hit.
    pub fn get_with<P: FnMut(&K) -> bool>(&mut self, mut pred: P) -> Option<&V> {
        let i = self.entries.iter().position(|(k, _, _)| pred(k))?;
        self.tick += 1;
        self.entries[i].2 = self.tick;
        Some(&self.entries[i].1)
    }

    /// Insert or replace. At capacity the least-recently-used entry is
    /// evicted — and only that one (no wholesale clearing).
    pub fn insert(&mut self, key: K, val: V) {
        self.tick += 1;
        if let Some(i) = self.entries.iter().position(|(k, _, _)| *k == key) {
            self.entries[i].1 = val;
            self.entries[i].2 = self.tick;
            return;
        }
        if self.entries.len() >= self.cap {
            let oldest = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, _, used))| *used)
                .map(|(i, _)| i)
                .expect("cap >= 1 so a full cache is non-empty");
            self.entries.swap_remove(oldest);
            self.evictions += 1;
        }
        self.entries.push((key, val, self.tick));
    }
}

/// Counter snapshot surfaced by `GET /v1/cache/stats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResultCacheStats {
    /// Requests served from the LRU without waiting on anyone.
    pub hits: u64,
    /// Requests that ran the simulation (each miss = one compute).
    pub misses: u64,
    /// Requests that blocked on an identical in-flight computation and
    /// shared its result (single-flight coalescing).
    pub coalesced: u64,
    /// LRU evictions performed to stay within capacity.
    pub evictions: u64,
    pub entries: usize,
    pub capacity: usize,
}

struct FlightTable {
    lru: LruCache<u64, Arc<String>>,
    /// Signatures currently being computed by some thread.
    inflight: Vec<u64>,
    hits: u64,
    misses: u64,
    coalesced: u64,
}

/// Concurrency-safe memo with single-flight coalescing (module docs).
pub struct ResultCache {
    state: Mutex<FlightTable>,
    done: Condvar,
}

impl ResultCache {
    pub fn new(cap: usize) -> ResultCache {
        ResultCache {
            state: Mutex::new(FlightTable {
                lru: LruCache::new(cap),
                inflight: Vec::new(),
                hits: 0,
                misses: 0,
                coalesced: 0,
            }),
            done: Condvar::new(),
        }
    }

    pub fn stats(&self) -> ResultCacheStats {
        let st = self.state.lock().expect("result cache poisoned");
        ResultCacheStats {
            hits: st.hits,
            misses: st.misses,
            coalesced: st.coalesced,
            evictions: st.lru.evictions,
            entries: st.lru.len(),
            capacity: st.lru.capacity(),
        }
    }

    /// Return the cached payload for `key`, computing it at most once
    /// across all concurrent callers. While one thread computes, every
    /// other caller with the same key blocks and then shares the same
    /// `Arc` (counted as `coalesced`, not `hits`). Errors are **not**
    /// cached: the failing leader wakes the waiters, one of them becomes
    /// the new leader, and each caller gets its own error if the
    /// computation keeps failing.
    pub fn get_or_compute<F>(&self, key: u64, compute: F) -> anyhow::Result<Arc<String>>
    where
        F: FnOnce() -> anyhow::Result<String>,
    {
        let mut st = self.state.lock().expect("result cache poisoned");
        let mut waited = false;
        loop {
            if let Some(v) = st.lru.get(&key) {
                let out = Arc::clone(v);
                if waited {
                    st.coalesced += 1;
                } else {
                    st.hits += 1;
                }
                return Ok(out);
            }
            if st.inflight.contains(&key) {
                waited = true;
                st = self.done.wait(st).expect("result cache poisoned");
                continue;
            }
            st.inflight.push(key);
            st.misses += 1;
            break;
        }
        drop(st);
        let result = compute();
        let mut st = self.state.lock().expect("result cache poisoned");
        st.inflight.retain(|k| *k != key);
        let out = match result {
            Ok(body) => {
                let payload = Arc::new(body);
                st.lru.insert(key, Arc::clone(&payload));
                Ok(payload)
            }
            Err(e) => Err(e),
        };
        drop(st);
        self.done.notify_all();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn lru_evicts_least_recently_used_in_order() {
        let mut c: LruCache<u32, &str> = LruCache::new(3);
        c.insert(1, "a");
        c.insert(2, "b");
        c.insert(3, "c");
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(&"a"));
        c.insert(4, "d");
        assert_eq!(c.len(), 3);
        assert_eq!(c.evictions, 1);
        assert_eq!(c.get(&2), None, "2 was least-recently-used");
        assert_eq!(c.get(&1), Some(&"a"));
        // Next victim must be 3 (1 and 4 are fresher).
        c.insert(5, "e");
        assert_eq!(c.get(&3), None);
        assert_eq!(c.get(&4), Some(&"d"));
        assert_eq!(c.get(&5), Some(&"e"));
        assert_eq!(c.evictions, 2);
    }

    #[test]
    fn lru_replacement_does_not_evict() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11); // replace in place
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions, 0);
        assert_eq!(c.get(&1), Some(&11));
        assert_eq!(c.get(&2), Some(&20));
    }

    #[test]
    fn lru_never_exceeds_capacity() {
        let mut c: LruCache<u64, u64> = LruCache::new(4);
        for i in 0..100u64 {
            c.insert(i, i * i);
            assert!(c.len() <= 4);
        }
        assert_eq!(c.evictions, 96);
        // The four most recent keys survive.
        for i in 96..100u64 {
            assert_eq!(c.get(&i), Some(&(i * i)));
        }
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let mut c: LruCache<u8, u8> = LruCache::new(0);
        c.insert(1, 1);
        assert_eq!(c.get(&1), Some(&1));
        assert_eq!(c.capacity(), 1);
    }

    #[test]
    fn get_with_marks_used() {
        let mut c: LruCache<u32, &str> = LruCache::new(2);
        c.insert(1, "a");
        c.insert(2, "b");
        assert_eq!(c.get_with(|k| *k == 1), Some(&"a"));
        c.insert(3, "c"); // must evict 2, not the just-touched 1
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&2), None);
    }

    #[test]
    fn result_cache_hits_after_miss_and_stays_bounded() {
        let cache = ResultCache::new(2);
        for key in [1u64, 2, 3, 2, 3] {
            let got = cache.get_or_compute(key, || Ok(format!("r{key}"))).unwrap();
            assert_eq!(*got, format!("r{key}"));
        }
        let s = cache.stats();
        assert_eq!(s.misses, 3, "{s:?}");
        assert_eq!(s.hits, 2, "{s:?}");
        assert_eq!(s.evictions, 1, "{s:?}"); // key 1 fell out at cap 2
        assert!(s.entries <= 2, "{s:?}");
    }

    #[test]
    fn result_cache_does_not_cache_errors() {
        let cache = ResultCache::new(4);
        let err = cache.get_or_compute(7, || anyhow::bail!("transient"));
        assert!(err.is_err());
        let ok = cache.get_or_compute(7, || Ok("recovered".to_string())).unwrap();
        assert_eq!(*ok, "recovered");
        assert_eq!(cache.stats().misses, 2, "error must not poison the key");
    }

    #[test]
    fn result_cache_coalesces_concurrent_identical_queries() {
        // All threads release together on one key whose computation is
        // slow: exactly one simulation runs, everyone shares its bytes.
        let cache = ResultCache::new(4);
        let computes = AtomicUsize::new(0);
        let n = 8;
        let start = Barrier::new(n);
        let payloads: Vec<Arc<String>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    scope.spawn(|| {
                        start.wait();
                        cache
                            .get_or_compute(42, || {
                                computes.fetch_add(1, Ordering::SeqCst);
                                std::thread::sleep(std::time::Duration::from_millis(200));
                                Ok("slow result".to_string())
                            })
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(computes.load(Ordering::SeqCst), 1, "single-flight violated");
        for p in &payloads {
            assert!(Arc::ptr_eq(p, &payloads[0]), "coalesced callers must share one Arc");
        }
        let s = cache.stats();
        assert_eq!(s.misses, 1, "{s:?}");
        // A thread descheduled past the whole compute window would land
        // as a plain hit, so pin the sum exactly and the coalesce floor.
        assert_eq!(s.coalesced + s.hits, (n - 1) as u64, "{s:?}");
        assert!(s.coalesced >= 1, "{s:?}");
    }
}
