//! One what-if query: a full single-job scenario (cluster + fabric +
//! transport + tenancy + workload + faults + model + run window) parsed
//! from the same TOML the `run --config` CLI takes, plus its canonical
//! JSON answer and its cache signature.
//!
//! This is the `cmd_run_config` single-job path hoisted out of `main.rs`
//! so the CLI and the HTTP service share **one** parser, one simulator
//! entry point and one serializer — which is what makes the service's
//! headline guarantee cheap to keep: a `/v1/whatif` response is
//! byte-for-bit the `run --config ... --json` output for the same
//! config, cold cache or warm (the CI smoke job diffs them).
//!
//! The cache signature composes the signatures the simulator already
//! maintains for its own exact-keyed memo tiers —
//! [`crate::trainer::scheduler::world_sig`] (topology + fabric +
//! placement), [`crate::fabric::FaultSpec::signature`],
//! [`crate::config::TenancySpec::signature`] — and folds in every
//! remaining knob a response byte can depend on (transport, workload,
//! model, batch, run window). Two configs that hash alike but differ in
//! any of those fields would be a correctness bug, so each field is
//! FNV-folded individually (no XOR-combining, same rule as the tenancy
//! signature).

use crate::cluster::Placement;
use crate::config::spec::{
    ClusterSpec, FabricSpec, ParallelismKind, RunSpec, TenancySpec, TransportOptions,
    WorkloadSpec,
};
use crate::fabric::{FaultSpec, NetSim};
use crate::models::Arch;
use crate::trainer::coordinator::{ThroughputResult, DEFAULT_COORDINATION_OVERHEAD};
use crate::trainer::TrainerSim;
use crate::util::hash::{fnv1a_bytes, fnv1a_u64, FNV_OFFSET};
use crate::util::json::{self, Json};
use anyhow::{anyhow, Result};

/// Response schema tag; bump on any change to the emitted shape.
pub const SCHEMA: &str = "fabricbench-whatif-v1";

/// A fully-resolved single-job what-if scenario.
pub struct Scenario {
    pub cluster: ClusterSpec,
    pub fabric: FabricSpec,
    pub opts: TransportOptions,
    pub tenancy: TenancySpec,
    pub workload: WorkloadSpec,
    pub faults: FaultSpec,
    pub arch: Arch,
    pub gpus: usize,
    pub per_gpu_batch: usize,
    pub fusion_mib: f64,
    pub overlap: bool,
    pub run: RunSpec,
}

impl Scenario {
    /// Parse the service-facing TOML text. Rejects `[fleet]` configs:
    /// the what-if endpoints price exactly one job (the fleet scheduler
    /// emits a multi-job report with a different shape — use the CLI).
    pub fn from_toml_text(text: &str) -> Result<Scenario> {
        let doc = crate::config::toml::parse(text)?;
        if doc.get("fleet").is_some() {
            anyhow::bail!(
                "config has a [fleet] table; /v1/whatif prices single jobs — \
                 run fleet scenarios through the `run --config` CLI"
            );
        }
        Scenario::from_doc(&doc)
    }

    /// Build from a parsed TOML document, applying the same defaults and
    /// validation as the `run --config` CLI. A `[fleet]` table (if any)
    /// is ignored here — the CLI branches on it separately.
    pub fn from_doc(doc: &Json) -> Result<Scenario> {
        let cluster = match doc.get("cluster") {
            Some(v) => ClusterSpec::from_toml(v)?,
            None => ClusterSpec::txgaia(),
        };
        let opts = match doc.get("transport") {
            Some(v) => TransportOptions::from_toml(v)?,
            None => TransportOptions::default(),
        };
        let mut fabric = FabricSpec::from_toml(
            doc.get("fabric").ok_or_else(|| anyhow!("config missing [fabric]"))?,
        )?;
        // Optional [topology] table: explicit fat-tree / dragonfly tiers
        // above the NICs. Absent, the fabric keeps its preset (the
        // legacy scalar rack-uplink model, bit-for-bit).
        if let Some(v) = doc.get("topology") {
            fabric.topology = crate::config::TopologySpec::from_toml(v)?;
        }
        fabric.topology.validate_for(&cluster)?;
        // Optional [tenancy] table: shared-tenancy background traffic +
        // stragglers. Absent, the system is dedicated — bit-for-bit the
        // pre-tenancy model.
        let tenancy = match doc.get("tenancy") {
            Some(v) => TenancySpec::from_toml(v)?,
            None => TenancySpec::default(),
        };
        if tenancy.background_active() {
            // Surface node-set misconfiguration before the run starts.
            tenancy.resolve_sets(&cluster)?;
        }
        // Optional [workload] table: which parallelism strategy the step
        // lowers to. Absent, the classic bucketed-DP path, bit-for-bit.
        let workload = match doc.get("workload") {
            Some(v) => WorkloadSpec::from_toml(v)?,
            None => WorkloadSpec::default(),
        };
        // Optional [faults] table: deterministic fabric fault trace.
        // Absent, the fabric is healthy — bit-for-bit the pre-fault
        // engine.
        let faults = match doc.get("faults") {
            Some(v) => FaultSpec::from_toml(v)?,
            None => FaultSpec::default(),
        };
        faults.validate()?;
        let train = doc.get("train").ok_or_else(|| anyhow!("config missing [train]"))?;
        let model = train.get("model").and_then(|x| x.as_str()).unwrap_or("resnet50");
        let arch = crate::models::zoo::by_name(model)
            .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
        let gpus = match train.get("gpus") {
            None => 8,
            Some(v) => v
                .as_usize()
                .ok_or_else(|| anyhow!("[train] gpus must be a non-negative integer"))?,
        };
        let per_gpu_batch = match train.get("per_gpu_batch") {
            None => 64,
            Some(v) => v
                .as_usize()
                .ok_or_else(|| anyhow!("[train] per_gpu_batch must be a non-negative integer"))?,
        };
        let fusion_mib = train.get("fusion_mib").and_then(|x| x.as_f64()).unwrap_or(64.0);
        let overlap = !matches!(train.get("overlap"), Some(Json::Bool(false)));
        let mut run = RunSpec::default();
        if let Some(r) = doc.get("run") {
            if let Some(seed) = r.get("seed").and_then(|x| x.as_usize()) {
                run.seed = seed as u64;
            }
            if let Some(w) = r.get("warmup_steps").and_then(|x| x.as_usize()) {
                run.warmup_steps = w;
            }
            if let Some(m) = r.get("measure_steps").and_then(|x| x.as_usize()) {
                run.measure_steps = m;
            }
        }
        Ok(Scenario {
            cluster,
            fabric,
            opts,
            tenancy,
            workload,
            faults,
            arch,
            gpus,
            per_gpu_batch,
            fusion_mib,
            overlap,
            run,
        })
    }

    /// Assemble the trainer exactly as the CLI does.
    pub fn trainer(&self) -> TrainerSim {
        TrainerSim {
            arch: self.arch.clone(),
            fabric: self.fabric.clone(),
            cluster: self.cluster.clone(),
            opts: self.opts,
            strategy: Box::new(crate::collectives::RingAllreduce),
            per_gpu_batch: self.per_gpu_batch,
            precision: crate::models::perf::Precision::Fp32,
            fusion_bytes: self.fusion_mib * crate::util::units::MIB,
            overlap: self.overlap,
            step_overhead: 0.0,
            coordination_overhead: DEFAULT_COORDINATION_OVERHEAD,
            tenancy: self.tenancy.clone(),
            workload: self.workload.clone(),
            faults: self.faults.clone(),
        }
    }

    pub fn run_sim(&self) -> Result<ThroughputResult> {
        self.trainer().run(self.gpus, &self.run)
    }

    /// The cross-request cache key (see module docs). Built on the same
    /// world signature the schedule cache keys by, then extended with
    /// every remaining response-affecting field. Performance toggles
    /// that are bit-exact by contract (`schedule_cache`,
    /// `flow_aggregation`, `solver_threads`) are folded anyway: aliasing
    /// them would be *correct* but folding is safer-by-default and only
    /// costs a cold cell per A/B arm.
    pub fn signature(&self) -> Result<u64> {
        let net = NetSim::try_new(self.fabric.clone(), self.cluster.clone(), self.opts)?;
        let placement = Placement::gpus(&self.cluster, self.gpus)?;
        let mut h = crate::trainer::scheduler::world_sig(&net, &placement);
        h = fnv1a_u64(h, self.faults.signature());
        h = fnv1a_u64(h, self.tenancy.signature());
        // Transport: world_sig already folds flow_aggregation; fold the
        // rest field by field.
        h = fnv1a_u64(h, self.opts.gpudirect as u64);
        h = fnv1a_u64(h, self.opts.use_rdma as u64);
        h = fnv1a_u64(h, self.opts.num_streams as u64);
        h = fnv1a_u64(h, opt_bits(self.opts.rendezvous_threshold));
        h = fnv1a_u64(h, opt_bits(self.opts.chunk_bytes));
        h = fnv1a_u64(h, self.opts.schedule_cache as u64);
        h = fnv1a_u64(h, self.opts.solver_threads as u64);
        h = fnv1a_u64(h, self.opts.retry_timeout.to_bits());
        h = fnv1a_u64(h, self.opts.retry_backoff.to_bits());
        h = fnv1a_u64(h, self.opts.max_retries as u64);
        // Workload IR shape.
        h = fnv1a_bytes(h, self.workload.parallelism.name().as_bytes());
        h = fnv1a_u64(h, self.workload.pipeline_stages as u64);
        h = fnv1a_u64(h, self.workload.microbatches as u64);
        h = fnv1a_u64(h, self.workload.activation_mib.to_bits());
        h = fnv1a_u64(h, self.workload.moe_layers as u64);
        h = fnv1a_u64(h, self.workload.moe_expert_mib.to_bits());
        // Model + trainer knobs.
        h = fnv1a_bytes(h, self.arch.name.as_bytes());
        h = fnv1a_u64(h, self.gpus as u64);
        h = fnv1a_u64(h, self.per_gpu_batch as u64);
        h = fnv1a_u64(h, self.fusion_mib.to_bits());
        h = fnv1a_u64(h, self.overlap as u64);
        // Run window.
        h = fnv1a_u64(h, self.run.seed);
        h = fnv1a_u64(h, self.run.warmup_steps as u64);
        h = fnv1a_u64(h, self.run.measure_steps as u64);
        h = fnv1a_u64(h, self.run.jitter_sigma.to_bits());
        Ok(h)
    }

    /// The canonical response document. `Json::Obj` is a `BTreeMap`, so
    /// key order — and therefore the emitted bytes — are deterministic.
    pub fn response_json(&self) -> Result<Json> {
        let r = self.run_sim()?;
        Ok(json::obj(vec![
            ("schema", json::s(SCHEMA)),
            (
                "config",
                json::obj(vec![
                    ("model", json::s(&self.arch.name)),
                    ("fabric", json::s(&self.fabric.name)),
                    ("gpus", json::num(self.gpus as f64)),
                    ("per_gpu_batch", json::num(self.per_gpu_batch as f64)),
                    ("streams", json::num(self.opts.num_streams as f64)),
                    ("parallelism", json::s(self.workload.parallelism.name())),
                    ("background_load", json::num(self.tenancy.background_load)),
                    ("seed", json::num(self.run.seed as f64)),
                    ("warmup_steps", json::num(self.run.warmup_steps as f64)),
                    ("measure_steps", json::num(self.run.measure_steps as f64)),
                ]),
            ),
            (
                "result",
                json::obj(vec![
                    ("images_per_sec", json::num(r.images_per_sec)),
                    ("linear_images_per_sec", json::num(r.linear_images_per_sec)),
                    ("step_time_mean_s", json::num(r.step_time_mean)),
                    ("step_time_p95_s", json::num(r.step_time_p95)),
                    ("scaling_efficiency", json::num(r.scaling_efficiency())),
                    ("exposed_comm_fraction", json::num(r.comm_fraction)),
                    ("fault_exposure", json::num(r.fault_exposure)),
                ]),
            ),
        ]))
    }

    /// The exact wire/file payload: canonical JSON plus one trailing
    /// newline (NDJSON-ready, byte-diffable against `run --json`).
    pub fn response_body(&self) -> Result<String> {
        Ok(format!("{}\n", self.response_json()?))
    }
}

/// `None` and `Some(x)` must never alias, nor `Some(0.0)` and `None`:
/// fold a presence tag with the payload bits.
fn opt_bits(x: Option<f64>) -> u64 {
    match x {
        None => FNV_OFFSET,
        Some(v) => fnv1a_u64(1, v.to_bits()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CFG: &str = r#"
[fabric]
kind = "25gbe-roce"

[train]
model = "resnet50"
gpus = 8
per_gpu_batch = 32

[run]
seed = 7
warmup_steps = 1
measure_steps = 3
"#;

    #[test]
    fn parses_minimal_config_with_cli_defaults() {
        let s = Scenario::from_toml_text(CFG).unwrap();
        assert_eq!(s.arch.name, "resnet50");
        assert_eq!(s.gpus, 8);
        assert_eq!(s.per_gpu_batch, 32);
        assert_eq!(s.fusion_mib, 64.0);
        assert!(s.overlap);
        assert_eq!(s.run.seed, 7);
        assert_eq!(s.run.warmup_steps, 1);
        assert_eq!(s.run.measure_steps, 3);
    }

    #[test]
    fn response_is_deterministic_and_parses() {
        let s = Scenario::from_toml_text(CFG).unwrap();
        let a = s.response_body().unwrap();
        let b = s.response_body().unwrap();
        assert_eq!(a, b, "same scenario must serialize to identical bytes");
        assert!(a.ends_with('\n'));
        let j = Json::parse(a.trim_end()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(SCHEMA));
        assert!(j.get("result").unwrap().get("images_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("config").unwrap().get("gpus").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn signature_separates_every_knob_it_folds() {
        let base = Scenario::from_toml_text(CFG).unwrap();
        let sig = base.signature().unwrap();
        // Same text, same signature.
        assert_eq!(sig, Scenario::from_toml_text(CFG).unwrap().signature().unwrap());
        let mut gpus = Scenario::from_toml_text(CFG).unwrap();
        gpus.gpus = 16;
        assert_ne!(sig, gpus.signature().unwrap());
        let mut seed = Scenario::from_toml_text(CFG).unwrap();
        seed.run.seed = 8;
        assert_ne!(sig, seed.signature().unwrap());
        let mut batch = Scenario::from_toml_text(CFG).unwrap();
        batch.per_gpu_batch = 64;
        assert_ne!(sig, batch.signature().unwrap());
        let mut streams = Scenario::from_toml_text(CFG).unwrap();
        streams.opts.num_streams = 4;
        assert_ne!(sig, streams.signature().unwrap());
        let mut par = Scenario::from_toml_text(CFG).unwrap();
        par.workload.parallelism = ParallelismKind::Zero;
        assert_ne!(sig, par.signature().unwrap());
        let mut chunk = Scenario::from_toml_text(CFG).unwrap();
        chunk.opts.chunk_bytes = Some(0.0);
        assert_ne!(sig, chunk.signature().unwrap(), "None vs Some(0.0) must not alias");
    }

    #[test]
    fn fleet_configs_are_rejected_loudly() {
        let cfg = format!("{CFG}\n[fleet]\njobs = 4\n");
        let err = Scenario::from_toml_text(&cfg).unwrap_err().to_string();
        assert!(err.contains("fleet"), "unexpected error: {err}");
    }

    #[test]
    fn missing_fabric_is_loud() {
        let err = Scenario::from_toml_text("[train]\nmodel = \"resnet50\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("[fabric]"), "unexpected error: {err}");
    }
}
