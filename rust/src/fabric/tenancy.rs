//! Background cross-traffic from the fabric's other tenants.
//!
//! The paper's headline claim is about **shared** HPC systems, so the
//! simulator must be able to populate the fabric with competing flows.
//! [`BackgroundTraffic`] is a deterministic, seeded flow generator: a
//! poisson or on-off arrival process over configurable source/destination
//! node sets (neighbor-rack incast, all-to-all shuffle — see
//! [`TenancySpec`]), whose flows are injected into
//! [`crate::fabric::NetSim::transfer_batch`] as first-class flows that
//! share NIC ports, rack up/down-links and spine links **max-min fairly**
//! with the training job's traffic.
//!
//! # Determinism and load coupling
//!
//! The generator owns a private [`Rng`] seeded from
//! `spec.seed XOR run_seed`, advanced in a fixed draw order
//! (gap, source, destination, thinning coin) regardless of configuration,
//! and restarted with an epoch-advanced seed on every
//! [`crate::fabric::NetSim::reset`] — each training step sees a fresh but
//! reproducible background realization, independent of `--jobs` (every
//! sweep cell owns its simulator and generator).
//!
//! Loads are realized by **thinning**: arrivals are always drawn at the
//! full (load = 1) rate and each is accepted with probability
//! `background_load`. At a fixed seed the accepted flow set at load `a`
//! is therefore a strict subset of the set at load `b > a`, which turns
//! "more background load never speeds training up" into a coupled
//! property instead of a statistical hope.
//!
//! The full rate is calibrated to the pattern's aggregate *bottleneck*
//! capacity (destination NICs for incast, source NICs for shuffle), so
//! `background_load <= 1` keeps the background queue stable by
//! construction.

use crate::config::{ClusterSpec, FabricSpec, SourceModel, TenancySpec, TrafficPattern};
use crate::util::rng::Rng;
use anyhow::Result;

/// One background flow to inject: node-level endpoints, payload and the
/// virtual time its payload exists at the source.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BgFlow {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
    pub ready: f64,
}

/// Deterministic background flow generator (see the module docs).
#[derive(Clone, Debug)]
pub struct BackgroundTraffic {
    spec: TenancySpec,
    /// Tenant source / destination node sets. Endpoint draws are
    /// *index*-based (`set[rng.below(len)]`), so a contiguous set built
    /// from the spec's `(first, count)` range replays bit-identically to
    /// the original range arithmetic, while fleet jobs can hand in
    /// arbitrary (non-contiguous) node sets.
    srcs: Vec<usize>,
    dsts: Vec<usize>,
    /// Aggregate arrival rate at load = 1, flows/second.
    full_rate: f64,
    base_seed: u64,
    epoch: u64,
    rng: Rng,
    /// Time of the last drawn arrival (the generation cursor).
    t: f64,
    /// On-off phase state (poisson stays permanently "on").
    in_on: bool,
    phase_end: f64,
    /// The next drawn arrival (with its thinning verdict), held back when
    /// it lies past the requested window so no draw is ever lost.
    pending: Option<(BgFlow, bool)>,
}

impl BackgroundTraffic {
    /// Build a generator for one simulator. Fails loudly when the spec's
    /// node sets do not fit the cluster.
    pub fn new(
        spec: &TenancySpec,
        fabric: &FabricSpec,
        cluster: &ClusterSpec,
        run_seed: u64,
    ) -> Result<Self> {
        let (srcs, dsts) = spec.resolve_sets(cluster)?;
        Self::with_node_sets(
            spec,
            fabric,
            run_seed,
            (srcs.0..srcs.0 + srcs.1).collect(),
            (dsts.0..dsts.0 + dsts.1).collect(),
        )
    }

    /// Build a generator over *explicit* node sets — the fleet
    /// scheduler's path, where a tenant is a placed job whose nodes are
    /// whatever the placement policy chose (possibly non-contiguous).
    /// The spec's own `src_first`/`src_count` range is ignored; pattern,
    /// load, flow size, source model and seed still apply. Fails loudly
    /// on empty sets or a singleton destination overlapping the sources
    /// (the self-send remap needs an alternative destination).
    pub fn with_node_sets(
        spec: &TenancySpec,
        fabric: &FabricSpec,
        run_seed: u64,
        srcs: Vec<usize>,
        dsts: Vec<usize>,
    ) -> Result<Self> {
        anyhow::ensure!(!srcs.is_empty(), "tenant source set is empty");
        anyhow::ensure!(!dsts.is_empty(), "tenant destination set is empty");
        anyhow::ensure!(
            dsts.len() >= 2 || !srcs.contains(&dsts[0]),
            "a single-destination set overlapping the sources cannot remap self-sends"
        );
        let bottleneck = match spec.pattern {
            TrafficPattern::Incast => dsts.len(),
            TrafficPattern::Shuffle => srcs.len(),
        };
        let full_rate = bottleneck as f64 * fabric.effective_bandwidth() / spec.flow_bytes;
        let mut bg = BackgroundTraffic {
            spec: *spec,
            srcs,
            dsts,
            full_rate,
            base_seed: spec.seed ^ run_seed,
            epoch: 0,
            rng: Rng::new(0),
            t: 0.0,
            in_on: false,
            phase_end: 0.0,
            pending: None,
        };
        bg.restart();
        Ok(bg)
    }

    /// The spec this generator realizes.
    pub fn spec(&self) -> &TenancySpec {
        &self.spec
    }

    /// Stable hash of the tenancy configuration (for cache-key folding).
    /// Folds the *realized* node sets, so two fleet tenants with the
    /// same spec on different placements hash apart.
    pub fn signature(&self) -> u64 {
        use crate::util::hash::fnv1a_u64;
        let mut h = fnv1a_u64(self.spec.signature(), 0xB6_7E7A);
        for &n in &self.srcs {
            h = fnv1a_u64(h, n as u64);
        }
        h = fnv1a_u64(h, u64::MAX);
        for &n in &self.dsts {
            h = fnv1a_u64(h, n as u64);
        }
        h
    }

    fn restart(&mut self) {
        // Epoch-salted seed: each step (simulator reset) replays a fresh
        // but reproducible realization of the same process.
        self.rng = Rng::new(self.base_seed ^ self.epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.t = 0.0;
        self.in_on = false;
        self.phase_end = 0.0;
        self.pending = None;
    }

    /// Restart the stream for a new step/experiment (called by
    /// [`crate::fabric::NetSim::reset`]); virtual time restarts at zero
    /// with the next epoch's realization.
    pub fn advance_epoch(&mut self) {
        self.epoch += 1;
        self.restart();
    }

    /// Arrival rate while the source is emitting: poisson sources emit
    /// continuously; on-off sources compress the same average into
    /// bursts (rate / duty-cycle during on phases).
    fn on_rate(&self) -> f64 {
        match self.spec.source {
            SourceModel::Poisson => self.full_rate,
            SourceModel::OnOff => {
                let duty = self.spec.burst_secs / (self.spec.burst_secs + self.spec.idle_secs);
                self.full_rate / duty
            }
        }
    }

    /// Advance the cursor by `gap` seconds of *emitting* time, skipping
    /// over off phases for on-off sources.
    fn advance_time(&mut self, gap: f64) {
        match self.spec.source {
            SourceModel::Poisson => self.t += gap,
            SourceModel::OnOff => {
                let mut g = gap;
                loop {
                    if !self.in_on {
                        // Jump over the idle phase, then open a burst.
                        self.t = self.t.max(self.phase_end);
                        let burst = self.rng.exponential(self.spec.burst_secs);
                        self.phase_end = self.t + burst;
                        self.in_on = true;
                    }
                    let room = self.phase_end - self.t;
                    if g <= room {
                        self.t += g;
                        return;
                    }
                    g -= room;
                    self.t = self.phase_end;
                    let idle = self.rng.exponential(self.spec.idle_secs);
                    self.phase_end = self.t + idle;
                    self.in_on = false;
                }
            }
        }
    }

    fn draw_endpoints(&mut self) -> (usize, usize) {
        let src = self.srcs[self.rng.below(self.srcs.len() as u64) as usize];
        let j = self.rng.below(self.dsts.len() as u64) as usize;
        let mut dst = self.dsts[j];
        if dst == src {
            // Deterministic remap instead of a redraw, so the draw count
            // (and thus the coupling across loads) never depends on the
            // collision pattern. Construction guarantees an alternative
            // destination exists whenever a collision is possible. For a
            // contiguous set the index step equals the old value step, so
            // range-spec streams replay bit-identically.
            dst = self.dsts[(j + 1) % self.dsts.len()];
        }
        (src, dst)
    }

    /// Append every accepted flow with `ready <= t_hi` to `out`,
    /// advancing the cursor. Monotone: each drawn arrival is emitted (or
    /// thinned away) exactly once across successive calls.
    pub fn flows_until(&mut self, t_hi: f64, out: &mut Vec<BgFlow>) {
        loop {
            if let Some((flow, accepted)) = self.pending {
                if flow.ready > t_hi {
                    return;
                }
                if accepted {
                    out.push(flow);
                }
                self.pending = None;
            }
            let gap = self.rng.exponential(1.0 / self.on_rate());
            self.advance_time(gap);
            let (src, dst) = self.draw_endpoints();
            // Thinning coin drawn unconditionally: the stream consumed is
            // identical for every load, so higher loads accept supersets.
            let accepted = self.rng.uniform() < self.spec.background_load;
            self.pending = Some((
                BgFlow { src, dst, bytes: self.spec.flow_bytes, ready: self.t },
                accepted,
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::FabricKind;

    fn generator(spec: TenancySpec, run_seed: u64) -> BackgroundTraffic {
        BackgroundTraffic::new(
            &spec,
            &fabric(FabricKind::EthernetRoce25),
            &ClusterSpec::txgaia(),
            run_seed,
        )
        .unwrap()
    }

    fn drain(bg: &mut BackgroundTraffic, t_hi: f64) -> Vec<BgFlow> {
        let mut out = Vec::new();
        bg.flows_until(t_hi, &mut out);
        out
    }

    #[test]
    fn stream_is_deterministic_and_seed_sensitive() {
        let spec = TenancySpec::neighbor_incast(0.5);
        let a = drain(&mut generator(spec, 7), 0.05);
        let b = drain(&mut generator(spec, 7), 0.05);
        assert!(!a.is_empty(), "50% load over 50 ms must emit flows");
        assert_eq!(a, b, "same seed must replay bit-identically");
        let c = drain(&mut generator(spec, 8), 0.05);
        assert_ne!(a, c, "the run seed folds into the stream");
    }

    #[test]
    fn flows_land_in_configured_sets_and_never_self_send() {
        let spec = TenancySpec::neighbor_incast(0.8);
        let flows = drain(&mut generator(spec, 1), 0.02);
        for f in &flows {
            assert!((32..64).contains(&f.src), "src {} outside the second rack", f.src);
            assert!(f.dst < 8, "incast dst {} outside the first rack head", f.dst);
            assert_ne!(f.src, f.dst);
            assert!(f.bytes > 0.0 && f.ready >= 0.0);
        }
        let spec = TenancySpec {
            pattern: TrafficPattern::Shuffle,
            background_load: 0.8,
            src_first: Some(0),
            src_count: Some(4),
            ..Default::default()
        };
        let flows = drain(&mut generator(spec, 1), 0.02);
        assert!(!flows.is_empty());
        for f in &flows {
            assert!(f.src < 4 && f.dst < 4);
            assert_ne!(f.src, f.dst, "shuffle must remap self-sends");
        }
    }

    #[test]
    fn explicit_node_sets_replay_ranges_and_honor_membership() {
        // A contiguous explicit set must replay the range-spec stream
        // bit-identically (the index-based draw refactor is invisible)...
        let spec = TenancySpec::neighbor_incast(0.7);
        let from_range = drain(&mut generator(spec, 9), 0.03);
        let mut explicit = BackgroundTraffic::with_node_sets(
            &spec,
            &fabric(FabricKind::EthernetRoce25),
            9,
            (32..64).collect(),
            (0..8).collect(),
        )
        .unwrap();
        assert_eq!(from_range, drain(&mut explicit, 0.03));

        // ...and a non-contiguous set (a spread-placed fleet job) keeps
        // every flow inside its membership, never self-sending.
        let srcs = vec![3, 17, 42, 99];
        let dsts = vec![5, 17, 61];
        let mut bg = BackgroundTraffic::with_node_sets(
            &spec,
            &fabric(FabricKind::EthernetRoce25),
            2,
            srcs.clone(),
            dsts.clone(),
        )
        .unwrap();
        let flows = drain(&mut bg, 0.05);
        assert!(!flows.is_empty());
        for f in &flows {
            assert!(srcs.contains(&f.src), "src {} outside the job's nodes", f.src);
            assert!(dsts.contains(&f.dst), "dst {} outside the target set", f.dst);
            assert_ne!(f.src, f.dst);
        }
        // Loud failures: empty sets and un-remappable singletons.
        assert!(BackgroundTraffic::with_node_sets(
            &spec,
            &fabric(FabricKind::EthernetRoce25),
            0,
            vec![],
            vec![1],
        )
        .is_err());
        assert!(BackgroundTraffic::with_node_sets(
            &spec,
            &fabric(FabricKind::EthernetRoce25),
            0,
            vec![4],
            vec![4],
        )
        .is_err());
    }

    #[test]
    fn thinning_couples_loads_into_supersets() {
        // The load-0.2 flow set must be a subset of the load-0.7 set at
        // the same seed — the property the sweep's monotonicity rests on.
        let lo = drain(&mut generator(TenancySpec::neighbor_incast(0.2), 3), 0.05);
        let hi = drain(&mut generator(TenancySpec::neighbor_incast(0.7), 3), 0.05);
        assert!(lo.len() < hi.len());
        for f in &lo {
            assert!(hi.contains(f), "low-load flow {f:?} missing from the high-load set");
        }
    }

    #[test]
    fn windows_partition_the_stream() {
        // Draining in two windows must equal draining in one: no flow is
        // lost or duplicated at a window boundary.
        let spec = TenancySpec::neighbor_incast(0.6);
        let whole = drain(&mut generator(spec, 11), 0.04);
        let mut split = generator(spec, 11);
        let mut parts = drain(&mut split, 0.013);
        parts.extend(drain(&mut split, 0.04));
        assert_eq!(whole, parts);
        assert!(whole.windows(2).all(|w| w[0].ready <= w[1].ready), "arrivals must be ordered");
    }

    #[test]
    fn epoch_advance_gives_fresh_but_reproducible_realizations() {
        let spec = TenancySpec::neighbor_incast(0.5);
        let mut a = generator(spec, 5);
        let first = drain(&mut a, 0.03);
        a.advance_epoch();
        let second = drain(&mut a, 0.03);
        assert_ne!(first, second, "each epoch is a fresh realization");
        let mut b = generator(spec, 5);
        drain(&mut b, 0.03);
        b.advance_epoch();
        assert_eq!(second, drain(&mut b, 0.03), "epochs replay bit-identically");
    }

    #[test]
    fn on_off_bursts_and_matches_average_rate() {
        let mut p = TenancySpec::neighbor_incast(1.0);
        p.source = SourceModel::OnOff;
        let flows = drain(&mut generator(p, 2), 0.5);
        // Average rate over a long window ~= the poisson full rate.
        let poisson = drain(&mut generator(TenancySpec::neighbor_incast(1.0), 2), 0.5);
        let ratio = flows.len() as f64 / poisson.len() as f64;
        assert!((0.6..1.4).contains(&ratio), "on-off average rate off: {ratio}");
        // Bursty: the largest inter-arrival gap dwarfs the median one.
        let gaps: Vec<f64> = flows.windows(2).map(|w| w[1].ready - w[0].ready).collect();
        let mut sorted = gaps.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = sorted[sorted.len() / 2];
        let max = *sorted.last().unwrap();
        assert!(max > 20.0 * median.max(1e-9), "no idle gaps: max {max} vs median {median}");
    }
}
