//! Message-level event tracing: when enabled on a [`crate::fabric::NetSim`],
//! every delivered message is recorded with its endpoints, size and
//! virtual-time window. The analysis here turns a trace into the
//! questions a fabric engineer actually asks: which node's NIC is
//! hottest, how much traffic crossed racks, what the utilization
//! timeline looked like.

use crate::util::table::{fnum, Table};
use crate::util::units::{fmt_bytes, fmt_time};

/// One delivered message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MessageEvent {
    pub src_node: usize,
    pub dst_node: usize,
    pub bytes: f64,
    pub start: f64,
    pub end: f64,
    pub inter_rack: bool,
    /// Which tenant this message belongs to. `0` is the *observing* job's
    /// own traffic; any other id is a co-located tenant — either the
    /// anonymous generator from [`crate::fabric::tenancy`] (id 1) or an
    /// attributed fleet job (its job id, see `cluster::scheduler`).
    pub tenant: usize,
}

impl MessageEvent {
    /// True for any traffic that is not the observing job's own.
    pub fn is_background(&self) -> bool {
        self.tenant != 0
    }
}

/// A recorded simulation trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<MessageEvent>,
}

impl Trace {
    pub fn record(&mut self, ev: MessageEvent) {
        self.events.push(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Virtual time span covered by the trace.
    pub fn span(&self) -> (f64, f64) {
        let lo = self.events.iter().map(|e| e.start).fold(f64::INFINITY, f64::min);
        let hi = self.events.iter().map(|e| e.end).fold(0.0, f64::max);
        (lo.min(hi), hi)
    }

    /// Training-job bytes transmitted per node (tx side), sorted
    /// descending. Background-tenant traffic is excluded, mirroring the
    /// engine-stats contract (training counters stay training-only) —
    /// the tenant's share is in [`Trace::tenant_bytes`].
    pub fn bytes_by_node(&self) -> Vec<(usize, f64)> {
        let mut map: std::collections::BTreeMap<usize, f64> = Default::default();
        for e in self.events.iter().filter(|e| !e.is_background()) {
            *map.entry(e.src_node).or_insert(0.0) += e.bytes;
        }
        let mut v: Vec<(usize, f64)> = map.into_iter().collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }

    /// Fraction of the *training job's* bytes that crossed a rack
    /// boundary. Background flows are excluded: a neighbor-rack incast
    /// tenant is ~all inter-rack and would otherwise swamp the metric's
    /// meaning (the job's own traffic locality).
    pub fn inter_rack_byte_fraction(&self) -> f64 {
        let total: f64 =
            self.events.iter().filter(|e| !e.is_background()).map(|e| e.bytes).sum();
        if total == 0.0 {
            return 0.0;
        }
        let cross: f64 = self
            .events
            .iter()
            .filter(|e| e.inter_rack && !e.is_background())
            .map(|e| e.bytes)
            .sum();
        cross / total
    }

    /// Aggregate byte attribution: `(training, background)` where
    /// "background" is every tenant other than the observing job (id 0).
    pub fn tenant_bytes(&self) -> (f64, f64) {
        let mut training = 0.0;
        let mut background = 0.0;
        for e in &self.events {
            if e.is_background() {
                background += e.bytes;
            } else {
                training += e.bytes;
            }
        }
        (training, background)
    }

    /// Per-tenant byte breakdown, ascending by tenant id (id 0 = the
    /// observing job itself). Lets a fleet post-mortem answer "which
    /// neighbor hurt me" instead of just "how much background was there".
    pub fn bytes_by_tenant(&self) -> Vec<(usize, f64)> {
        let mut map: std::collections::BTreeMap<usize, f64> = Default::default();
        for e in &self.events {
            *map.entry(e.tenant).or_insert(0.0) += e.bytes;
        }
        map.into_iter().collect()
    }

    /// Fraction of traced bytes that belonged to background tenants
    /// (0 on an empty trace or a dedicated fabric).
    pub fn background_byte_fraction(&self) -> f64 {
        let (training, background) = self.tenant_bytes();
        let total = training + background;
        if total == 0.0 { 0.0 } else { background / total }
    }

    /// Bytes in flight per timeline bucket (for a quick utilization
    /// profile): returns `buckets` values covering the trace span.
    pub fn utilization_timeline(&self, buckets: usize) -> Vec<f64> {
        assert!(buckets > 0);
        let (lo, hi) = self.span();
        let width = ((hi - lo) / buckets as f64).max(f64::MIN_POSITIVE);
        let mut out = vec![0.0; buckets];
        for e in &self.events {
            // Spread the message's bytes across the buckets it overlaps.
            let b0 = (((e.start - lo) / width) as usize).min(buckets - 1);
            let b1 = (((e.end - lo) / width) as usize).min(buckets - 1);
            let n = (b1 - b0 + 1) as f64;
            for b in b0..=b1 {
                out[b] += e.bytes / n;
            }
        }
        out
    }

    /// Summary table for reports.
    pub fn summary(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["metric", "value"]);
        let total: f64 = self.events.iter().map(|e| e.bytes).sum();
        let (lo, hi) = self.span();
        t.row(vec!["messages".into(), self.len().to_string()]);
        t.row(vec!["bytes".into(), fmt_bytes(total)]);
        t.row(vec!["span".into(), fmt_time(hi - lo)]);
        t.row(vec![
            "inter-rack byte fraction".into(),
            format!("{:.3}", self.inter_rack_byte_fraction()),
        ]);
        t.row(vec![
            "background byte fraction".into(),
            format!("{:.3}", self.background_byte_fraction()),
        ]);
        if let Some((node, bytes)) = self.bytes_by_node().first() {
            t.row(vec![
                "hottest tx node".into(),
                format!("node {node} ({})", fmt_bytes(*bytes)),
            ]);
        }
        if hi > lo {
            t.row(vec![
                "mean offered load".into(),
                format!("{} GB/s", fnum(total / (hi - lo) / 1e9)),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(src: usize, dst: usize, bytes: f64, start: f64, end: f64, xr: bool) -> MessageEvent {
        MessageEvent {
            src_node: src,
            dst_node: dst,
            bytes,
            start,
            end,
            inter_rack: xr,
            tenant: 0,
        }
    }

    fn sample() -> Trace {
        let mut t = Trace::default();
        t.record(ev(0, 1, 100.0, 0.0, 1.0, false));
        t.record(ev(1, 2, 300.0, 0.5, 2.0, true));
        t.record(ev(0, 2, 100.0, 1.0, 3.0, true));
        t
    }

    #[test]
    fn span_and_counts() {
        let t = sample();
        assert_eq!(t.len(), 3);
        assert_eq!(t.span(), (0.0, 3.0));
    }

    #[test]
    fn bytes_by_node_sorted() {
        let t = sample();
        let by = t.bytes_by_node();
        assert_eq!(by[0], (1, 300.0));
        assert_eq!(by[1], (0, 200.0));
    }

    #[test]
    fn inter_rack_fraction() {
        let t = sample();
        assert!((t.inter_rack_byte_fraction() - 0.8).abs() < 1e-12);
        assert_eq!(Trace::default().inter_rack_byte_fraction(), 0.0);
    }

    #[test]
    fn tenant_attribution_splits_and_breaks_down() {
        let mut t = sample();
        t.record(MessageEvent { tenant: 3, ..ev(5, 6, 250.0, 0.0, 1.0, true) });
        t.record(MessageEvent { tenant: 1, ..ev(6, 5, 50.0, 0.5, 1.5, true) });
        let (training, background) = t.tenant_bytes();
        assert_eq!(training, 500.0);
        assert_eq!(background, 300.0);
        assert!((t.background_byte_fraction() - 300.0 / 800.0).abs() < 1e-12);
        assert_eq!(t.bytes_by_tenant(), vec![(0, 500.0), (1, 50.0), (3, 250.0)]);
        // Training-only views ignore every non-zero tenant.
        assert!((t.inter_rack_byte_fraction() - 0.8).abs() < 1e-12);
        assert!(t.bytes_by_node().iter().all(|&(n, _)| n < 3));
    }

    #[test]
    fn utilization_conserves_bytes() {
        let t = sample();
        for buckets in [1, 3, 10] {
            let tl = t.utilization_timeline(buckets);
            let total: f64 = tl.iter().sum();
            assert!((total - 500.0).abs() < 1e-9, "buckets={buckets}: {total}");
        }
    }

    #[test]
    fn summary_renders() {
        let md = sample().summary("trace").to_markdown();
        assert!(md.contains("hottest tx node"));
        assert!(md.contains("inter-rack"));
    }
}
