//! The explicit multi-tier link graph above the NICs.
//!
//! The event engine used to hard-code a two-tier resource model (NIC
//! tx/rx ports plus one scalar up/down link per rack). This module
//! replaces that wiring with a declarative topology: a **fat-tree**
//! (node -> ToR/leaf -> spine, with per-tier oversubscription and ECMP
//! across spines) or a **dragonfly-style** variant where ToRs are
//! grouped and inter-group traffic additionally claims the source
//! group's aggregate global-egress link and the destination group's
//! global-ingress link.
//!
//! Every link is a shared capacity in the max-min fair fluid model (see
//! [`crate::fabric::contention`]): [`Topology::route`] maps a flow to
//! the exact set of link ids it occupies, and
//! [`crate::fabric::NetSim::transfer_batch`] claims that set instead of
//! the old hard-coded NIC/rack resources.
//!
//! # Determinism
//!
//! Routes are pure functions of `(src_node, dst_node, flow_seq)` and the
//! spec's `ecmp_seed`: the ECMP spine choice is a seeded splitmix64-style
//! hash of the **unordered** endpoint pair and the per-pair flow
//! sequence number. No global mutable state, no platform-dependent
//! hashing — sweep CSVs stay byte-identical across `--jobs` values, and
//! `route(a -> b)` is the mirror image of `route(b -> a)` for the same
//! sequence number (symmetric paths).
//!
//! # Bit-for-bit default equivalence
//!
//! [`TopologySpec::default`] builds one spine per leaf tier whose
//! capacity is exactly `FabricSpec::rack_uplink_bandwidth()`, with
//! `leaf_ports = cluster.nodes_per_rack` — the resource table layout,
//! ids and capacities are *identical* to the legacy hard-coded model, so
//! the engine's pre-topology timings (including the committed golden CSV
//! fixtures) are reproduced bit-for-bit. `tests/topology_properties.rs`
//! pins this. (The hierarchical *collective* deliberately changed for
//! multi-ToR placements — that is an algorithm change above the engine,
//! not covered by this guarantee.)

use crate::config::{ClusterSpec, FabricSpec, TopologyKind, TopologySpec};
use crate::fabric::contention::FlowResources;
use anyhow::{bail, Result};

/// splitmix64 finalizer: the bit mixer behind the ECMP hash.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Seeded, order-independent ECMP hash. Symmetric in the endpoints
/// (unordered-pair normalization), so the forward and reverse directions
/// of a flow pick the same spine and routes reverse cleanly.
pub fn ecmp_hash(seed: u64, a: usize, b: usize, flow_seq: u64) -> u64 {
    let (lo, hi) = if a <= b { (a as u64, b as u64) } else { (b as u64, a as u64) };
    mix64(seed ^ mix64((lo << 32) | hi) ^ mix64(flow_seq.wrapping_add(0x9e37_79b9_7f4a_7c15)))
}

/// One flow's deterministic path through the topology.
#[derive(Clone, Copy, Debug)]
pub struct Route {
    /// Every shared link the flow occupies, in src -> dst order.
    pub res: FlowResources,
    /// Does the path leave the source ToR (leaf switch)?
    pub inter_tor: bool,
    /// Spine chosen by the ECMP hash (`None` for intra-ToR paths).
    pub spine: Option<usize>,
    /// Dragonfly: does the path cross a group boundary?
    pub inter_group: bool,
}

/// The runtime link graph built from a [`TopologySpec`] + fabric +
/// cluster. Owns the per-link capacity table the engine solves over.
#[derive(Clone, Debug)]
pub struct Topology {
    pub kind: TopologyKind,
    pub n_nodes: usize,
    /// Node-facing ports per leaf switch (ToR membership stride).
    pub nodes_per_tor: usize,
    pub n_tors: usize,
    pub n_spines: usize,
    /// Dragonfly group count (0 for fat-tree: no global links allocated).
    pub n_groups: usize,
    pub tors_per_group: usize,
    ecmp_seed: u64,
    /// Per-link capacity, bytes/s. Layout: `[0,n)` NIC tx, `[n,2n)` NIC
    /// rx, then up-links (ToR-major, spine-minor), down-links, and — for
    /// dragonfly — per-group global-egress then global-ingress links.
    caps: Vec<f64>,
}

impl Topology {
    /// Build the link graph. Fails loudly on a spec the cluster cannot
    /// host (see [`TopologySpec::validate_for`]).
    pub fn build(spec: &TopologySpec, fabric: &FabricSpec, cluster: &ClusterSpec) -> Result<Self> {
        spec.validate_for(cluster)?;
        let n_nodes = cluster.nodes;
        let nodes_per_tor = spec.leaf_ports.unwrap_or(cluster.nodes_per_rack);
        let n_tors = spec.tors.unwrap_or_else(|| n_nodes.div_ceil(nodes_per_tor));
        let n_spines = spec.spines;
        let nic = fabric.effective_bandwidth();
        // Aggregate uplink per ToR: explicit Gb/s beats the
        // oversubscription ratio beats the fabric's legacy scalar (which
        // is exactly `rack_uplink_bandwidth()`, preserving old results).
        let agg_uplink = if let Some(g) = spec.uplink_gbps {
            crate::util::units::gbps_to_bytes_per_sec(g) * fabric.efficiency
        } else if let Some(r) = spec.oversubscription {
            nodes_per_tor as f64 * nic / r
        } else {
            fabric.rack_uplink_bandwidth()
        };
        if !(agg_uplink > 0.0) {
            bail!("topology: non-positive uplink capacity {agg_uplink}");
        }
        let per_spine = agg_uplink / n_spines as f64;
        let (n_groups, tors_per_group) = match spec.kind {
            TopologyKind::FatTree => (0, n_tors.max(1)),
            TopologyKind::Dragonfly => (spec.groups, n_tors.div_ceil(spec.groups)),
        };
        let mut caps = vec![nic; 2 * n_nodes];
        caps.extend(std::iter::repeat(per_spine).take(2 * n_tors * n_spines));
        if n_groups > 0 {
            // Aggregate global bandwidth per group, relative to the
            // group's injection bandwidth.
            let global = (tors_per_group * nodes_per_tor) as f64 * nic
                / spec.global_oversubscription;
            caps.extend(std::iter::repeat(global).take(2 * n_groups));
        }
        Ok(Topology {
            kind: spec.kind,
            n_nodes,
            nodes_per_tor,
            n_tors,
            n_spines,
            n_groups,
            tors_per_group,
            ecmp_seed: spec.ecmp_seed,
            caps,
        })
    }

    #[inline]
    pub fn tx_id(&self, node: usize) -> usize {
        node
    }

    #[inline]
    pub fn rx_id(&self, node: usize) -> usize {
        self.n_nodes + node
    }

    /// Up-link from ToR `tor` to spine `spine`.
    #[inline]
    pub fn up_id(&self, tor: usize, spine: usize) -> usize {
        2 * self.n_nodes + tor * self.n_spines + spine
    }

    /// Down-link from spine `spine` to ToR `tor`.
    #[inline]
    pub fn down_id(&self, tor: usize, spine: usize) -> usize {
        2 * self.n_nodes + self.n_tors * self.n_spines + tor * self.n_spines + spine
    }

    /// Dragonfly: group `group`'s aggregate global-egress link.
    #[inline]
    pub fn global_out_id(&self, group: usize) -> usize {
        2 * self.n_nodes + 2 * self.n_tors * self.n_spines + group
    }

    /// Dragonfly: group `group`'s aggregate global-ingress link.
    #[inline]
    pub fn global_in_id(&self, group: usize) -> usize {
        2 * self.n_nodes + 2 * self.n_tors * self.n_spines + self.n_groups + group
    }

    pub fn num_resources(&self) -> usize {
        self.caps.len()
    }

    /// Per-link capacities, bytes/s, indexed by link id.
    pub fn caps(&self) -> &[f64] {
        &self.caps
    }

    #[inline]
    pub fn tor_of_node(&self, node: usize) -> usize {
        node / self.nodes_per_tor
    }

    #[inline]
    pub fn group_of_tor(&self, tor: usize) -> usize {
        tor / self.tors_per_group
    }

    /// The deterministic route of flow number `flow_seq` between two
    /// distinct nodes: the exact set of shared links it occupies.
    pub fn route(&self, src_node: usize, dst_node: usize, flow_seq: u64) -> Route {
        debug_assert_ne!(src_node, dst_node, "route to self");
        let mut res = FlowResources::new();
        res.push(self.tx_id(src_node));
        let st = self.tor_of_node(src_node);
        let dt = self.tor_of_node(dst_node);
        let inter_tor = st != dt;
        let mut spine = None;
        let mut inter_group = false;
        if inter_tor {
            let s = (ecmp_hash(self.ecmp_seed, src_node, dst_node, flow_seq)
                % self.n_spines as u64) as usize;
            spine = Some(s);
            res.push(self.up_id(st, s));
            if self.kind == TopologyKind::Dragonfly {
                let (sg, dg) = (self.group_of_tor(st), self.group_of_tor(dt));
                if sg != dg {
                    inter_group = true;
                    res.push(self.global_out_id(sg));
                    res.push(self.global_in_id(dg));
                }
            }
            res.push(self.down_id(dt, s));
        }
        res.push(self.rx_id(dst_node));
        Route { res, inter_tor, spine, inter_group }
    }

    /// [`Topology::route`] restricted to surviving spines: the ECMP hash
    /// picks the `hash % n_alive`-th entry of the alive list, so when all
    /// spines are alive the choice is *identical* to `route` (same hash,
    /// same modulus over the same ordered set), and excluding dead spines
    /// re-distributes exactly the displaced flows — deterministically,
    /// with no RNG and no dependence on discovery order. Returns `None`
    /// when the flow crosses ToRs and no spine in `spine_alive` survives.
    pub fn route_excluding(
        &self,
        src_node: usize,
        dst_node: usize,
        flow_seq: u64,
        spine_alive: &[bool],
    ) -> Option<Route> {
        debug_assert_ne!(src_node, dst_node, "route to self");
        debug_assert_eq!(spine_alive.len(), self.n_spines);
        let mut res = FlowResources::new();
        res.push(self.tx_id(src_node));
        let st = self.tor_of_node(src_node);
        let dt = self.tor_of_node(dst_node);
        let inter_tor = st != dt;
        let mut spine = None;
        let mut inter_group = false;
        if inter_tor {
            let n_alive = spine_alive.iter().filter(|&&a| a).count();
            if n_alive == 0 {
                return None;
            }
            let pick = (ecmp_hash(self.ecmp_seed, src_node, dst_node, flow_seq)
                % n_alive as u64) as usize;
            let s = spine_alive
                .iter()
                .enumerate()
                .filter(|(_, &a)| a)
                .nth(pick)
                .map(|(s, _)| s)
                .expect("pick < n_alive");
            spine = Some(s);
            res.push(self.up_id(st, s));
            if self.kind == TopologyKind::Dragonfly {
                let (sg, dg) = (self.group_of_tor(st), self.group_of_tor(dt));
                if sg != dg {
                    inter_group = true;
                    res.push(self.global_out_id(sg));
                    res.push(self.global_in_id(dg));
                }
            }
            res.push(self.down_id(dt, s));
        }
        res.push(self.rx_id(dst_node));
        Some(Route { res, inter_tor, spine, inter_group })
    }

    /// Stable 64-bit signature of the link graph: tier shape, ECMP seed
    /// and every capacity bit. Two topologies with equal signatures route
    /// and price flows identically — the schedule cache keys on this.
    pub fn signature(&self) -> u64 {
        let mut h = mix64(
            (self.kind as u64)
                ^ ((self.n_nodes as u64) << 2)
                ^ ((self.nodes_per_tor as u64) << 18)
                ^ ((self.n_tors as u64) << 30)
                ^ ((self.n_spines as u64) << 42)
                ^ ((self.n_groups as u64) << 50)
                ^ ((self.tors_per_group as u64) << 58),
        );
        h = mix64(h ^ self.ecmp_seed);
        for &c in &self.caps {
            h = mix64(h ^ c.to_bits());
        }
        h
    }

    /// Human-readable name of a link id (tests, trace debugging).
    pub fn link_label(&self, id: usize) -> String {
        let n = self.n_nodes;
        let ts = self.n_tors * self.n_spines;
        if id < n {
            format!("nic-tx(node {id})")
        } else if id < 2 * n {
            format!("nic-rx(node {})", id - n)
        } else if id < 2 * n + ts {
            let k = id - 2 * n;
            format!("up(tor {}, spine {})", k / self.n_spines, k % self.n_spines)
        } else if id < 2 * n + 2 * ts {
            let k = id - 2 * n - ts;
            format!("down(tor {}, spine {})", k / self.n_spines, k % self.n_spines)
        } else if id < 2 * n + 2 * ts + self.n_groups {
            format!("global-out(group {})", id - 2 * n - 2 * ts)
        } else {
            format!("global-in(group {})", id - 2 * n - 2 * ts - self.n_groups)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::FabricKind;

    fn eth() -> FabricSpec {
        fabric(FabricKind::EthernetRoce25)
    }

    #[test]
    fn default_layout_is_the_legacy_resource_table() {
        // The default spec must reproduce the legacy hard-coded wiring:
        // [nic tx x n | nic rx x n | up x racks | down x racks] with the
        // scalar rack-uplink capacity. Ids AND capacities, exactly.
        let cluster = ClusterSpec::txgaia();
        let f = eth();
        let topo = Topology::build(&TopologySpec::default(), &f, &cluster).unwrap();
        let n = cluster.nodes;
        let racks = cluster.nodes.div_ceil(cluster.nodes_per_rack);
        assert_eq!(topo.n_tors, racks);
        assert_eq!(topo.n_spines, 1);
        assert_eq!(topo.num_resources(), 2 * n + 2 * racks);
        let nic = f.effective_bandwidth();
        let uplink = f.rack_uplink_bandwidth();
        for node in 0..n {
            assert_eq!(topo.tx_id(node), node);
            assert_eq!(topo.rx_id(node), n + node);
            assert_eq!(topo.caps()[topo.tx_id(node)].to_bits(), nic.to_bits());
            assert_eq!(topo.caps()[topo.rx_id(node)].to_bits(), nic.to_bits());
        }
        for tor in 0..racks {
            assert_eq!(topo.up_id(tor, 0), 2 * n + tor);
            assert_eq!(topo.down_id(tor, 0), 2 * n + racks + tor);
            assert_eq!(topo.caps()[topo.up_id(tor, 0)].to_bits(), uplink.to_bits());
            assert_eq!(topo.caps()[topo.down_id(tor, 0)].to_bits(), uplink.to_bits());
        }
    }

    #[test]
    fn routes_claim_exactly_the_path_links() {
        let cluster = ClusterSpec::txgaia();
        let topo = Topology::build(&TopologySpec::default(), &eth(), &cluster).unwrap();
        // Intra-ToR: NIC ports only.
        let r = topo.route(0, 3, 0);
        assert!(!r.inter_tor && r.spine.is_none());
        let ids: Vec<usize> = r.res.iter().collect();
        assert_eq!(ids, vec![topo.tx_id(0), topo.rx_id(3)]);
        // Inter-ToR: NICs plus the matching up/down pair on one spine.
        let r = topo.route(1, 40, 0);
        assert!(r.inter_tor);
        let s = r.spine.unwrap();
        let ids: Vec<usize> = r.res.iter().collect();
        assert_eq!(
            ids,
            vec![topo.tx_id(1), topo.up_id(0, s), topo.down_id(1, s), topo.rx_id(40)]
        );
    }

    #[test]
    fn ecmp_is_deterministic_symmetric_and_spreads() {
        let cluster = ClusterSpec::txgaia();
        let spec = TopologySpec { spines: 4, oversubscription: Some(1.0), ..Default::default() };
        let topo = Topology::build(&spec, &eth(), &cluster).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for seq in 0..32u64 {
            for (a, b) in [(0usize, 40usize), (5, 100), (33, 200)] {
                let f = topo.route(a, b, seq);
                let f2 = topo.route(a, b, seq);
                let r = topo.route(b, a, seq);
                assert_eq!(f.spine, f2.spine, "route must be deterministic");
                assert_eq!(f.spine, r.spine, "forward/reverse must share a spine");
                seen.insert(f.spine.unwrap());
            }
        }
        assert!(seen.len() > 1, "ECMP never spread across spines: {seen:?}");
        assert!(seen.iter().all(|&s| s < 4));
    }

    #[test]
    fn route_excluding_matches_route_when_all_spines_alive() {
        let cluster = ClusterSpec::txgaia();
        let spec = TopologySpec { spines: 4, oversubscription: Some(1.0), ..Default::default() };
        let topo = Topology::build(&spec, &eth(), &cluster).unwrap();
        let alive = vec![true; 4];
        for seq in 0..16u64 {
            for (a, b) in [(0usize, 40usize), (5, 100), (33, 200), (0, 3)] {
                let r = topo.route(a, b, seq);
                let x = topo.route_excluding(a, b, seq, &alive).unwrap();
                assert_eq!(r.spine, x.spine);
                assert_eq!(
                    r.res.iter().collect::<Vec<_>>(),
                    x.res.iter().collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn route_excluding_avoids_dead_spines_deterministically() {
        let cluster = ClusterSpec::txgaia();
        let spec = TopologySpec { spines: 4, oversubscription: Some(1.0), ..Default::default() };
        let topo = Topology::build(&spec, &eth(), &cluster).unwrap();
        let mut alive = vec![true; 4];
        alive[2] = false;
        for seq in 0..64u64 {
            let a = topo.route_excluding(0, 40, seq, &alive).unwrap();
            let b = topo.route_excluding(0, 40, seq, &alive).unwrap();
            assert_eq!(a.spine, b.spine, "re-hash must be deterministic");
            assert_ne!(a.spine, Some(2), "dead spine must never be chosen");
        }
        // No surviving spine: inter-ToR flows are unroutable, intra-ToR
        // flows never touch the spine tier.
        let none = vec![false; 4];
        assert!(topo.route_excluding(0, 40, 0, &none).is_none());
        assert!(topo.route_excluding(0, 3, 0, &none).is_some());
    }

    #[test]
    fn oversubscription_scales_uplink_capacity() {
        let cluster = ClusterSpec::txgaia();
        let f = eth();
        let nic = f.effective_bandwidth();
        for (ratio, spines) in [(1.0, 1usize), (4.0, 2), (8.0, 4)] {
            let spec = TopologySpec {
                spines,
                oversubscription: Some(ratio),
                ..Default::default()
            };
            let topo = Topology::build(&spec, &f, &cluster).unwrap();
            let want = cluster.nodes_per_rack as f64 * nic / ratio / spines as f64;
            let got = topo.caps()[topo.up_id(0, 0)];
            assert!((got - want).abs() < 1e-6, "ratio {ratio}: {got} vs {want}");
        }
    }

    #[test]
    fn dragonfly_routes_add_global_links_between_groups() {
        let cluster = ClusterSpec::txgaia(); // 14 ToRs of 32 nodes
        let spec = TopologySpec {
            kind: TopologyKind::Dragonfly,
            groups: 7, // 2 ToRs per group
            global_oversubscription: 2.0,
            ..Default::default()
        };
        let topo = Topology::build(&spec, &eth(), &cluster).unwrap();
        assert_eq!(topo.n_groups, 7);
        assert_eq!(topo.tors_per_group, 2);
        // Same group (ToR 0 -> ToR 1): fat-tree-like 4-link path.
        let r = topo.route(0, 40, 0);
        assert!(r.inter_tor && !r.inter_group);
        assert_eq!(r.res.len(), 4);
        // Cross-group (ToR 0 -> ToR 2): adds global out + in.
        let r = topo.route(0, 70, 0);
        assert!(r.inter_group);
        assert_eq!(r.res.len(), 6);
        let ids: Vec<usize> = r.res.iter().collect();
        assert!(ids.contains(&topo.global_out_id(0)));
        assert!(ids.contains(&topo.global_in_id(1)));
        // Global capacity honors the configured taper.
        let nic = eth().effective_bandwidth();
        let want = (2 * 32) as f64 * nic / 2.0;
        assert!((topo.caps()[topo.global_out_id(0)] - want).abs() < 1e-6);
    }

    #[test]
    fn build_rejects_cluster_it_cannot_host() {
        let mut cluster = ClusterSpec::txgaia();
        cluster.nodes = 64;
        cluster.nodes_per_rack = 8;
        let spec = TopologySpec { tors: Some(4), leaf_ports: Some(8), ..Default::default() };
        assert!(Topology::build(&spec, &eth(), &cluster).is_err());
    }

    #[test]
    fn link_labels_cover_every_id() {
        let cluster = ClusterSpec::txgaia();
        let spec = TopologySpec {
            kind: TopologyKind::Dragonfly,
            groups: 2,
            spines: 2,
            oversubscription: Some(2.0),
            ..Default::default()
        };
        let topo = Topology::build(&spec, &eth(), &cluster).unwrap();
        let labels: Vec<String> =
            (0..topo.num_resources()).map(|id| topo.link_label(id)).collect();
        assert!(labels.iter().any(|l| l.starts_with("nic-tx")));
        assert!(labels.iter().any(|l| l.starts_with("up(tor 13, spine 1)")));
        assert!(labels.iter().any(|l| l.starts_with("global-in(group 1)")));
    }
}
