//! Transport protocol modeling: RDMA vs TCP, eager vs rendezvous, and the
//! GPUDirect-vs-staged-copy PCIe path (§II.B of the paper).
//!
//! Produces a [`MessageCost`] decomposition for a single point-to-point
//! message given fabric, cluster, transport options, and endpoint
//! geometry. The returned `bandwidth` is this flow's **uncontended rate
//! cap** (wire rate bounded by PCIe/UPI segments); the discrete-event
//! engine in [`crate::fabric::sim`] layers NIC/up-link sharing and
//! switch-level congestion on top, so no concurrency factor appears here.

use crate::cluster::EndpointKind;
use crate::config::{ClusterSpec, FabricSpec, TransportOptions};

/// Decomposed cost of one message (seconds / bytes-per-second).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MessageCost {
    /// Fixed pre-wire time on the sender (software overhead + staging).
    pub send_overhead: f64,
    /// Wire + switch latency (propagation, hops, rendezvous handshake).
    pub latency: f64,
    /// Fixed post-wire time on the receiver.
    pub recv_overhead: f64,
    /// Effective end-to-end bandwidth for the payload, bytes/s.
    pub bandwidth: f64,
}

impl MessageCost {
    /// Total one-way time for `bytes`.
    pub fn total(&self, bytes: f64) -> f64 {
        self.send_overhead + self.latency + self.recv_overhead + bytes / self.bandwidth
    }
}

/// Geometry of a message as seen by the transport layer.
#[derive(Clone, Copy, Debug)]
pub struct MessageGeometry {
    pub bytes: f64,
    pub inter_rack: bool,
    pub endpoint: EndpointKind,
    /// Sender's GPU slot (for per-socket affinity); ignored for CPU ranks.
    pub src_slot: usize,
    pub dst_slot: usize,
}

/// Cost of a network (inter-node) message.
pub fn network_message(
    fabric: &FabricSpec,
    cluster: &ClusterSpec,
    opts: &TransportOptions,
    geo: &MessageGeometry,
) -> MessageCost {
    let rdma = fabric.rdma && opts.use_rdma;
    // Software overhead per side: RDMA posts a verb; TCP walks the kernel
    // stack. The fabric preset already encodes the technology difference;
    // disabling RDMA on an RDMA-capable fabric falls back to ~TCP costs.
    let sw = if rdma { fabric.per_msg_overhead } else { fabric.per_msg_overhead.max(4.0e-6) };

    let mut latency = fabric.latency;
    if geo.inter_rack {
        // Leaf hop up + core hop down (single extra stage on TX-GAIA's
        // flat Ethernet; OPA edge-director-edge).
        latency += 2.0 * fabric.switch_hop_latency;
    }
    // Rendezvous protocol: large messages handshake before the payload.
    // Same classification as the recv-post ordering gate in Comm::p2p, so
    // a TransportOptions::rendezvous_threshold override moves the
    // handshake cost and the ordering semantics together.
    if crate::fabric::mpi::is_rendezvous(opts, fabric.eager_threshold, geo.bytes) {
        latency += 2.0 * fabric.latency;
    }

    let mut bandwidth = fabric.effective_bandwidth();
    let mut send_overhead = sw;
    let mut recv_overhead = sw;

    if geo.endpoint == EndpointKind::Gpu {
        let src_crosses = cluster.affinity.gpu_to_nic_crosses_upi(geo.src_slot, fabric.kind);
        let dst_crosses = cluster.affinity.gpu_to_nic_crosses_upi(geo.dst_slot, fabric.kind);
        if opts.gpudirect && rdma {
            // GPUDirect RDMA: NIC DMAs GPU memory. The PCIe segment is part
            // of the pipeline; it only matters if it (or UPI) is narrower
            // than the wire.
            bandwidth = bandwidth.min(cluster.pcie_bw);
            if src_crosses || dst_crosses {
                bandwidth = bandwidth.min(cluster.upi_bw);
                latency += cluster.upi_latency
                    * ((src_crosses as u8 + dst_crosses as u8) as f64);
            }
        } else {
            // Staged through host RAM: an extra store-and-forward copy on
            // each side (D2H on the sender, H2D on the receiver).
            let src_copy_bw =
                if src_crosses { cluster.pcie_bw.min(cluster.upi_bw) } else { cluster.pcie_bw };
            let dst_copy_bw =
                if dst_crosses { cluster.pcie_bw.min(cluster.upi_bw) } else { cluster.pcie_bw };
            send_overhead += cluster.pcie_latency + geo.bytes / src_copy_bw;
            recv_overhead += cluster.pcie_latency + geo.bytes / dst_copy_bw;
        }
    }

    MessageCost { send_overhead, latency, recv_overhead, bandwidth }
}

/// Cost of an intra-node message (no NIC involved).
pub fn local_message(
    cluster: &ClusterSpec,
    endpoint: EndpointKind,
    _bytes: f64,
) -> MessageCost {
    match endpoint {
        // GPU peer-to-peer over PCIe (TX-GAIA: both GPUs behind CPU1, no
        // PCIe switch, so P2P transits the root complex).
        EndpointKind::Gpu => MessageCost {
            send_overhead: 0.0,
            latency: cluster.pcie_latency,
            recv_overhead: 0.0,
            bandwidth: cluster.pcie_bw,
        },
        // CPU ranks: shared-memory transport.
        EndpointKind::Cpu => MessageCost {
            send_overhead: 0.0,
            latency: cluster.shm_latency,
            recv_overhead: 0.0,
            bandwidth: cluster.shm_bw,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::{AffinityConfig, FabricKind};

    fn geo(bytes: f64) -> MessageGeometry {
        MessageGeometry {
            bytes,
            inter_rack: false,
            endpoint: EndpointKind::Cpu,
            src_slot: 0,
            dst_slot: 0,
        }
    }

    #[test]
    fn zero_byte_latency_close_to_spec() {
        let f = fabric(FabricKind::OmniPath100);
        let c = ClusterSpec::txgaia();
        let cost = network_message(&f, &c, &TransportOptions::default(), &geo(0.0));
        let t = cost.total(0.0);
        // latency + 2x overhead, all within a couple of microseconds.
        assert!(t > f.latency && t < f.latency + 3.0e-6, "t={t}");
    }

    #[test]
    fn large_message_hits_line_rate() {
        let f = fabric(FabricKind::EthernetRoce25);
        let c = ClusterSpec::txgaia();
        let bytes = 64.0 * 1024.0 * 1024.0;
        let mut g = geo(bytes);
        g.endpoint = EndpointKind::Gpu;
        let cost = network_message(&f, &c, &TransportOptions::default(), &g);
        let achieved = bytes / cost.total(bytes);
        let line = f.effective_bandwidth();
        assert!(achieved > 0.95 * line, "achieved {achieved:.3e} vs line {line:.3e}");
    }

    #[test]
    fn opa_large_message_bounded_by_pcie() {
        // 100 Gb/s line rate exceeds PCIe3 x16; GPUDirect path must be
        // PCIe-bound.
        let f = fabric(FabricKind::OmniPath100);
        let mut c = ClusterSpec::txgaia();
        c.affinity = AffinityConfig::GpusAndOpaOnCpu1; // no UPI crossing
        let mut g = geo(1e9);
        g.endpoint = EndpointKind::Gpu;
        let cost = network_message(&f, &c, &TransportOptions::default(), &g);
        assert!(cost.bandwidth <= c.pcie_bw);
        assert!(cost.bandwidth >= 0.9 * c.pcie_bw.min(f.effective_bandwidth()));
    }

    #[test]
    fn rendezvous_penalty_above_threshold() {
        let f = fabric(FabricKind::EthernetRoce25);
        let c = ClusterSpec::txgaia();
        let small = network_message(&f, &c, &TransportOptions::default(), &geo(1024.0));
        let large = network_message(
            &f, &c, &TransportOptions::default(), &geo(f.eager_threshold * 2.0),
        );
        assert!(large.latency > small.latency);
        assert!((large.latency - small.latency - 2.0 * f.latency).abs() < 1e-12);
    }

    #[test]
    fn rendezvous_threshold_override_moves_handshake() {
        // The TransportOptions override reclassifies the message for the
        // handshake cost too, not just the recv-post ordering gate.
        let f = fabric(FabricKind::EthernetRoce25);
        let c = ClusterSpec::txgaia();
        let big = geo(f.eager_threshold * 2.0);
        let eager_opts =
            TransportOptions { rendezvous_threshold: Some(1e12), ..Default::default() };
        let forced_eager = network_message(&f, &c, &eager_opts, &big);
        let default = network_message(&f, &c, &TransportOptions::default(), &big);
        assert!((default.latency - forced_eager.latency - 2.0 * f.latency).abs() < 1e-12);
    }

    #[test]
    fn inter_rack_adds_hops() {
        let f = fabric(FabricKind::OmniPath100);
        let c = ClusterSpec::txgaia();
        let mut g = geo(1024.0);
        let intra = network_message(&f, &c, &TransportOptions::default(), &g);
        g.inter_rack = true;
        let inter = network_message(&f, &c, &TransportOptions::default(), &g);
        assert!((inter.latency - intra.latency - 2.0 * f.switch_hop_latency).abs() < 1e-15);
    }

    #[test]
    fn staged_copy_slower_than_gpudirect() {
        let f = fabric(FabricKind::EthernetRoce25);
        let c = ClusterSpec::txgaia();
        let mut g = geo(8.0 * 1024.0 * 1024.0);
        g.endpoint = EndpointKind::Gpu;
        let gd = network_message(&f, &c, &TransportOptions::default(), &g);
        let staged = network_message(
            &f,
            &c,
            &TransportOptions { gpudirect: false, ..Default::default() },
            &g,
        );
        assert!(staged.total(g.bytes) > gd.total(g.bytes));
    }

    #[test]
    fn tcp_fallback_has_higher_overhead() {
        let f = fabric(FabricKind::EthernetRoce25);
        let c = ClusterSpec::txgaia();
        let g = geo(1024.0);
        let rdma = network_message(&f, &c, &TransportOptions::default(), &g);
        let tcp = network_message(
            &f,
            &c,
            &TransportOptions { use_rdma: false, ..Default::default() },
            &g,
        );
        assert!(tcp.send_overhead > rdma.send_overhead);
    }

    #[test]
    fn upi_crossing_penalty_config2() {
        // Config 2: GPU0 on CPU0, Ethernet NIC on CPU1 -> GPU0 crosses UPI.
        let f = fabric(FabricKind::EthernetRoce25);
        let mut c = ClusterSpec::txgaia();
        c.affinity = AffinityConfig::GpuPerSocket;
        let mut g = geo(1e6);
        g.endpoint = EndpointKind::Gpu;
        g.src_slot = 0;
        g.dst_slot = 0;
        let crossing = network_message(&f, &c, &TransportOptions::default(), &g);
        g.src_slot = 1;
        g.dst_slot = 1;
        let local = network_message(&f, &c, &TransportOptions::default(), &g);
        assert!(crossing.total(g.bytes) > local.total(g.bytes));
    }

    #[test]
    fn local_paths() {
        let c = ClusterSpec::txgaia();
        let gpu = local_message(&c, EndpointKind::Gpu, 1e6);
        let cpu = local_message(&c, EndpointKind::Cpu, 1e6);
        assert_eq!(gpu.bandwidth, c.pcie_bw);
        assert_eq!(cpu.bandwidth, c.shm_bw);
    }
}
