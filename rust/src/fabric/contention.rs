//! Contention machinery for the discrete-event fabric engine.
//!
//! Two generations of model live here:
//!
//! * [`Resource`] — the original scalar-occupancy model (a serializing
//!   queue with a single `available_at` clock). The event engine no longer
//!   uses it on the message path; it is kept because it is a useful
//!   building block for microbenches and as the reference the engine's
//!   aggregate-throughput behavior is checked against.
//! * [`FlowResources`] + the max-min solvers — the fluid-flow model:
//!   every in-flight message holds a set of shared capacities (its source
//!   NIC transmit port, destination NIC receive port, and the rack
//!   up/down links when it crosses racks), and the instantaneous rate of
//!   every flow is the **max-min fair** allocation subject to per-flow
//!   caps (PCIe/UPI limits from the transport layer). Rates are
//!   recomputed by [`crate::fabric::NetSim`] on every flow arrival and
//!   departure.
//!
//! Both solvers are classic progressive filling: raise all unfrozen
//! flows' rates at the same speed until a flow hits its own cap or some
//! resource saturates, freeze the affected flows, repeat. Termination:
//! every iteration with a positive increment freezes at least one flow
//! (the increment is the minimum of the freeze conditions), so the loop
//! runs at most `flows` times.
//!
//! * [`max_min_rates`] is the original allocating solver. It is retained
//!   as the **reference oracle**: the engine no longer calls it, but the
//!   property suites (`tests/solver_equivalence.rs` and the unit tests
//!   below) pin the production solver against it bit for bit.
//! * [`MaxMinScratch`] is the production solver: an allocation-free
//!   arena that exploits the water-filling structure. All unfrozen flows
//!   share one fill `level` (a scalar — no per-flow rate updates per
//!   round), flows are pre-sorted by cap so cap-limited flows freeze as
//!   a prefix of that order, and drained resources freeze their holders
//!   through a per-resource member index (CSR) instead of a full flow
//!   scan. Per round the work is O(touched resources + newly frozen)
//!   instead of the reference's O(flows + resources), and a solve over a
//!   subset of a batch (a bottleneck group, see [`crate::fabric::sim`])
//!   touches only that subset's resources. The produced rates are
//!   bit-identical to the reference on the same flow set: the level is
//!   the same partial sum of the same round increments, and both freeze
//!   conditions are evaluated with the same arithmetic.

/// A serializing resource with a fixed bandwidth (legacy scalar model).
#[derive(Clone, Debug)]
pub struct Resource {
    /// Bytes/second this resource can move.
    pub bandwidth: f64,
    /// Virtual time until which the resource is busy.
    pub available_at: f64,
    /// Total busy seconds accumulated (for utilization reporting).
    pub busy: f64,
}

impl Resource {
    pub fn new(bandwidth: f64) -> Self {
        Resource { bandwidth, available_at: 0.0, busy: 0.0 }
    }

    /// Reserve the resource for `bytes` starting no earlier than `ready`.
    /// Returns (start, serialization_time).
    pub fn reserve(&mut self, ready: f64, bytes: f64) -> (f64, f64) {
        let start = ready.max(self.available_at);
        let ser = bytes / self.bandwidth;
        self.available_at = start + ser;
        self.busy += ser;
        (start, ser)
    }

    /// Peek at when a reservation could start without making it.
    pub fn earliest_start(&self, ready: f64) -> f64 {
        ready.max(self.available_at)
    }

    pub fn reset(&mut self) {
        self.available_at = 0.0;
        self.busy = 0.0;
    }
}

/// Maximum shared resources one flow can hold. The longest route is the
/// dragonfly cross-group path: src NIC tx, source-ToR up-link, source
/// group global-egress, destination group global-ingress, destination-ToR
/// down-link, dst NIC rx (see [`crate::fabric::topology`]).
pub const MAX_FLOW_RESOURCES: usize = 6;

/// The (small) set of resource ids one flow occupies.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlowResources {
    ids: [usize; MAX_FLOW_RESOURCES],
    n: usize,
}

impl FlowResources {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, id: usize) {
        debug_assert!(self.n < MAX_FLOW_RESOURCES);
        self.ids[self.n] = id;
        self.n += 1;
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.ids[..self.n].iter().copied()
    }
}

/// Max-min fair rate allocation by progressive filling — the **reference
/// oracle**. Allocates per call and scans every flow every round; the
/// engine's hot path uses [`MaxMinScratch`] instead, which is pinned
/// bit-for-bit against this function by the solver-equivalence property
/// suites.
///
/// * `caps[r]` — capacity of resource `r` in bytes/s (must be positive
///   for every resource referenced by a flow).
/// * `flow_caps[i]` — flow `i`'s own rate ceiling (transport bandwidth).
/// * `flow_res[i]` — the resources flow `i` occupies (ids index `caps`).
///
/// Returns the per-flow rates. A flow with no resources gets its cap.
pub fn max_min_rates(caps: &[f64], flow_caps: &[f64], flow_res: &[FlowResources]) -> Vec<f64> {
    max_min_rates_weighted(caps, flow_caps, flow_res, &vec![1u32; flow_caps.len()])
}

/// Weighted max-min reference oracle: entry `i` stands for `weights[i]`
/// identical flows (same cap, same resource set), and the returned rate
/// is **per member**, not per aggregate. Progressive filling with
/// integer loads makes this bit-identical to [`max_min_rates`] over the
/// expanded (de-aggregated) flow set: per-resource load is the same
/// integer sum, the round increments are the same quotients in the same
/// order, and identical members freeze together in the same round —
/// which is what lets the engine collapse same-route flows into one
/// fluid aggregate without changing a single output bit (pinned by the
/// unit tests below and `tests/aggregation_properties.rs`).
pub fn max_min_rates_weighted(
    caps: &[f64],
    flow_caps: &[f64],
    flow_res: &[FlowResources],
    weights: &[u32],
) -> Vec<f64> {
    let n = flow_caps.len();
    debug_assert_eq!(weights.len(), n);
    let mut rate = vec![0.0; n];
    let mut frozen = vec![false; n];
    let mut remaining = caps.to_vec();
    let mut load = vec![0usize; caps.len()];
    for (fr, &w) in flow_res.iter().zip(weights) {
        for id in fr.iter() {
            load[id] += w as usize;
        }
    }
    let mut unfrozen = n;
    while unfrozen > 0 {
        // Largest equal increment every unfrozen flow can absorb.
        let mut delta = f64::INFINITY;
        for i in 0..n {
            if !frozen[i] {
                delta = delta.min(flow_caps[i] - rate[i]);
            }
        }
        for (r, &l) in load.iter().enumerate() {
            if l > 0 {
                delta = delta.min(remaining[r] / l as f64);
            }
        }
        if delta.is_finite() && delta > 0.0 {
            for i in 0..n {
                if !frozen[i] {
                    rate[i] += delta;
                }
            }
            for (r, &l) in load.iter().enumerate() {
                if l > 0 {
                    remaining[r] -= delta * l as f64;
                }
            }
        }
        // Freeze flows that hit their cap or sit on a drained resource.
        let mut newly = 0;
        for i in 0..n {
            if frozen[i] {
                continue;
            }
            let cap_hit = rate[i] >= flow_caps[i] * (1.0 - 1e-12);
            let res_hit = flow_res[i]
                .iter()
                .any(|r| remaining[r] <= caps[r] * 1e-12);
            if cap_hit || res_hit {
                frozen[i] = true;
                newly += 1;
                for r in flow_res[i].iter() {
                    load[r] -= weights[i] as usize;
                }
            }
        }
        if newly == 0 {
            // Numerical stall (degenerate inputs): stop raising rates.
            break;
        }
        unfrozen -= newly;
    }
    rate
}

/// Allocation-free incremental max-min solver (see the module docs).
///
/// One arena is reused across every solve of a simulation: no per-call
/// `Vec`s for rates / frozen flags / remaining capacity / load. The
/// dense per-resource tables are kept clean between calls by sparse
/// reset over the resources the previous solve touched, so a solve over
/// a small bottleneck group costs only that group's footprint even when
/// the compact resource table of the enclosing batch is large.
#[derive(Debug, Default)]
pub struct MaxMinScratch {
    /// Member slots sorted by flow cap ascending (prefix-freeze order).
    order: Vec<u32>,
    frozen: Vec<bool>,
    /// SoA gathers of the member set (cap / route / weight / rate, in
    /// member order): the filling rounds index these dense arrays
    /// instead of double-indirecting through `members` into the
    /// batch-wide tables on every access.
    m_caps: Vec<f64>,
    m_res: Vec<FlowResources>,
    m_w: Vec<u32>,
    m_rate: Vec<f64>,
    /// Per-resource unfrozen load — the sum of unfrozen holders'
    /// *weights* (dense, zero between solves).
    load: Vec<u32>,
    /// Per-resource unfrozen-holder count (dense, zero between solves);
    /// sizes the CSR, which stores one slot per member, not per weight
    /// unit.
    holders: Vec<u32>,
    /// Per-resource remaining capacity (valid only for touched entries).
    remaining: Vec<f64>,
    /// Per-resource drained marker (dense, false between solves).
    drained: Vec<bool>,
    /// Resources referenced by the current member set.
    touched: Vec<u32>,
    /// CSR of resource -> member slots: `csr_start[r]..cursor[r]`.
    csr_start: Vec<u32>,
    cursor: Vec<u32>,
    csr_items: Vec<u32>,
    drain_stack: Vec<u32>,
    all: Vec<u32>,
    /// Perf counters: total solve calls and filling rounds (reported by
    /// the engine bench as `solver_iterations`).
    pub solves: u64,
    pub rounds: u64,
}

impl MaxMinScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Solve max-min rates for the flows in `members` (indices into the
    /// batch-wide `flow_caps` / `flow_res` / `rate` tables). Writes only
    /// `rate[m]` for `m` in `members`. Bit-identical to
    /// [`max_min_rates`] over the same flow set.
    pub fn solve(
        &mut self,
        caps: &[f64],
        flow_caps: &[f64],
        flow_res: &[FlowResources],
        members: &[u32],
        rate: &mut [f64],
    ) {
        self.solve_member_order(caps, flow_caps, flow_res, None, members);
        for (k, &m) in members.iter().enumerate() {
            rate[m as usize] = self.m_rate[k];
        }
    }

    /// Weighted variant: member `m` stands for `weights[m]` identical
    /// flows and receives its **per-member** rate. Bit-identical to
    /// [`max_min_rates_weighted`] over the same member set, and hence to
    /// the unweighted solve over the de-aggregated flow multiset.
    pub fn solve_weighted(
        &mut self,
        caps: &[f64],
        flow_caps: &[f64],
        flow_res: &[FlowResources],
        weights: &[u32],
        members: &[u32],
        rate: &mut [f64],
    ) {
        self.solve_member_order(caps, flow_caps, flow_res, Some(weights), members);
        for (k, &m) in members.iter().enumerate() {
            rate[m as usize] = self.m_rate[k];
        }
    }

    /// The core progressive-filling loop. Gathers the member set into
    /// the SoA arrays, solves, and leaves the per-member rates in member
    /// order in the returned slice (`solve`/`solve_weighted` scatter it
    /// back to the batch-wide table; the engine's parallel group-solve
    /// path reads it directly so workers never alias the shared rate
    /// table).
    pub fn solve_member_order(
        &mut self,
        caps: &[f64],
        flow_caps: &[f64],
        flow_res: &[FlowResources],
        weights: Option<&[u32]>,
        members: &[u32],
    ) -> &[f64] {
        let n = members.len();
        self.m_rate.clear();
        self.m_rate.resize(n, 0.0);
        if n == 0 {
            return &self.m_rate;
        }
        self.solves += 1;
        let nr = caps.len();
        if self.load.len() < nr {
            self.load.resize(nr, 0);
            self.holders.resize(nr, 0);
            self.remaining.resize(nr, 0.0);
            self.drained.resize(nr, false);
            self.csr_start.resize(nr, 0);
            self.cursor.resize(nr, 0);
        }

        // SoA gather + touched resources + per-resource loads.
        self.m_caps.clear();
        self.m_res.clear();
        self.m_w.clear();
        self.touched.clear();
        for &m in members {
            let i = m as usize;
            let fres = flow_res[i];
            let w = weights.map_or(1, |w| w[i]);
            self.m_caps.push(flow_caps[i]);
            self.m_res.push(fres);
            self.m_w.push(w);
            for r in fres.iter() {
                if self.holders[r] == 0 {
                    self.touched.push(r as u32);
                    self.remaining[r] = caps[r];
                }
                self.holders[r] += 1;
                self.load[r] += w;
            }
        }
        // CSR: which member slots hold each touched resource.
        let mut total = 0u32;
        for &r in &self.touched {
            self.csr_start[r as usize] = total;
            self.cursor[r as usize] = total;
            total += self.holders[r as usize];
        }
        self.csr_items.clear();
        self.csr_items.resize(total as usize, 0);
        for k in 0..n {
            let fres = self.m_res[k];
            for r in fres.iter() {
                let c = self.cursor[r] as usize;
                self.csr_items[c] = k as u32;
                self.cursor[r] += 1;
            }
        }

        self.order.clear();
        self.order.extend(0..n as u32);
        let m_caps = &self.m_caps;
        self.order
            .sort_unstable_by(|a, b| m_caps[*a as usize].total_cmp(&m_caps[*b as usize]));
        self.frozen.clear();
        self.frozen.resize(n, false);
        self.drain_stack.clear();

        let mut level = 0.0f64;
        let mut ptr = 0usize;
        let mut unfrozen = n;
        while unfrozen > 0 {
            self.rounds += 1;
            while ptr < n && self.frozen[self.order[ptr] as usize] {
                ptr += 1;
            }
            // Largest equal increment every unfrozen flow can absorb: the
            // smallest unfrozen cap slack is at the cap-order cursor (all
            // unfrozen flows sit at `level`), then the resource slacks.
            let mut delta = f64::INFINITY;
            if ptr < n {
                delta = self.m_caps[self.order[ptr] as usize] - level;
            }
            for &r in &self.touched {
                let l = self.load[r as usize];
                if l > 0 {
                    delta = delta.min(self.remaining[r as usize] / l as f64);
                }
            }
            if delta.is_finite() && delta > 0.0 {
                level += delta;
                for &r in &self.touched {
                    let l = self.load[r as usize];
                    if l > 0 {
                        self.remaining[r as usize] -= delta * l as f64;
                    }
                }
            }
            // Freeze pass — the same set the reference freezes this round.
            let mut newly = 0usize;
            // (a) Cap-limited flows are a prefix of the cap order.
            while ptr < n {
                let k = self.order[ptr] as usize;
                if self.frozen[k] {
                    ptr += 1;
                    continue;
                }
                if level >= self.m_caps[k] * (1.0 - 1e-12) {
                    self.frozen[k] = true;
                    newly += 1;
                    self.m_rate[k] = level;
                    let fres = self.m_res[k];
                    let w = self.m_w[k];
                    for r in fres.iter() {
                        self.load[r] -= w;
                    }
                    ptr += 1;
                } else {
                    break;
                }
            }
            // (b) Flows holding a drained resource (checked against the
            // same epsilon as the reference; a resource drains once).
            for &r in &self.touched {
                let ri = r as usize;
                if !self.drained[ri] && self.remaining[ri] <= caps[ri] * 1e-12 {
                    self.drained[ri] = true;
                    self.drain_stack.push(r);
                }
            }
            while let Some(r) = self.drain_stack.pop() {
                let ri = r as usize;
                for idx in self.csr_start[ri] as usize..self.cursor[ri] as usize {
                    let k = self.csr_items[idx] as usize;
                    if self.frozen[k] {
                        continue;
                    }
                    self.frozen[k] = true;
                    newly += 1;
                    self.m_rate[k] = level;
                    let fres = self.m_res[k];
                    let w = self.m_w[k];
                    for r2 in fres.iter() {
                        self.load[r2] -= w;
                    }
                }
            }
            if newly == 0 {
                // Numerical stall: unfrozen flows keep the current level
                // (the reference leaves their accumulated rate, which is
                // the same partial sum).
                for k in 0..n {
                    if !self.frozen[k] {
                        self.m_rate[k] = level;
                    }
                }
                break;
            }
            unfrozen -= newly;
        }
        // Sparse cleanup: restore the dense tables' invariants.
        for &r in &self.touched {
            self.load[r as usize] = 0;
            self.holders[r as usize] = 0;
            self.drained[r as usize] = false;
        }
        &self.m_rate
    }

    /// Convenience for oracles and tests: solve over every flow,
    /// resizing `rate`.
    pub fn solve_all(
        &mut self,
        caps: &[f64],
        flow_caps: &[f64],
        flow_res: &[FlowResources],
        rate: &mut Vec<f64>,
    ) {
        rate.clear();
        rate.resize(flow_caps.len(), 0.0);
        self.all.clear();
        self.all.extend(0..flow_caps.len() as u32);
        let members = std::mem::take(&mut self.all);
        self.solve(caps, flow_caps, flow_res, &members, rate);
        self.all = members;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn back_to_back_flows_serialize() {
        let mut r = Resource::new(1e9); // 1 GB/s
        let (s1, d1) = r.reserve(0.0, 1e6); // 1 MB -> 1 ms
        assert_eq!(s1, 0.0);
        assert!((d1 - 1e-3).abs() < 1e-12);
        let (s2, _) = r.reserve(0.0, 1e6);
        assert!((s2 - 1e-3).abs() < 1e-12, "second flow must queue");
    }

    #[test]
    fn idle_gap_respected() {
        let mut r = Resource::new(1e9);
        r.reserve(0.0, 1e6);
        let (s, _) = r.reserve(5.0, 1e3);
        assert_eq!(s, 5.0, "flow arriving later starts at its ready time");
    }

    #[test]
    fn busy_accounting() {
        let mut r = Resource::new(2e9);
        r.reserve(0.0, 2e9); // 1 s
        r.reserve(0.0, 1e9); // 0.5 s
        assert!((r.busy - 1.5).abs() < 1e-9);
        r.reset();
        assert_eq!(r.busy, 0.0);
        assert_eq!(r.available_at, 0.0);
    }

    fn fr(ids: &[usize]) -> FlowResources {
        let mut f = FlowResources::new();
        for &id in ids {
            f.push(id);
        }
        f
    }

    #[test]
    fn single_flow_gets_its_cap() {
        let rates = max_min_rates(&[10.0, 10.0], &[3.0], &[fr(&[0, 1])]);
        assert_eq!(rates, vec![3.0]);
    }

    #[test]
    fn two_flows_share_a_bottleneck_equally() {
        // Both flows want 10, the shared resource has 10 -> 5 each.
        let rates = max_min_rates(&[10.0], &[10.0, 10.0], &[fr(&[0]), fr(&[0])]);
        assert!((rates[0] - 5.0).abs() < 1e-9);
        assert!((rates[1] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn capped_flow_releases_headroom() {
        // Flow 0 capped at 2; flow 1 takes the remaining 8.
        let rates = max_min_rates(&[10.0], &[2.0, 100.0], &[fr(&[0]), fr(&[0])]);
        assert!((rates[0] - 2.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[1] - 8.0).abs() < 1e-9, "{rates:?}");
    }

    #[test]
    fn disjoint_flows_independent() {
        let rates = max_min_rates(&[4.0, 6.0], &[10.0, 10.0], &[fr(&[0]), fr(&[1])]);
        assert!((rates[0] - 4.0).abs() < 1e-9);
        assert!((rates[1] - 6.0).abs() < 1e-9);
    }

    #[test]
    fn multi_resource_bottleneck_is_the_minimum() {
        // Flow crosses NIC (cap 10 shared with another flow) and an uplink
        // of 3: uplink binds it; the NIC peer then takes the NIC headroom.
        let rates = max_min_rates(
            &[10.0, 3.0],
            &[100.0, 100.0],
            &[fr(&[0, 1]), fr(&[0])],
        );
        assert!((rates[0] - 3.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[1] - 7.0).abs() < 1e-9, "{rates:?}");
    }

    type Instance = (Vec<f64>, Vec<f64>, Vec<FlowResources>);

    fn random_instance(rng: &mut crate::util::rng::Rng) -> Instance {
        let n_res = 1 + rng.below(8) as usize;
        let caps: Vec<f64> = (0..n_res).map(|_| rng.uniform_in(0.5, 25.0)).collect();
        let n_flows = 1 + rng.below(24) as usize;
        let mut flow_caps = Vec::new();
        let mut flow_res = Vec::new();
        for _ in 0..n_flows {
            flow_caps.push(rng.uniform_in(0.25, 40.0));
            let k = 1 + rng.below(MAX_FLOW_RESOURCES as u64 - 1) as usize;
            let mut f = FlowResources::new();
            let mut used = Vec::new();
            for _ in 0..k {
                let r = rng.below(n_res as u64) as usize;
                if !used.contains(&r) {
                    f.push(r);
                    used.push(r);
                }
            }
            flow_res.push(f);
        }
        (caps, flow_caps, flow_res)
    }

    #[test]
    fn scratch_solver_bit_identical_to_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xBEEF);
        let mut scratch = MaxMinScratch::new();
        let mut rates = Vec::new();
        for _ in 0..500 {
            let (caps, flow_caps, flow_res) = random_instance(&mut rng);
            let want = max_min_rates(&caps, &flow_caps, &flow_res);
            scratch.solve_all(&caps, &flow_caps, &flow_res, &mut rates);
            for (i, (a, b)) in want.iter().zip(&rates).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "flow {i}: ref {a} vs scratch {b}");
            }
        }
        assert!(scratch.solves == 500 && scratch.rounds >= 500);
    }

    #[test]
    fn scratch_subset_solve_matches_subinstance_reference() {
        // Solving a member subset in the batch-wide tables must equal the
        // reference run on the extracted sub-instance (what the engine's
        // bottleneck groups rely on).
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x5EED);
        let mut scratch = MaxMinScratch::new();
        for _ in 0..200 {
            let (caps, flow_caps, flow_res) = random_instance(&mut rng);
            let members: Vec<u32> = (0..flow_caps.len() as u32)
                .filter(|_| rng.below(2) == 0)
                .collect();
            if members.is_empty() {
                continue;
            }
            let mut rates = vec![f64::NAN; flow_caps.len()];
            scratch.solve(&caps, &flow_caps, &flow_res, &members, &mut rates);
            let sub_caps: Vec<f64> =
                members.iter().map(|&m| flow_caps[m as usize]).collect();
            let sub_res: Vec<FlowResources> =
                members.iter().map(|&m| flow_res[m as usize]).collect();
            let want = max_min_rates(&caps, &sub_caps, &sub_res);
            for (k, &m) in members.iter().enumerate() {
                assert_eq!(want[k].to_bits(), rates[m as usize].to_bits());
            }
            // Non-members are untouched.
            for i in 0..flow_caps.len() {
                if !members.contains(&(i as u32)) {
                    assert!(rates[i].is_nan(), "flow {i} written outside member set");
                }
            }
        }
    }

    #[test]
    fn scratch_arena_reuse_is_clean() {
        // A large solve must leave no residue that skews a later small
        // solve on different resources (dense tables reset sparsely).
        let mut scratch = MaxMinScratch::new();
        let mut rates = Vec::new();
        let caps = vec![10.0, 3.0, 8.0, 1.0];
        let fc = vec![100.0, 100.0, 100.0];
        let fr_all = vec![fr(&[0, 1]), fr(&[0, 2]), fr(&[3])];
        scratch.solve_all(&caps, &fc, &fr_all, &mut rates);
        let first = rates.clone();
        let mut fresh = MaxMinScratch::new();
        scratch.solve_all(&caps, &fc, &fr_all, &mut rates);
        let mut rates2 = Vec::new();
        fresh.solve_all(&caps, &fc, &fr_all, &mut rates2);
        for ((a, b), c) in first.iter().zip(&rates).zip(&rates2) {
            assert_eq!(a.to_bits(), b.to_bits());
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    fn random_weights(rng: &mut crate::util::rng::Rng, n: usize) -> Vec<u32> {
        (0..n).map(|_| 1 + rng.below(5) as u32).collect()
    }

    /// Expand a weighted instance into the flow multiset it stands for:
    /// member `i` becomes `weights[i]` identical flows.
    fn expand(
        flow_caps: &[f64],
        flow_res: &[FlowResources],
        weights: &[u32],
    ) -> (Vec<f64>, Vec<FlowResources>, Vec<usize>) {
        let mut fc = Vec::new();
        let mut fres = Vec::new();
        let mut owner = Vec::new();
        for i in 0..flow_caps.len() {
            for _ in 0..weights[i] {
                fc.push(flow_caps[i]);
                fres.push(flow_res[i]);
                owner.push(i);
            }
        }
        (fc, fres, owner)
    }

    #[test]
    fn weighted_reference_bit_identical_to_expanded_reference() {
        // The aggregation contract: a weight-w member solves to exactly
        // the rate each of its w expanded copies would get. Integer loads
        // make the round increments the same quotients, so this is
        // bit-exact, not approximate.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xA66);
        for _ in 0..300 {
            let (caps, flow_caps, flow_res) = random_instance(&mut rng);
            let weights = random_weights(&mut rng, flow_caps.len());
            let agg = max_min_rates_weighted(&caps, &flow_caps, &flow_res, &weights);
            let (fc, fres, owner) = expand(&flow_caps, &flow_res, &weights);
            let full = max_min_rates(&caps, &fc, &fres);
            for (j, &i) in owner.iter().enumerate() {
                assert_eq!(
                    agg[i].to_bits(),
                    full[j].to_bits(),
                    "member {i} copy {j}: agg {} vs expanded {}",
                    agg[i],
                    full[j]
                );
            }
        }
    }

    #[test]
    fn scratch_weighted_bit_identical_to_weighted_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xA66E5);
        let mut scratch = MaxMinScratch::new();
        for _ in 0..300 {
            let (caps, flow_caps, flow_res) = random_instance(&mut rng);
            let weights = random_weights(&mut rng, flow_caps.len());
            let members: Vec<u32> = (0..flow_caps.len() as u32).collect();
            let mut rates = vec![f64::NAN; flow_caps.len()];
            scratch.solve_weighted(&caps, &flow_caps, &flow_res, &weights, &members, &mut rates);
            let want = max_min_rates_weighted(&caps, &flow_caps, &flow_res, &weights);
            for i in 0..flow_caps.len() {
                assert_eq!(want[i].to_bits(), rates[i].to_bits(), "member {i}");
            }
        }
    }

    #[test]
    fn scratch_weighted_matches_duplicated_unaggregated_scratch() {
        // End-to-end over the scratch solver both ways: solving the
        // weighted instance equals solving the physically duplicated
        // flow set, per member, bit for bit.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xD0B1E);
        let mut agg_scratch = MaxMinScratch::new();
        let mut full_scratch = MaxMinScratch::new();
        for _ in 0..200 {
            let (caps, flow_caps, flow_res) = random_instance(&mut rng);
            let weights = random_weights(&mut rng, flow_caps.len());
            let members: Vec<u32> = (0..flow_caps.len() as u32).collect();
            let mut agg_rates = vec![f64::NAN; flow_caps.len()];
            agg_scratch.solve_weighted(
                &caps, &flow_caps, &flow_res, &weights, &members, &mut agg_rates,
            );
            let (fc, fres, owner) = expand(&flow_caps, &flow_res, &weights);
            let mut full_rates = Vec::new();
            full_scratch.solve_all(&caps, &fc, &fres, &mut full_rates);
            for (j, &i) in owner.iter().enumerate() {
                assert_eq!(agg_rates[i].to_bits(), full_rates[j].to_bits());
            }
        }
    }

    #[test]
    fn member_order_rates_match_scatter_path() {
        // The parallel group-solve path reads member-order rates directly;
        // they must be the same values `solve` scatters.
        let caps = vec![10.0, 4.0];
        let fc = vec![100.0, 2.0, 100.0];
        let fres = vec![fr(&[0]), fr(&[0, 1]), fr(&[1])];
        let members = vec![0u32, 1, 2];
        let mut s1 = MaxMinScratch::new();
        let mut rates = vec![f64::NAN; 3];
        s1.solve(&caps, &fc, &fres, &members, &mut rates);
        let mut s2 = MaxMinScratch::new();
        let mo = s2
            .solve_member_order(&caps, &fc, &fres, None, &members)
            .to_vec();
        for (k, &m) in members.iter().enumerate() {
            assert_eq!(mo[k].to_bits(), rates[m as usize].to_bits());
        }
    }

    #[test]
    fn conservation_and_fairness_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..200 {
            let n_res = 1 + rng.below(5) as usize;
            let caps: Vec<f64> = (0..n_res).map(|_| rng.uniform_in(1.0, 20.0)).collect();
            let n_flows = 1 + rng.below(8) as usize;
            let mut flow_caps = Vec::new();
            let mut flow_res = Vec::new();
            for _ in 0..n_flows {
                flow_caps.push(rng.uniform_in(0.5, 30.0));
                let k = 1 + rng.below(2) as usize;
                let mut f = FlowResources::new();
                let mut used = Vec::new();
                for _ in 0..k {
                    let r = rng.below(n_res as u64) as usize;
                    if !used.contains(&r) {
                        f.push(r);
                        used.push(r);
                    }
                }
                flow_res.push(f);
            }
            let rates = max_min_rates(&caps, &flow_caps, &flow_res);
            // No flow exceeds its cap; no resource is oversubscribed.
            for (i, &r) in rates.iter().enumerate() {
                assert!(r <= flow_caps[i] * (1.0 + 1e-9), "flow {i} over cap");
                assert!(r >= 0.0);
            }
            for (r, &cap) in caps.iter().enumerate() {
                let used: f64 = rates
                    .iter()
                    .zip(&flow_res)
                    .filter(|(_, fr)| fr.iter().any(|id| id == r))
                    .map(|(rate, _)| rate)
                    .sum();
                assert!(used <= cap * (1.0 + 1e-9), "resource {r} oversubscribed");
            }
            // Work-conserving: every flow is blocked by its cap or by a
            // saturated resource.
            for (i, &r) in rates.iter().enumerate() {
                let at_cap = r >= flow_caps[i] * (1.0 - 1e-6);
                let blocked = flow_res[i].iter().any(|id| {
                    let used: f64 = rates
                        .iter()
                        .zip(&flow_res)
                        .filter(|(_, fr)| fr.iter().any(|x| x == id))
                        .map(|(rate, _)| rate)
                        .sum();
                    used >= caps[id] * (1.0 - 1e-6)
                });
                assert!(
                    at_cap || blocked || flow_res[i].is_empty(),
                    "flow {i} rate {r} is not work-conserving"
                );
            }
        }
    }
}
