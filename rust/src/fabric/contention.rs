//! Resource occupancy: serialization of concurrent flows through a shared
//! resource (NIC port, PCIe link). A `Resource` hands out transmission
//! slots; a flow that arrives while the resource is busy waits.

/// A serializing resource with a fixed bandwidth.
#[derive(Clone, Debug)]
pub struct Resource {
    /// Bytes/second this resource can move.
    pub bandwidth: f64,
    /// Virtual time until which the resource is busy.
    pub available_at: f64,
    /// Total busy seconds accumulated (for utilization reporting).
    pub busy: f64,
}

impl Resource {
    pub fn new(bandwidth: f64) -> Self {
        Resource { bandwidth, available_at: 0.0, busy: 0.0 }
    }

    /// Reserve the resource for `bytes` starting no earlier than `ready`.
    /// Returns (start, serialization_time).
    pub fn reserve(&mut self, ready: f64, bytes: f64) -> (f64, f64) {
        let start = ready.max(self.available_at);
        let ser = bytes / self.bandwidth;
        self.available_at = start + ser;
        self.busy += ser;
        (start, ser)
    }

    /// Peek at when a reservation could start without making it.
    pub fn earliest_start(&self, ready: f64) -> f64 {
        ready.max(self.available_at)
    }

    pub fn reset(&mut self) {
        self.available_at = 0.0;
        self.busy = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn back_to_back_flows_serialize() {
        let mut r = Resource::new(1e9); // 1 GB/s
        let (s1, d1) = r.reserve(0.0, 1e6); // 1 MB -> 1 ms
        assert_eq!(s1, 0.0);
        assert!((d1 - 1e-3).abs() < 1e-12);
        let (s2, _) = r.reserve(0.0, 1e6);
        assert!((s2 - 1e-3).abs() < 1e-12, "second flow must queue");
    }

    #[test]
    fn idle_gap_respected() {
        let mut r = Resource::new(1e9);
        r.reserve(0.0, 1e6);
        let (s, _) = r.reserve(5.0, 1e3);
        assert_eq!(s, 5.0, "flow arriving later starts at its ready time");
    }

    #[test]
    fn busy_accounting() {
        let mut r = Resource::new(2e9);
        r.reserve(0.0, 2e9); // 1 s
        r.reserve(0.0, 1e9); // 0.5 s
        assert!((r.busy - 1.5).abs() < 1e-9);
        r.reset();
        assert_eq!(r.busy, 0.0);
        assert_eq!(r.available_at, 0.0);
    }
}
