//! Contention machinery for the discrete-event fabric engine.
//!
//! Two generations of model live here:
//!
//! * [`Resource`] — the original scalar-occupancy model (a serializing
//!   queue with a single `available_at` clock). The event engine no longer
//!   uses it on the message path; it is kept because it is a useful
//!   building block for microbenches and as the reference the engine's
//!   aggregate-throughput behavior is checked against.
//! * [`FlowResources`] + [`max_min_rates`] — the fluid-flow model: every
//!   in-flight message holds a set of shared capacities (its source NIC
//!   transmit port, destination NIC receive port, and the rack up/down
//!   links when it crosses racks), and the instantaneous rate of every
//!   flow is the **max-min fair** allocation subject to per-flow caps
//!   (PCIe/UPI limits from the transport layer). Rates are recomputed by
//!   [`crate::fabric::NetSim`] on every flow arrival and departure.
//!
//! The solver is classic progressive filling: raise all unfrozen flows'
//! rates at the same speed until a flow hits its own cap or some resource
//! saturates, freeze the affected flows, repeat. Termination: every
//! iteration with a positive increment freezes at least one flow (the
//! increment is the minimum of the freeze conditions), so the loop runs at
//! most `flows` times.

/// A serializing resource with a fixed bandwidth (legacy scalar model).
#[derive(Clone, Debug)]
pub struct Resource {
    /// Bytes/second this resource can move.
    pub bandwidth: f64,
    /// Virtual time until which the resource is busy.
    pub available_at: f64,
    /// Total busy seconds accumulated (for utilization reporting).
    pub busy: f64,
}

impl Resource {
    pub fn new(bandwidth: f64) -> Self {
        Resource { bandwidth, available_at: 0.0, busy: 0.0 }
    }

    /// Reserve the resource for `bytes` starting no earlier than `ready`.
    /// Returns (start, serialization_time).
    pub fn reserve(&mut self, ready: f64, bytes: f64) -> (f64, f64) {
        let start = ready.max(self.available_at);
        let ser = bytes / self.bandwidth;
        self.available_at = start + ser;
        self.busy += ser;
        (start, ser)
    }

    /// Peek at when a reservation could start without making it.
    pub fn earliest_start(&self, ready: f64) -> f64 {
        ready.max(self.available_at)
    }

    pub fn reset(&mut self) {
        self.available_at = 0.0;
        self.busy = 0.0;
    }
}

/// Maximum shared resources one flow can hold. The longest route is the
/// dragonfly cross-group path: src NIC tx, source-ToR up-link, source
/// group global-egress, destination group global-ingress, destination-ToR
/// down-link, dst NIC rx (see [`crate::fabric::topology`]).
pub const MAX_FLOW_RESOURCES: usize = 6;

/// The (small) set of resource ids one flow occupies.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlowResources {
    ids: [usize; MAX_FLOW_RESOURCES],
    n: usize,
}

impl FlowResources {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, id: usize) {
        debug_assert!(self.n < MAX_FLOW_RESOURCES);
        self.ids[self.n] = id;
        self.n += 1;
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.ids[..self.n].iter().copied()
    }
}

/// Max-min fair rate allocation by progressive filling.
///
/// * `caps[r]` — capacity of resource `r` in bytes/s (must be positive
///   for every resource referenced by a flow).
/// * `flow_caps[i]` — flow `i`'s own rate ceiling (transport bandwidth).
/// * `flow_res[i]` — the resources flow `i` occupies (ids index `caps`).
///
/// Returns the per-flow rates. A flow with no resources gets its cap.
pub fn max_min_rates(caps: &[f64], flow_caps: &[f64], flow_res: &[FlowResources]) -> Vec<f64> {
    let n = flow_caps.len();
    let mut rate = vec![0.0; n];
    let mut frozen = vec![false; n];
    let mut remaining = caps.to_vec();
    let mut load = vec![0usize; caps.len()];
    for fr in flow_res {
        for id in fr.iter() {
            load[id] += 1;
        }
    }
    let mut unfrozen = n;
    while unfrozen > 0 {
        // Largest equal increment every unfrozen flow can absorb.
        let mut delta = f64::INFINITY;
        for i in 0..n {
            if !frozen[i] {
                delta = delta.min(flow_caps[i] - rate[i]);
            }
        }
        for (r, &l) in load.iter().enumerate() {
            if l > 0 {
                delta = delta.min(remaining[r] / l as f64);
            }
        }
        if delta.is_finite() && delta > 0.0 {
            for i in 0..n {
                if !frozen[i] {
                    rate[i] += delta;
                }
            }
            for (r, &l) in load.iter().enumerate() {
                if l > 0 {
                    remaining[r] -= delta * l as f64;
                }
            }
        }
        // Freeze flows that hit their cap or sit on a drained resource.
        let mut newly = 0;
        for i in 0..n {
            if frozen[i] {
                continue;
            }
            let cap_hit = rate[i] >= flow_caps[i] * (1.0 - 1e-12);
            let res_hit = flow_res[i]
                .iter()
                .any(|r| remaining[r] <= caps[r] * 1e-12);
            if cap_hit || res_hit {
                frozen[i] = true;
                newly += 1;
                for r in flow_res[i].iter() {
                    load[r] -= 1;
                }
            }
        }
        if newly == 0 {
            // Numerical stall (degenerate inputs): stop raising rates.
            break;
        }
        unfrozen -= newly;
    }
    rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn back_to_back_flows_serialize() {
        let mut r = Resource::new(1e9); // 1 GB/s
        let (s1, d1) = r.reserve(0.0, 1e6); // 1 MB -> 1 ms
        assert_eq!(s1, 0.0);
        assert!((d1 - 1e-3).abs() < 1e-12);
        let (s2, _) = r.reserve(0.0, 1e6);
        assert!((s2 - 1e-3).abs() < 1e-12, "second flow must queue");
    }

    #[test]
    fn idle_gap_respected() {
        let mut r = Resource::new(1e9);
        r.reserve(0.0, 1e6);
        let (s, _) = r.reserve(5.0, 1e3);
        assert_eq!(s, 5.0, "flow arriving later starts at its ready time");
    }

    #[test]
    fn busy_accounting() {
        let mut r = Resource::new(2e9);
        r.reserve(0.0, 2e9); // 1 s
        r.reserve(0.0, 1e9); // 0.5 s
        assert!((r.busy - 1.5).abs() < 1e-9);
        r.reset();
        assert_eq!(r.busy, 0.0);
        assert_eq!(r.available_at, 0.0);
    }

    fn fr(ids: &[usize]) -> FlowResources {
        let mut f = FlowResources::new();
        for &id in ids {
            f.push(id);
        }
        f
    }

    #[test]
    fn single_flow_gets_its_cap() {
        let rates = max_min_rates(&[10.0, 10.0], &[3.0], &[fr(&[0, 1])]);
        assert_eq!(rates, vec![3.0]);
    }

    #[test]
    fn two_flows_share_a_bottleneck_equally() {
        // Both flows want 10, the shared resource has 10 -> 5 each.
        let rates = max_min_rates(&[10.0], &[10.0, 10.0], &[fr(&[0]), fr(&[0])]);
        assert!((rates[0] - 5.0).abs() < 1e-9);
        assert!((rates[1] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn capped_flow_releases_headroom() {
        // Flow 0 capped at 2; flow 1 takes the remaining 8.
        let rates = max_min_rates(&[10.0], &[2.0, 100.0], &[fr(&[0]), fr(&[0])]);
        assert!((rates[0] - 2.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[1] - 8.0).abs() < 1e-9, "{rates:?}");
    }

    #[test]
    fn disjoint_flows_independent() {
        let rates = max_min_rates(&[4.0, 6.0], &[10.0, 10.0], &[fr(&[0]), fr(&[1])]);
        assert!((rates[0] - 4.0).abs() < 1e-9);
        assert!((rates[1] - 6.0).abs() < 1e-9);
    }

    #[test]
    fn multi_resource_bottleneck_is_the_minimum() {
        // Flow crosses NIC (cap 10 shared with another flow) and an uplink
        // of 3: uplink binds it; the NIC peer then takes the NIC headroom.
        let rates = max_min_rates(
            &[10.0, 3.0],
            &[100.0, 100.0],
            &[fr(&[0, 1]), fr(&[0])],
        );
        assert!((rates[0] - 3.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[1] - 7.0).abs() < 1e-9, "{rates:?}");
    }

    #[test]
    fn conservation_and_fairness_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..200 {
            let n_res = 1 + rng.below(5) as usize;
            let caps: Vec<f64> = (0..n_res).map(|_| rng.uniform_in(1.0, 20.0)).collect();
            let n_flows = 1 + rng.below(8) as usize;
            let mut flow_caps = Vec::new();
            let mut flow_res = Vec::new();
            for _ in 0..n_flows {
                flow_caps.push(rng.uniform_in(0.5, 30.0));
                let k = 1 + rng.below(2) as usize;
                let mut f = FlowResources::new();
                let mut used = Vec::new();
                for _ in 0..k {
                    let r = rng.below(n_res as u64) as usize;
                    if !used.contains(&r) {
                        f.push(r);
                        used.push(r);
                    }
                }
                flow_res.push(f);
            }
            let rates = max_min_rates(&caps, &flow_caps, &flow_res);
            // No flow exceeds its cap; no resource is oversubscribed.
            for (i, &r) in rates.iter().enumerate() {
                assert!(r <= flow_caps[i] * (1.0 + 1e-9), "flow {i} over cap");
                assert!(r >= 0.0);
            }
            for (r, &cap) in caps.iter().enumerate() {
                let used: f64 = rates
                    .iter()
                    .zip(&flow_res)
                    .filter(|(_, fr)| fr.iter().any(|id| id == r))
                    .map(|(rate, _)| rate)
                    .sum();
                assert!(used <= cap * (1.0 + 1e-9), "resource {r} oversubscribed");
            }
            // Work-conserving: every flow is blocked by its cap or by a
            // saturated resource.
            for (i, &r) in rates.iter().enumerate() {
                let at_cap = r >= flow_caps[i] * (1.0 - 1e-6);
                let blocked = flow_res[i].iter().any(|id| {
                    let used: f64 = rates
                        .iter()
                        .zip(&flow_res)
                        .filter(|(_, fr)| fr.iter().any(|x| x == id))
                        .map(|(rate, _)| rate)
                        .sum();
                    used >= caps[id] * (1.0 - 1e-6)
                });
                assert!(
                    at_cap || blocked || flow_res[i].is_empty(),
                    "flow {i} rate {r} is not work-conserving"
                );
            }
        }
    }
}
