//! The fabric simulator: a discrete-event, fluid-flow engine.
//!
//! Every inter-node message is a **flow** that occupies every link of
//! its deterministic route through the configured topology
//! ([`crate::fabric::topology`]): its source node's NIC transmit port,
//! its destination node's NIC receive port, and — when it leaves the
//! source ToR — the leaf up/down-links on the ECMP-chosen spine (plus
//! the group global links under a dragonfly spec). Flows submitted
//! together in one [`NetSim::transfer_batch`]
//! call (one communication round) progress concurrently: virtual time
//! advances event by event (flow arrival / flow completion), and at every
//! event the instantaneous rate of each in-flight flow is recomputed as
//! the **max-min fair** share of its resources, capped by the flow's own
//! transport-level ceiling (PCIe/UPI segments, GPUDirect vs staged copy).
//!
//! On top of endpoint fair sharing, a batch-level switch congestion factor
//! (the fabric's knee model, fed with the number of *distinct transmitting
//! nodes* in the round — i.e. concurrent NIC-level flows through the core)
//! scales both flow caps and port capacities, reproducing shallow-buffer
//! Ethernet's sag at scale versus OPA's credit-based flow control.
//!
//! Batches are the unit of concurrency: rounds issued sequentially contend
//! only through per-resource `busy_until` carry-over (a later flow cannot
//! start before the resources it needs have drained), which matches the
//! serialized-collectives execution model of Horovod/NCCL streams. An
//! uncontended batch (no resource shared by two flows — the common case
//! for ring rounds) takes a closed-form fast path that is exactly the
//! latency/bandwidth model, so single-flow timings are identical to
//! [`transport::MessageCost::total`] by construction.

use crate::cluster::{Endpoint, EndpointKind, Placement};
use crate::config::{ClusterSpec, FabricSpec, TransportOptions};
use crate::fabric::contention::{max_min_rates, FlowResources};
use crate::fabric::topology::Topology;
use crate::fabric::transport::{self, MessageGeometry};
use std::collections::HashMap;

/// Aggregate statistics for a simulation run.
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    pub messages: u64,
    pub bytes: f64,
    pub inter_node_messages: u64,
    pub inter_rack_messages: u64,
    /// Largest number of inter-node flows submitted in any single batch
    /// (an upper bound on simultaneous flight: staggered ready times can
    /// make actual overlap smaller).
    pub peak_concurrent_flows: u64,
}

/// One message submitted to the engine.
#[derive(Clone, Copy, Debug)]
pub struct FlowReq {
    pub src: Endpoint,
    pub dst: Endpoint,
    pub bytes: f64,
    /// Virtual time at which the payload is available on the sender.
    pub ready: f64,
}

/// Completion report for one flow.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlowTimes {
    /// When the sender may continue (last byte handed to its NIC).
    pub send_release: f64,
    /// When the receiver owns the data (wire latency + recv overhead after
    /// the transfer drains).
    pub recv_complete: f64,
}

/// An inter-node flow in flight (engine-internal).
struct NetFlow {
    req_idx: usize,
    src_node: usize,
    dst_node: usize,
    inter_rack: bool,
    /// Transfer start: ready + send overhead, floored by the prior
    /// occupancy of every resource the flow needs.
    arrival: f64,
    bytes: f64,
    /// Uncontended rate cap from the transport layer (bytes/s).
    cap: f64,
    latency: f64,
    recv_overhead: f64,
    res: FlowResources,
}

/// Discrete-event network simulator for one fabric + cluster + transport
/// configuration. Virtual time is `f64` seconds; rank clocks are owned by
/// [`crate::fabric::Comm`], not by the simulator.
pub struct NetSim {
    pub fabric: FabricSpec,
    pub cluster: ClusterSpec,
    pub opts: TransportOptions,
    /// The link graph flows are routed through. Built from
    /// `fabric.topology`; owns the per-link capacity table (the default
    /// spec reproduces the legacy NIC + rack-uplink layout bit-for-bit).
    pub topology: Topology,
    /// Virtual time until which each resource is drained by prior batches.
    busy_until: Vec<f64>,
    /// Scratch per-resource flow counter (zeroed outside `transfer_batch`).
    load: Vec<u32>,
    /// Per-(src, dst) flow sequence numbers feeding the ECMP hash.
    /// Deterministic: only ever read/written for pairs this sim routed,
    /// in submission order, so routes are independent of `--jobs`.
    flow_seq: HashMap<(usize, usize), u64>,
    pub stats: NetStats,
    /// Optional message-level trace (enable with [`NetSim::enable_trace`]).
    pub trace: Option<crate::fabric::trace::Trace>,
}

fn time_eps(t: f64) -> f64 {
    1e-12 * (1.0 + t.abs())
}

fn byte_eps(bytes: f64) -> f64 {
    1e-12 * (1.0 + bytes)
}

impl NetSim {
    /// Build a simulator, routing through `fabric.topology`. Panics if
    /// the topology spec cannot host the cluster — use
    /// [`NetSim::try_new`] where the config comes from user input.
    pub fn new(fabric: FabricSpec, cluster: ClusterSpec, opts: TransportOptions) -> Self {
        Self::try_new(fabric, cluster, opts).expect("invalid fabric topology for cluster")
    }

    /// Fallible constructor: validates the topology against the cluster.
    pub fn try_new(
        fabric: FabricSpec,
        cluster: ClusterSpec,
        opts: TransportOptions,
    ) -> anyhow::Result<Self> {
        let topology = Topology::build(&fabric.topology, &fabric, &cluster)?;
        let n_res = topology.num_resources();
        Ok(NetSim {
            fabric,
            cluster,
            opts,
            topology,
            busy_until: vec![0.0; n_res],
            load: vec![0; n_res],
            flow_seq: HashMap::new(),
            stats: NetStats::default(),
            trace: None,
        })
    }

    /// Start recording every delivered message.
    pub fn enable_trace(&mut self) {
        self.trace = Some(crate::fabric::trace::Trace::default());
    }

    /// Reset occupancy, stats and ECMP flow sequencing between
    /// experiments (keeps specs).
    pub fn reset(&mut self) {
        for b in self.busy_until.iter_mut() {
            *b = 0.0;
        }
        self.flow_seq.clear();
        self.stats = NetStats::default();
    }

    /// Drain time of one link (observability: lets tests assert a flow
    /// occupied exactly the links of its route).
    pub fn resource_busy_until(&self, id: usize) -> f64 {
        self.busy_until[id]
    }

    /// Deliver one message; returns (send_release_time, recv_complete_time).
    ///
    /// Equivalent to a one-flow [`NetSim::transfer_batch`]: an uncontended
    /// flow reproduces the closed-form transport cost exactly; occupancy
    /// left by earlier calls delays it.
    pub fn message(
        &mut self,
        src: Endpoint,
        dst: Endpoint,
        bytes: f64,
        ready: f64,
    ) -> (f64, f64) {
        let times = self.transfer_batch(&[FlowReq { src, dst, bytes, ready }]);
        (times[0].send_release, times[0].recv_complete)
    }

    /// Run one communication round: all `reqs` flows are concurrently in
    /// flight and share NIC ports / rack up-links max-min fairly. Returns
    /// per-flow completion times in request order.
    pub fn transfer_batch(&mut self, reqs: &[FlowReq]) -> Vec<FlowTimes> {
        let mut out = vec![FlowTimes::default(); reqs.len()];
        let mut flows: Vec<NetFlow> = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            self.stats.messages += 1;
            self.stats.bytes += req.bytes;

            if req.src.node == req.dst.node {
                // Intra-node path: PCIe P2P or shared memory; no NIC, no
                // shared engine resources (the link is point-to-point).
                let cost = transport::local_message(&self.cluster, req.src.kind, req.bytes);
                let done = req.ready + cost.total(req.bytes);
                out[i] = FlowTimes { send_release: done, recv_complete: done };
                continue;
            }

            self.stats.inter_node_messages += 1;
            // Route the flow through the topology: the returned link set
            // replaces the old hard-coded NIC/rack wiring. The per-pair
            // sequence number feeds the (deterministic) ECMP hash — with a
            // single spine the hash is trivial, so skip the counter upkeep
            // and keep the default-topology hot path map-free.
            let seq = if self.topology.n_spines > 1 {
                let e = self.flow_seq.entry((req.src.node, req.dst.node)).or_insert(0);
                let s = *e;
                *e += 1;
                s
            } else {
                0
            };
            let route = self.topology.route(req.src.node, req.dst.node, seq);
            let inter_rack = route.inter_tor;
            if inter_rack {
                self.stats.inter_rack_messages += 1;
            }
            let geo = MessageGeometry {
                bytes: req.bytes,
                inter_rack,
                endpoint: req.src.kind,
                src_slot: req.src.slot,
                dst_slot: req.dst.slot,
            };
            let cost = transport::network_message(&self.fabric, &self.cluster, &self.opts, &geo);

            let res = route.res;
            let mut arrival = req.ready + cost.send_overhead;
            for id in res.iter() {
                arrival = arrival.max(self.busy_until[id]);
            }
            flows.push(NetFlow {
                req_idx: i,
                src_node: req.src.node,
                dst_node: req.dst.node,
                inter_rack,
                arrival,
                bytes: req.bytes,
                cap: cost.bandwidth,
                latency: cost.latency,
                recv_overhead: cost.recv_overhead,
                res,
            });
        }
        if flows.is_empty() {
            return out;
        }

        // Switch-level congestion: concurrent NIC-level flows through the
        // core ~= distinct transmitting nodes in this round.
        let mut srcs: Vec<usize> = flows.iter().map(|f| f.src_node).collect();
        srcs.sort_unstable();
        srcs.dedup();
        let factor = self.fabric.congestion_factor(srcs.len() as f64);
        self.stats.peak_concurrent_flows =
            self.stats.peak_concurrent_flows.max(flows.len() as u64);

        // Contention detection: does any resource carry two flows?
        let mut contended = false;
        for f in &flows {
            for id in f.res.iter() {
                self.load[id] += 1;
                if self.load[id] > 1 {
                    contended = true;
                }
            }
        }
        let finishes: Vec<f64> = if contended {
            self.fluid_finishes(&flows, factor)
        } else {
            // Fast path: every flow runs at its (congestion-scaled) cap.
            flows
                .iter()
                .map(|f| f.arrival + f.bytes / (f.cap * factor))
                .collect()
        };
        for f in &flows {
            for id in f.res.iter() {
                self.load[id] = 0;
            }
        }

        for (f, &fin) in flows.iter().zip(&finishes) {
            let recv_complete = fin + f.latency + f.recv_overhead;
            out[f.req_idx] = FlowTimes { send_release: fin, recv_complete };
            for id in f.res.iter() {
                self.busy_until[id] = self.busy_until[id].max(fin);
            }
            if let Some(trace) = self.trace.as_mut() {
                trace.record(crate::fabric::trace::MessageEvent {
                    src_node: f.src_node,
                    dst_node: f.dst_node,
                    bytes: f.bytes,
                    start: f.arrival,
                    end: recv_complete,
                    inter_rack: f.inter_rack,
                });
            }
        }
        out
    }

    /// Event loop over a contended batch: advance virtual time from event
    /// to event (arrival or completion), recomputing max-min fair rates at
    /// each one. Returns per-flow transfer-finish times (same order as
    /// `flows`).
    fn fluid_finishes(&self, flows: &[NetFlow], factor: f64) -> Vec<f64> {
        let n = flows.len();
        // Compact the touched resource ids so the solver works on a dense
        // table (global ids are sparse over nodes x racks).
        let mut ids: Vec<usize> = flows.iter().flat_map(|f| f.res.iter()).collect();
        ids.sort_unstable();
        ids.dedup();
        let caps: Vec<f64> = ids.iter().map(|&id| self.topology.caps()[id] * factor).collect();
        let res: Vec<FlowResources> = flows
            .iter()
            .map(|f| {
                let mut fr = FlowResources::new();
                for id in f.res.iter() {
                    fr.push(ids.binary_search(&id).unwrap());
                }
                fr
            })
            .collect();
        let fcaps: Vec<f64> = flows.iter().map(|f| f.cap * factor).collect();

        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| flows[a].arrival.partial_cmp(&flows[b].arrival).unwrap());

        let mut finish = vec![0.0f64; n];
        let mut remaining: Vec<f64> = flows.iter().map(|f| f.bytes).collect();
        let mut active: Vec<usize> = Vec::new();
        let mut ptr = 0usize;
        let mut t = flows[order[0]].arrival;
        // Event budget: symmetric batches collapse into a handful of
        // completion waves (flows of equal size and contention finish at
        // bit-identical times and retire together), but an adversarial
        // mix could make every completion its own event — O(F) events x
        // O(F) rate solve. Past the budget, remaining flows keep their
        // current rates and pending ones fall back to their caps:
        // deterministic, work-bounded, and exact for every batch whose
        // event count fits (all the test workloads do by a wide margin).
        let max_events = 512 + 40_000_000 / (n + 64);
        let mut events = 0usize;
        let mut a_caps: Vec<f64> = Vec::new();
        let mut a_res: Vec<FlowResources> = Vec::new();
        loop {
            // Activate flows whose arrival is due (ties within epsilon).
            while ptr < n && flows[order[ptr]].arrival <= t + time_eps(t) {
                let fi = order[ptr];
                ptr += 1;
                if remaining[fi] <= byte_eps(flows[fi].bytes) {
                    finish[fi] = flows[fi].arrival; // zero-byte flow
                } else {
                    active.push(fi);
                }
            }
            if active.is_empty() {
                if ptr >= n {
                    break;
                }
                t = flows[order[ptr]].arrival;
                continue;
            }

            a_caps.clear();
            a_res.clear();
            for &fi in &active {
                a_caps.push(fcaps[fi]);
                a_res.push(res[fi]);
            }
            let rates = max_min_rates(&caps, &a_caps, &a_res);

            events += 1;
            if events > max_events {
                // Budget exhausted: freeze the current fair allocation.
                for (k, &fi) in active.iter().enumerate() {
                    finish[fi] = if rates[k] > 0.0 {
                        t + remaining[fi] / rates[k]
                    } else {
                        t
                    };
                }
                while ptr < n {
                    let fi = order[ptr];
                    ptr += 1;
                    finish[fi] =
                        flows[fi].arrival + flows[fi].bytes / fcaps[fi].max(f64::MIN_POSITIVE);
                }
                break;
            }

            // Next event: earliest completion among active flows, or the
            // next arrival, whichever comes first.
            let mut t_next = f64::INFINITY;
            for (k, &fi) in active.iter().enumerate() {
                if rates[k] > 0.0 {
                    t_next = t_next.min(t + remaining[fi] / rates[k]);
                }
            }
            if ptr < n {
                t_next = t_next.min(flows[order[ptr]].arrival);
            }
            if !t_next.is_finite() {
                // Unreachable with positive capacities; fail closed.
                for &fi in &active {
                    finish[fi] = t;
                }
                active.clear();
                continue;
            }

            let dt = (t_next - t).max(0.0);
            for (k, &fi) in active.iter().enumerate() {
                remaining[fi] -= rates[k] * dt;
            }
            t = t_next;

            let mut still = Vec::with_capacity(active.len());
            for &fi in active.iter() {
                if remaining[fi] <= byte_eps(flows[fi].bytes) {
                    finish[fi] = t;
                } else {
                    still.push(fi);
                }
            }
            active = still;
            if active.is_empty() && ptr >= n {
                break;
            }
        }
        finish
    }

    /// One-shot convenience: time for a single message with an idle network.
    pub fn one_way_time(
        &mut self,
        placement: &Placement,
        src: usize,
        dst: usize,
        bytes: f64,
    ) -> f64 {
        self.reset();
        let (_, done) =
            self.message(placement.endpoints[src], placement.endpoints[dst], bytes, 0.0);
        done
    }

    /// Endpoint constructor for tests / microbenches.
    pub fn endpoint(node: usize, slot: usize, kind: EndpointKind) -> Endpoint {
        Endpoint { rank: 0, node, slot, kind }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::FabricKind;
    use crate::util::prop;

    fn sim(kind: FabricKind) -> NetSim {
        NetSim::new(fabric(kind), ClusterSpec::txgaia(), TransportOptions::default())
    }

    fn cpu_ep(node: usize) -> Endpoint {
        NetSim::endpoint(node, 0, EndpointKind::Cpu)
    }

    #[test]
    fn latency_dominates_small_messages() {
        let mut s = sim(FabricKind::OmniPath100);
        let (_, t) = s.message(cpu_ep(0), cpu_ep(1), 8.0, 0.0);
        assert!(t < 5.0e-6, "small message took {t}");
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 256.0 * 1024.0 * 1024.0;
        let (_, t) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let model = bytes / s.fabric.effective_bandwidth();
        assert!((t - model).abs() / model < 0.05, "t={t} model={model}");
    }

    #[test]
    fn opa_faster_than_ethernet_at_all_sizes() {
        for bytes in [8.0, 1024.0, 65536.0, 16.0 * 1024.0 * 1024.0] {
            let mut e = sim(FabricKind::EthernetRoce25);
            let mut o = sim(FabricKind::OmniPath100);
            let (_, te) = e.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
            let (_, to) = o.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
            assert!(to < te, "bytes={bytes}: opa {to} !< eth {te}");
        }
    }

    #[test]
    fn single_flow_matches_closed_form_exactly() {
        // Event-engine parity: an uncontended flow must land within 1e-9 s
        // of the analytic latency/bandwidth model, for every fabric and a
        // span of sizes crossing the eager/rendezvous threshold.
        for kind in [
            FabricKind::EthernetRoce25,
            FabricKind::EthernetTcp25,
            FabricKind::OmniPath100,
            FabricKind::InfinibandEdr100,
        ] {
            for bytes in [0.0, 8.0, 4096.0, 65536.0, 1e6, 64.0 * 1024.0 * 1024.0] {
                let mut s = sim(kind);
                let (_, t) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
                let geo = MessageGeometry {
                    bytes,
                    inter_rack: false,
                    endpoint: EndpointKind::Cpu,
                    src_slot: 0,
                    dst_slot: 0,
                };
                let cost =
                    transport::network_message(&s.fabric, &s.cluster, &s.opts, &geo);
                let model = cost.total(bytes);
                assert!(
                    (t - model).abs() < 1e-9,
                    "{kind:?} {bytes}B: engine {t} vs model {model}"
                );
            }
        }
    }

    #[test]
    fn nic_occupancy_serializes_fanout() {
        // Node 0 sending to two different nodes: second flow queues on tx.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let (_, t2) = s.message(cpu_ep(0), cpu_ep(2), bytes, 0.0);
        assert!(t2 > t1 * 1.8, "fanout must serialize: t1={t1} t2={t2}");
    }

    #[test]
    fn concurrent_fanout_shares_fairly() {
        // Same fanout submitted as ONE round: the two flows share the tx
        // port max-min fairly, finish together, and take ~2x a lone flow.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, lone) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        s.reset();
        let times = s.transfer_batch(&[
            FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes, ready: 0.0 },
            FlowReq { src: cpu_ep(0), dst: cpu_ep(2), bytes, ready: 0.0 },
        ]);
        let (a, b) = (times[0].recv_complete, times[1].recv_complete);
        assert!((a - b).abs() < 1e-9, "fair sharing must finish together: {a} vs {b}");
        assert!(a > 1.8 * lone && a < 2.2 * lone, "shared {a} vs lone {lone}");
    }

    #[test]
    fn staggered_contention_is_event_accurate() {
        // Flow B arrives halfway through flow A on the same tx port. A
        // runs alone, then both share, then B finishes alone: both take
        // longer than solo, and A finishes first.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, solo) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        s.reset();
        let times = s.transfer_batch(&[
            FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes, ready: 0.0 },
            FlowReq { src: cpu_ep(0), dst: cpu_ep(2), bytes, ready: solo / 2.0 },
        ]);
        let (a, b) = (times[0].recv_complete, times[1].recv_complete);
        assert!(a > solo * 1.2 && a < solo * 1.8, "A shared half its life: {a} vs solo {solo}");
        assert!(b > a, "B arrived later and must finish later: {b} !> {a}");
        // Work conservation: the port moved 2x bytes in total; B cannot
        // finish before the aggregate drain time.
        assert!(b > 1.9 * solo, "aggregate drain violated: {b} vs {solo}");
    }

    #[test]
    fn disjoint_pairs_run_in_parallel() {
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let (_, t2) = s.message(cpu_ep(2), cpu_ep(3), bytes, 0.0);
        assert!((t1 - t2).abs() < 1e-9, "disjoint flows must not interfere");
    }

    #[test]
    fn disjoint_batch_matches_sequential_disjoint() {
        // A round of disjoint pairs must time exactly like each pair alone.
        let mut s = sim(FabricKind::OmniPath100);
        let bytes = 1e6;
        let (_, alone) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        s.reset();
        let times = s.transfer_batch(&[
            FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes, ready: 0.0 },
            FlowReq { src: cpu_ep(2), dst: cpu_ep(3), bytes, ready: 0.0 },
            FlowReq { src: cpu_ep(4), dst: cpu_ep(5), bytes, ready: 0.0 },
        ]);
        for ft in &times {
            assert!((ft.recv_complete - alone).abs() < 1e-12);
        }
    }

    #[test]
    fn rack_uplink_contends_inter_rack_flows() {
        // Many simultaneous flows from rack 0 to rack 1 share the up-link;
        // the same count of intra-rack flows only share distinct NICs.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 16.0 * 1024.0 * 1024.0;
        let n = 16; // 16 * 2.875 GB/s >> 23 GB/s uplink
        let cross: Vec<FlowReq> = (0..n)
            .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(32 + i), bytes, ready: 0.0 })
            .collect();
        let t_cross = s
            .transfer_batch(&cross)
            .iter()
            .map(|f| f.recv_complete)
            .fold(0.0, f64::max);
        s.reset();
        let local: Vec<FlowReq> = (0..n)
            .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(16 + i), bytes, ready: 0.0 })
            .collect();
        let t_local = s
            .transfer_batch(&local)
            .iter()
            .map(|f| f.recv_complete)
            .fold(0.0, f64::max);
        assert!(
            t_cross > 1.5 * t_local,
            "uplink contention missing: cross {t_cross} vs local {t_local}"
        );
    }

    #[test]
    fn intra_node_faster_than_inter_node() {
        let mut s = sim(FabricKind::OmniPath100);
        let gpu0 = NetSim::endpoint(0, 0, EndpointKind::Gpu);
        let gpu1 = NetSim::endpoint(0, 1, EndpointKind::Gpu);
        let gpu2 = NetSim::endpoint(1, 0, EndpointKind::Gpu);
        let bytes = 1024.0 * 1024.0;
        let (_, local) = s.message(gpu0, gpu1, bytes, 0.0);
        s.reset();
        let (_, remote) = s.message(gpu0, gpu2, bytes, 0.0);
        assert!(local < remote);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = sim(FabricKind::OmniPath100);
        s.message(cpu_ep(0), cpu_ep(1), 100.0, 0.0);
        s.message(cpu_ep(0), cpu_ep(40), 100.0, 0.0); // node 40 = rack 1
        let gpu0 = NetSim::endpoint(0, 0, EndpointKind::Gpu);
        let gpu1 = NetSim::endpoint(0, 1, EndpointKind::Gpu);
        s.message(gpu0, gpu1, 100.0, 0.0);
        assert_eq!(s.stats.messages, 3);
        assert_eq!(s.stats.inter_node_messages, 2);
        assert_eq!(s.stats.inter_rack_messages, 1);
        assert_eq!(s.stats.bytes, 300.0);
        assert_eq!(s.stats.peak_concurrent_flows, 1);
    }

    #[test]
    fn message_time_monotone_in_size() {
        let gen = |r: &mut crate::util::rng::Rng| (r.below(24) as i32, r.below(1_000_000) as f64);
        prop::forall(31, 128, gen, |&(shift, base)| {
            let mut s = sim(FabricKind::EthernetRoce25);
            let b1 = base + 1.0;
            let b2 = b1 * (1.0 + (shift as f64 + 1.0) / 4.0);
            let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), b1, 0.0);
            s.reset();
            let (_, t2) = s.message(cpu_ep(0), cpu_ep(1), b2, 0.0);
            if t2 + 1e-15 < t1 {
                return Err(format!("time not monotone: {b1}B->{t1}s, {b2}B->{t2}s"));
            }
            Ok(())
        });
    }

    #[test]
    fn ready_time_shifts_completion() {
        let mut s = sim(FabricKind::OmniPath100);
        let (_, t0) = s.message(cpu_ep(0), cpu_ep(1), 1000.0, 0.0);
        s.reset();
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), 1000.0, 1.0);
        assert!((t1 - t0 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn oversubscription_throttles_cross_rack_rounds() {
        // 16 symmetric rack0 -> rack1 flows: tightening the leaf->spine
        // taper must never speed the batch up, and 8:1 must be clearly
        // slower than full bisection.
        let bytes = 16.0 * 1024.0 * 1024.0;
        let mut last = 0.0;
        let mut times = Vec::new();
        for ratio in [1.0, 2.0, 4.0, 8.0] {
            let mut f = fabric(FabricKind::EthernetRoce25);
            f.topology.oversubscription = Some(ratio);
            let mut s = NetSim::new(f, ClusterSpec::txgaia(), TransportOptions::default());
            let reqs: Vec<FlowReq> = (0..16)
                .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(32 + i), bytes, ready: 0.0 })
                .collect();
            let t = s
                .transfer_batch(&reqs)
                .iter()
                .map(|ft| ft.recv_complete)
                .fold(0.0, f64::max);
            assert!(t + 1e-12 >= last, "ratio {ratio}: batch sped up ({t} < {last})");
            last = t;
            times.push(t);
        }
        assert!(times[3] > 1.5 * times[0], "8:1 should clearly throttle: {times:?}");
    }

    #[test]
    fn ecmp_routes_are_replayable_after_reset() {
        // Same submission sequence after reset() -> bit-identical times:
        // per-pair flow sequencing restarts and ECMP replays.
        let mut f = fabric(FabricKind::EthernetRoce25);
        f.topology.spines = 4;
        f.topology.oversubscription = Some(4.0);
        let mut s = NetSim::new(f, ClusterSpec::txgaia(), TransportOptions::default());
        let reqs: Vec<FlowReq> = (0..8)
            .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(40 + i), bytes: 1e6, ready: 0.0 })
            .collect();
        let a: Vec<f64> = s.transfer_batch(&reqs).iter().map(|t| t.recv_complete).collect();
        s.reset();
        let b: Vec<f64> = s.transfer_batch(&reqs).iter().map(|t| t.recv_complete).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "reset did not replay routes");
        }
    }

    #[test]
    fn trace_records_batch_events() {
        let mut s = sim(FabricKind::OmniPath100);
        s.enable_trace();
        s.transfer_batch(&[
            FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes: 1e6, ready: 0.0 },
            FlowReq { src: cpu_ep(0), dst: cpu_ep(40), bytes: 1e6, ready: 0.0 },
        ]);
        let trace = s.trace.as_ref().unwrap();
        assert_eq!(trace.len(), 2);
        assert!(trace.events.iter().any(|e| e.inter_rack));
        assert!(trace.events.iter().all(|e| e.end > e.start));
    }
}
