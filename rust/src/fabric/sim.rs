//! The fabric simulator: a discrete-event, fluid-flow engine.
//!
//! Every inter-node message is a **flow** that occupies every link of
//! its deterministic route through the configured topology
//! ([`crate::fabric::topology`]): its source node's NIC transmit port,
//! its destination node's NIC receive port, and — when it leaves the
//! source ToR — the leaf up/down-links on the ECMP-chosen spine (plus
//! the group global links under a dragonfly spec). Flows submitted
//! together in one [`NetSim::transfer_batch`]
//! call (one communication round) progress concurrently: virtual time
//! advances event by event (flow arrival / flow completion), and at every
//! event the instantaneous rate of each in-flight flow is recomputed as
//! the **max-min fair** share of its resources, capped by the flow's own
//! transport-level ceiling (PCIe/UPI segments, GPUDirect vs staged copy).
//!
//! On top of endpoint fair sharing, a batch-level switch congestion factor
//! (the fabric's knee model, fed with the number of *distinct transmitting
//! nodes* in the round — i.e. concurrent NIC-level flows through the core)
//! scales both flow caps and port capacities, reproducing shallow-buffer
//! Ethernet's sag at scale versus OPA's credit-based flow control.
//!
//! Batches are the unit of concurrency: rounds issued sequentially contend
//! only through per-resource `busy_until` carry-over (a later flow cannot
//! start before the resources it needs have drained), which matches the
//! serialized-collectives execution model of Horovod/NCCL streams. An
//! uncontended batch (no resource shared by two flows — the common case
//! for ring rounds) takes a closed-form fast path that is exactly the
//! latency/bandwidth model, so single-flow timings are identical to
//! [`transport::MessageCost::total`] by construction.
//!
//! # The incremental hot path
//!
//! A contended batch runs an event loop over **bottleneck groups**: the
//! connected components of the flow/resource sharing graph. Groups merge
//! when an arriving flow touches a resource of an existing group (and,
//! conservatively, are never split while non-empty), every arrival or
//! departure marks only the affected group dirty, and only dirty groups
//! are re-solved — an event in one ToR's incast does not re-solve an
//! unrelated pair's flows. Remaining bytes are settled lazily (each flow
//! carries `(remaining, t0, rate)` and is integrated only when its
//! group's rates change), and the next completion comes from a binary
//! heap of projected finish times with lazy invalidation (per-flow
//! stamps) instead of a linear scan over all active flows. The solver
//! itself is the allocation-free [`MaxMinScratch`]
//! (see [`crate::fabric::contention`]); the batch-wide compact resource
//! remap is a persistent per-topology table built once in
//! [`NetSim::try_new`] and reset sparsely after each batch. See
//! `fabric/README.md` § "Performance model" for the complexity budget.

use crate::cluster::{Endpoint, EndpointKind, Placement};
use crate::config::{ClusterSpec, FabricSpec, TransportOptions};
use crate::fabric::contention::{FlowResources, MaxMinScratch};
use crate::fabric::faults::{FaultSpec, FaultTimeline};
use crate::fabric::mpi::RetryPolicy;
use crate::fabric::topology::Topology;
use crate::fabric::transport::{self, MessageGeometry};
use crate::trainer::scheduler::ScheduleCache;
use std::cmp::Ordering;
use std::collections::HashMap;

/// Aggregate statistics for a simulation run.
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    pub messages: u64,
    pub bytes: f64,
    pub inter_node_messages: u64,
    pub inter_rack_messages: u64,
    /// Largest number of inter-node flows submitted in any single batch
    /// (an upper bound on simultaneous flight: staggered ready times can
    /// make actual overlap smaller).
    pub peak_concurrent_flows: u64,
    /// Total fluid event-loop iterations (arrivals/completions processed
    /// by contended batches). A perf counter for the engine bench.
    pub fluid_events: u64,
    /// Contended batches that exhausted the event budget and fell back to
    /// frozen rates. Non-zero means timing degraded from event-exact to
    /// rate-frozen for those batches — the engine also warns on stderr
    /// the first time so sweeps cannot degrade silently.
    pub budget_exceeded: u64,
    /// Fluid aggregation units actually solved by contended batches (one
    /// unit per distinct (route, flow cap, arrival, bytes) class; equals
    /// the flow count when [`TransportOptions::flow_aggregation`] is
    /// off). Perf counters for the engine bench: `agg_collapsed` is the
    /// number of flows that rode along in an existing unit — the work
    /// the aggregation saved.
    pub agg_units: u64,
    pub agg_collapsed: u64,
    /// Background-tenant flows injected by the shared-tenancy model
    /// ([`crate::fabric::tenancy`]). Kept separate from the training
    /// counters above (`messages`/`bytes` stay training-only), so
    /// training-vs-background attribution is always available.
    pub background_messages: u64,
    pub background_bytes: f64,
    /// Fault-injection accounting ([`crate::fabric::faults`]): timeout
    /// probes paid by flows whose path was fault-dead (each backoff wait
    /// counts once, including the probe that succeeds), flows re-routed
    /// onto a surviving ECMP spine (at admission or mid-flight), and
    /// flows that exhausted the retry window and failed loudly. All zero
    /// on a healthy fabric.
    pub retries: u64,
    pub reroutes: u64,
    pub failed_flows: u64,
}

/// One message submitted to the engine.
#[derive(Clone, Copy, Debug)]
pub struct FlowReq {
    pub src: Endpoint,
    pub dst: Endpoint,
    pub bytes: f64,
    /// Virtual time at which the payload is available on the sender.
    pub ready: f64,
}

/// Completion report for one flow.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlowTimes {
    /// When the sender may continue (last byte handed to its NIC).
    pub send_release: f64,
    /// When the receiver owns the data (wire latency + recv overhead after
    /// the transfer drains).
    pub recv_complete: f64,
}

/// Marks a [`NetFlow`] as background-tenant traffic (no caller slot to
/// report a completion into).
const BACKGROUND_FLOW: usize = usize::MAX;

/// An inter-node flow in flight (engine-internal).
struct NetFlow {
    /// Index into the caller's request slice, or [`BACKGROUND_FLOW`].
    req_idx: usize,
    /// Owning tenant: 0 is the simulator's own job; any other id is a
    /// co-located tenant (anonymous generator or attributed fleet job).
    tenant: usize,
    src_node: usize,
    dst_node: usize,
    inter_rack: bool,
    /// Transfer start: ready + send overhead, floored by the prior
    /// occupancy of every resource the flow needs.
    arrival: f64,
    bytes: f64,
    /// Uncontended rate cap from the transport layer (bytes/s).
    cap: f64,
    latency: f64,
    recv_overhead: f64,
    res: FlowResources,
    /// The ECMP sequence the route was drawn with — kept so a mid-flight
    /// re-route over surviving spines re-hashes deterministically.
    seq: u64,
}

/// Lazily-invalidated completion-heap entry: `key` is the finish time
/// projected when `flow`'s rate was last assigned; `stamp` must match the
/// flow's current stamp or the entry is stale. Ordered by *reversed*
/// projection so `BinaryHeap` (a max-heap) peeks the earliest one.
#[derive(Clone, Copy, Debug)]
struct HeapEntry {
    key: f64,
    flow: u32,
    stamp: u32,
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        other.key.total_cmp(&self.key)
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for HeapEntry {}

/// One bottleneck group: the flows transitively sharing resources, plus
/// the resources the group has ever claimed (resources are retained
/// until the group empties — a conservative, deterministic over-merge
/// that never changes the solved rates, only how much is re-solved).
#[derive(Debug, Default)]
struct Group {
    members: Vec<u32>,
    resources: Vec<u32>,
    dirty: bool,
    live: bool,
}

/// Aggregation key for one fluid unit: flows are collapsed into one
/// weighted aggregate exactly when their **compact** resource set (route
/// through this batch's remap — ECMP spine choices key apart naturally),
/// congestion-scaled flow cap, arrival, and byte count are all
/// bit-identical. Under those conditions the members are fluid-
/// indistinguishable: they activate together, share every resource with
/// identical integer multiplicity, and the weighted max-min solve gives
/// each member exactly the rate it would get solved individually (see
/// [`crate::fabric::contention::max_min_rates_weighted`]) — so they
/// retire together and the de-aggregated finish times are bit-exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct AggKey {
    res: [u32; crate::fabric::contention::MAX_FLOW_RESOURCES],
    n_res: u8,
    fcap_bits: u64,
    arrival_bits: u64,
    bytes_bits: u64,
}

impl AggKey {
    fn new(res: FlowResources, fcap: f64, arrival: f64, bytes: f64) -> Self {
        let mut ids = [u32::MAX; crate::fabric::contention::MAX_FLOW_RESOURCES];
        let mut n_res = 0u8;
        for id in res.iter() {
            ids[n_res as usize] = id as u32;
            n_res += 1;
        }
        AggKey {
            res: ids,
            n_res,
            fcap_bits: fcap.to_bits(),
            arrival_bits: arrival.to_bits(),
            bytes_bits: bytes.to_bits(),
        }
    }
}

/// Per-batch event-loop state, allocated once per [`NetSim`] and reused
/// (no per-batch or per-event `Vec` allocation on the hot path).
#[derive(Debug, Default)]
struct FluidScratch {
    /// Global resource id -> compact batch-local id (`u32::MAX` unseen).
    /// Sized to the topology in [`NetSim::try_new`]; entries assigned
    /// during a batch are reset through `touched` afterwards.
    remap: Vec<u32>,
    touched: Vec<usize>,
    caps: Vec<f64>,
    res: Vec<FlowResources>,
    fcaps: Vec<f64>,
    /// Flow -> aggregation unit (the event loop below runs over units,
    /// not flows; identity when aggregation is off).
    unit_of: Vec<u32>,
    /// Per-unit inputs: representative route / flow cap / arrival /
    /// bytes, and the member multiplicity (`u_w`). The solver treats a
    /// weight-w unit as w identical flows and returns the per-member
    /// rate, so `rem`/`rate`/`t0` below carry per-member semantics too.
    u_res: Vec<FlowResources>,
    u_fcaps: Vec<f64>,
    u_arrival: Vec<f64>,
    u_bytes: Vec<f64>,
    u_w: Vec<u32>,
    u_finish: Vec<f64>,
    agg_map: HashMap<AggKey, u32>,
    /// The dirty groups settled this event, awaiting (possibly parallel)
    /// re-solve.
    wave: Vec<u32>,
    order: Vec<u32>,
    rem: Vec<f64>,
    t0: Vec<f64>,
    rate: Vec<f64>,
    active: Vec<bool>,
    stamp: Vec<u32>,
    heap: std::collections::BinaryHeap<HeapEntry>,
    group_of: Vec<u32>,
    member_pos: Vec<u32>,
    /// Per compact resource: owning group (`u32::MAX` none).
    res_group: Vec<u32>,
    groups: Vec<Group>,
    free_groups: Vec<u32>,
    dirty: Vec<u32>,
    /// Test hook: force a tiny event budget so the (structurally
    /// unreachable) frozen-rate fallback can be exercised.
    budget_override: Option<usize>,
    /// The budget warning fires once per *simulator lifetime* (not reset
    /// by [`NetSim::reset`], unlike the stats counter).
    budget_warned: bool,
}

impl FluidScratch {
    fn mark_dirty(&mut self, g: u32) {
        let gr = &mut self.groups[g as usize];
        if !gr.dirty {
            gr.dirty = true;
            self.dirty.push(g);
        }
    }

    fn alloc_group(&mut self) -> u32 {
        match self.free_groups.pop() {
            Some(g) => {
                self.groups[g as usize].live = true;
                g
            }
            None => {
                self.groups.push(Group { live: true, ..Group::default() });
                (self.groups.len() - 1) as u32
            }
        }
    }

    /// Activate unit `fi`: merge every group sharing one of its resources
    /// (largest absorbs, first wins ties) and mark the result dirty.
    fn join(&mut self, fi: usize) {
        let fr = self.u_res[fi];
        let mut gids = [u32::MAX; crate::fabric::contention::MAX_FLOW_RESOURCES];
        let mut n_g = 0usize;
        for r in fr.iter() {
            let g = self.res_group[r];
            if g != u32::MAX && !gids[..n_g].contains(&g) {
                gids[n_g] = g;
                n_g += 1;
            }
        }
        let g = if n_g == 0 {
            self.alloc_group()
        } else {
            let mut g = gids[0];
            for &o in &gids[1..n_g] {
                if self.groups[o as usize].members.len() > self.groups[g as usize].members.len() {
                    g = o;
                }
            }
            for &o in &gids[..n_g] {
                if o == g {
                    continue;
                }
                let (mem, res_list) = {
                    let go = &mut self.groups[o as usize];
                    go.live = false;
                    go.dirty = false;
                    (std::mem::take(&mut go.members), std::mem::take(&mut go.resources))
                };
                for &m in &mem {
                    self.group_of[m as usize] = g;
                    self.member_pos[m as usize] = self.groups[g as usize].members.len() as u32;
                    self.groups[g as usize].members.push(m);
                }
                for &r in &res_list {
                    self.res_group[r as usize] = g;
                    self.groups[g as usize].resources.push(r);
                }
                // Hand the emptied vecs back to the slot (keeps capacity).
                let go = &mut self.groups[o as usize];
                go.members = mem;
                go.members.clear();
                go.resources = res_list;
                go.resources.clear();
                self.free_groups.push(o);
            }
            g
        };
        self.group_of[fi] = g;
        self.member_pos[fi] = self.groups[g as usize].members.len() as u32;
        self.groups[g as usize].members.push(fi as u32);
        for r in fr.iter() {
            if self.res_group[r] != g {
                self.res_group[r] = g;
                self.groups[g as usize].resources.push(r as u32);
            }
        }
        self.mark_dirty(g);
    }

    /// Retire flow `fi` from its group; an emptied group releases its
    /// resources, a surviving one is re-solved (dirty).
    fn leave(&mut self, fi: usize) {
        let g = self.group_of[fi];
        let pos = self.member_pos[fi] as usize;
        let gr = &mut self.groups[g as usize];
        gr.members.swap_remove(pos);
        if pos < gr.members.len() {
            let moved = gr.members[pos];
            self.member_pos[moved as usize] = pos as u32;
        }
        self.group_of[fi] = u32::MAX;
        if self.groups[g as usize].members.is_empty() {
            let gr = &mut self.groups[g as usize];
            gr.live = false;
            gr.dirty = false;
            let res_list = std::mem::take(&mut gr.resources);
            for &r in &res_list {
                self.res_group[r as usize] = u32::MAX;
            }
            let gr = &mut self.groups[g as usize];
            gr.resources = res_list;
            gr.resources.clear();
            self.free_groups.push(g);
        } else {
            self.mark_dirty(g);
        }
    }

    /// Reset the group arena for a new batch (keeps every allocation).
    fn reset_groups(&mut self, n_compact: usize) {
        self.free_groups.clear();
        for i in (0..self.groups.len()).rev() {
            let g = &mut self.groups[i];
            g.members.clear();
            g.resources.clear();
            g.dirty = false;
            g.live = false;
            self.free_groups.push(i as u32);
        }
        self.dirty.clear();
        self.res_group.clear();
        self.res_group.resize(n_compact, u32::MAX);
    }
}

/// Discrete-event network simulator for one fabric + cluster + transport
/// configuration. Virtual time is `f64` seconds; rank clocks are owned by
/// [`crate::fabric::Comm`], not by the simulator.
pub struct NetSim {
    pub fabric: FabricSpec,
    pub cluster: ClusterSpec,
    pub opts: TransportOptions,
    /// The link graph flows are routed through. Built from
    /// `fabric.topology`; owns the per-link capacity table (the default
    /// spec reproduces the legacy NIC + rack-uplink layout bit-for-bit).
    pub topology: Topology,
    /// Virtual time until which each resource is drained by prior batches.
    busy_until: Vec<f64>,
    /// Scratch per-resource flow counter (zeroed outside `transfer_batch`).
    load: Vec<u32>,
    /// Per-(src, dst) flow sequence numbers feeding the ECMP hash.
    /// Deterministic: only ever read/written for pairs this sim routed,
    /// in submission order, so routes are independent of `--jobs`.
    flow_seq: HashMap<(usize, usize), u64>,
    /// The production max-min solver arena (perf counters inside).
    pub solver: MaxMinScratch,
    /// Worker-local solver arenas for parallel intra-batch group solves
    /// (bottleneck groups are independent by construction). Sized to
    /// `solver_jobs` in [`NetSim::try_new`]; empty means sequential.
    par_solvers: Vec<MaxMinScratch>,
    /// Resolved worker count from [`TransportOptions::solver_threads`]
    /// (0 = one per available core, capped; 1 = sequential).
    solver_jobs: usize,
    fluid: FluidScratch,
    scratch_flows: Vec<NetFlow>,
    scratch_srcs: Vec<usize>,
    scratch_finish: Vec<f64>,
    /// Shared-tenancy cross-traffic generators, one per attributed
    /// tenant id (sorted-by-insertion, ids unique, never 0). Empty (the
    /// default) is the dedicated, silent fabric — bit-for-bit the
    /// pre-tenancy engine. The anonymous single-generator API
    /// ([`NetSim::set_background`]) is tenant id 1.
    tenants: Vec<(usize, crate::fabric::tenancy::BackgroundTraffic)>,
    /// Per-tenant injected traffic: `(tenant id, messages, bytes)` in
    /// first-seen order. The aggregate lives in
    /// [`NetStats::background_messages`]/`background_bytes`; this
    /// breakdown is engine state (not `NetStats`) so the timing-cache
    /// delta plumbing stays untouched — tenant traffic disables that
    /// cache anyway ([`NetSim::timing_cache_usable`]).
    tenant_traffic: Vec<(usize, u64, f64)>,
    scratch_bg: Vec<crate::fabric::tenancy::BgFlow>,
    /// Collective schedule/timing memoization, owned per simulator so
    /// reuse across steps needs no cross-thread sharing (CSV output stays
    /// byte-identical for any `--jobs`). Survives [`NetSim::reset`]: keys
    /// capture all state a cached execution depends on.
    pub schedule_cache: ScheduleCache,
    pub stats: NetStats,
    /// Optional message-level trace (enable with [`NetSim::enable_trace`]).
    pub trace: Option<crate::fabric::trace::Trace>,
    /// Attached fault timeline ([`NetSim::set_faults`]); `None` (the
    /// neutral spec) keeps every batch on the exact pre-fault code path.
    faults: Option<FaultState>,
    /// The failed-flow warning fires once per simulator lifetime, like
    /// the budget warning: per-flow failures are counted in
    /// [`NetStats::failed_flows`], not spammed.
    fault_fail_warned: bool,
}

/// Engine-side fault state: the compiled timeline plus the absolute
/// fault-clock offset of the current step. Batches run in batch-local
/// time; `clock + t` is the position on the fault trace. The clock
/// survives [`NetSim::reset`] (the trainer advances it across steps via
/// [`NetSim::advance_fault_clock`]), so a multi-step run walks the trace
/// instead of replaying its first window.
struct FaultState {
    timeline: FaultTimeline,
    clock: f64,
    /// The spec's signature, cached for [`NetSim::fault_signature`].
    sig: u64,
}

/// Minimum settled-wave size (total members across dirty groups) before
/// an event's group re-solves fan out to the worker pool. Below this the
/// spawn/steal overhead dwarfs the solves; typical steady-state events
/// dirty one small group and stay sequential, while the opening event of
/// a frontier-scale batch (every unit arrives at t=0 across many ToR-
/// local groups) crosses it easily.
const PAR_SOLVE_MIN_MEMBERS: usize = 4096;

fn time_eps(t: f64) -> f64 {
    1e-12 * (1.0 + t.abs())
}

fn byte_eps(bytes: f64) -> f64 {
    1e-12 * (1.0 + bytes)
}

impl NetSim {
    /// Build a simulator, routing through `fabric.topology`. Panics if
    /// the topology spec cannot host the cluster — use
    /// [`NetSim::try_new`] where the config comes from user input.
    pub fn new(fabric: FabricSpec, cluster: ClusterSpec, opts: TransportOptions) -> Self {
        Self::try_new(fabric, cluster, opts).expect("invalid fabric topology for cluster")
    }

    /// Fallible constructor: validates the topology against the cluster.
    pub fn try_new(
        fabric: FabricSpec,
        cluster: ClusterSpec,
        opts: TransportOptions,
    ) -> anyhow::Result<Self> {
        let topology = Topology::build(&fabric.topology, &fabric, &cluster)?;
        let n_res = topology.num_resources();
        // Parallel group solves are bit-identical at any worker count
        // (the wave is settled, solved member-order, and scattered back
        // in deterministic wave order), so auto-sizing from the host is
        // safe for reproducibility; it only moves wall-clock.
        let solver_jobs = match opts.solver_threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16),
            j => j,
        };
        Ok(NetSim {
            fabric,
            cluster,
            opts,
            topology,
            busy_until: vec![0.0; n_res],
            load: vec![0; n_res],
            flow_seq: HashMap::new(),
            solver: MaxMinScratch::new(),
            par_solvers: (0..if solver_jobs > 1 { solver_jobs } else { 0 })
                .map(|_| MaxMinScratch::new())
                .collect(),
            solver_jobs,
            fluid: FluidScratch {
                // The global->compact remap is per-topology: built once
                // here, entries reset sparsely after each batch.
                remap: vec![u32::MAX; n_res],
                ..FluidScratch::default()
            },
            scratch_flows: Vec::new(),
            scratch_srcs: Vec::new(),
            scratch_finish: Vec::new(),
            tenants: Vec::new(),
            tenant_traffic: Vec::new(),
            scratch_bg: Vec::new(),
            schedule_cache: ScheduleCache::new(),
            stats: NetStats::default(),
            trace: None,
            faults: None,
            fault_fail_warned: false,
        })
    }

    /// Attach a compiled fault timeline. A no-op for an inactive spec —
    /// the neutral `faults = none` configuration never attaches, so the
    /// healthy engine stays bit-for-bit the pre-fault engine. The fault
    /// clock starts at 0 and survives [`NetSim::reset`].
    pub fn set_faults(&mut self, spec: &FaultSpec) -> anyhow::Result<()> {
        if !spec.active() {
            self.faults = None;
            return Ok(());
        }
        let timeline = FaultTimeline::compile(spec, &self.topology)?;
        self.faults = Some(FaultState { timeline, clock: 0.0, sig: spec.signature() });
        Ok(())
    }

    /// Detach the fault timeline (back to a healthy fabric).
    pub fn clear_faults(&mut self) {
        self.faults = None;
    }

    /// Is a fault timeline attached?
    pub fn faults_active(&self) -> bool {
        self.faults.is_some()
    }

    /// The attached timeline (collectives consult it for node liveness).
    pub fn fault_timeline(&self) -> Option<&FaultTimeline> {
        self.faults.as_ref().map(|f| &f.timeline)
    }

    /// Absolute fault-trace time of the current step's t=0.
    pub fn fault_clock(&self) -> f64 {
        self.faults.as_ref().map_or(0.0, |f| f.clock)
    }

    /// Advance the fault clock by one step's wall time so the next step
    /// sees the next window of the trace.
    pub fn advance_fault_clock(&mut self, dt: f64) {
        if let Some(f) = self.faults.as_mut() {
            f.clock += dt;
        }
    }

    /// Seconds of the batch-local interval `[a, b]` during which at
    /// least one fault is active — the per-step exposure integrand.
    /// 0 on a healthy fabric.
    pub fn fault_exposure(&self, a: f64, b: f64) -> f64 {
        match self.faults.as_ref() {
            None => 0.0,
            Some(f) => f.timeline.degraded_overlap(f.clock + a, f.clock + b),
        }
    }

    /// Fault configuration hash for schedule-cache world signatures
    /// (0 when no timeline is attached). Folds the current clock too:
    /// leader election and routing depend on *where* in the trace a step
    /// runs, so two steps of one faulted run must never alias.
    pub fn fault_signature(&self) -> u64 {
        match self.faults.as_ref() {
            None => 0,
            Some(f) => crate::util::hash::fnv1a_u64(f.sig, f.clock.to_bits()),
        }
    }

    /// Start recording every delivered message.
    pub fn enable_trace(&mut self) {
        self.trace = Some(crate::fabric::trace::Trace::default());
    }

    /// Attach the anonymous background cross-traffic generator (tenant
    /// id 1), replacing any existing tenant set: its flows are injected
    /// into every subsequent [`NetSim::transfer_batch`] and share the
    /// batch's resources max-min fairly with training flows.
    pub fn set_background(&mut self, bg: crate::fabric::tenancy::BackgroundTraffic) {
        self.tenants.clear();
        self.tenants.push((1, bg));
    }

    /// Attach one *attributed* tenant (a fleet job's traffic). Ids must
    /// be unique, non-zero (0 is the observing job itself), and are
    /// carried through to trace events and the per-tenant counters.
    pub fn add_tenant(&mut self, id: usize, bg: crate::fabric::tenancy::BackgroundTraffic) {
        assert!(id != 0, "tenant id 0 is the observing job");
        assert!(
            self.tenants.iter().all(|(t, _)| *t != id),
            "tenant id {id} already attached"
        );
        self.tenants.push((id, bg));
    }

    /// Back to a dedicated fabric (drops every tenant).
    pub fn clear_background(&mut self) {
        self.tenants.clear();
    }

    /// Is shared-tenancy cross-traffic active?
    pub fn background_active(&self) -> bool {
        !self.tenants.is_empty()
    }

    /// Tenancy configuration hash for schedule-cache world signatures
    /// (0 on a dedicated fabric). Folds every attached tenant's id and
    /// generator signature, so distinct tenant sets hash apart.
    pub fn background_signature(&self) -> u64 {
        if self.tenants.is_empty() {
            return 0;
        }
        let mut h = crate::util::hash::FNV_OFFSET;
        for (id, bg) in &self.tenants {
            h = crate::util::hash::fnv1a_u64(h, *id as u64);
            h = crate::util::hash::fnv1a_u64(h, bg.signature());
        }
        h
    }

    /// Per-tenant injected traffic so far: `(tenant id, messages,
    /// bytes)` in first-seen order. Cleared by [`NetSim::reset`].
    pub fn tenant_traffic(&self) -> &[(usize, u64, f64)] {
        &self.tenant_traffic
    }

    /// Reset occupancy, stats and ECMP flow sequencing between
    /// experiments (keeps specs and the schedule cache — cache keys
    /// capture the clock/occupancy state, so stale hits are impossible).
    /// Background generators advance to their next epoch: virtual time
    /// restarts at zero with a fresh, reproducible realization per step.
    pub fn reset(&mut self) {
        for b in self.busy_until.iter_mut() {
            *b = 0.0;
        }
        self.flow_seq.clear();
        self.stats = NetStats::default();
        self.tenant_traffic.clear();
        for (_, bg) in self.tenants.iter_mut() {
            bg.advance_epoch();
        }
        // The fault clock deliberately survives: the trainer resets the
        // sim every step but advances the clock explicitly
        // ([`NetSim::advance_fault_clock`]) so a run walks the trace.
    }

    /// Drain time of one link (observability: lets tests assert a flow
    /// occupied exactly the links of its route).
    pub fn resource_busy_until(&self, id: usize) -> f64 {
        self.busy_until[id]
    }

    /// Is the solved-timing tier of the schedule cache applicable?
    /// Requires the knob on, no message tracing (a replay records no
    /// events), trivial ECMP (with several spines the per-pair
    /// `flow_seq` counters are engine state a replay would skip), and a
    /// dedicated fabric (the background generators' cursors are engine
    /// state a replay would skip too) and a healthy one (a fault
    /// timeline makes timing depend on the advancing fault clock).
    pub(crate) fn timing_cache_usable(&self) -> bool {
        self.opts.schedule_cache
            && self.trace.is_none()
            && self.topology.n_spines <= 1
            && self.tenants.is_empty()
            && self.faults.is_none()
    }

    /// Snapshot the engine state a captured execution starts from.
    pub(crate) fn engine_snapshot(&self) -> crate::trainer::scheduler::EngineSnapshot {
        crate::trainer::scheduler::EngineSnapshot {
            busy: self.busy_until.clone(),
            stats: self.stats.clone(),
        }
    }

    /// Timing-tier lookup; on a hit, applies the captured engine side
    /// effects (occupancy + stats) and returns the final rank clocks.
    pub(crate) fn timing_cache_lookup(&mut self, config: u64, start: &[f64]) -> Option<Vec<f64>> {
        let NetSim { schedule_cache, busy_until, stats, .. } = self;
        let val = schedule_cache.lookup_timing(
            config,
            start,
            busy_until,
            stats.peak_concurrent_flows,
        )?;
        busy_until.copy_from_slice(&val.busy_after);
        stats.messages += val.d_messages;
        stats.bytes += val.d_bytes;
        stats.inter_node_messages += val.d_inter_node;
        stats.inter_rack_messages += val.d_inter_rack;
        stats.fluid_events += val.d_fluid_events;
        stats.budget_exceeded += val.d_budget;
        stats.agg_units += val.d_agg_units;
        stats.agg_collapsed += val.d_agg_collapsed;
        stats.peak_concurrent_flows = val.peak_after;
        Some(val.t_out.clone())
    }

    /// Store a captured execution into the timing tier.
    pub(crate) fn timing_cache_store(
        &mut self,
        config: u64,
        start: &[f64],
        before: &crate::trainer::scheduler::EngineSnapshot,
        t_out: &[f64],
    ) {
        let NetSim { schedule_cache, busy_until, stats, .. } = self;
        schedule_cache.insert_timing(config, start, before, busy_until, stats, t_out);
    }

    /// Deliver one message; returns (send_release_time, recv_complete_time).
    ///
    /// Equivalent to a one-flow [`NetSim::transfer_batch`]: an uncontended
    /// flow reproduces the closed-form transport cost exactly; occupancy
    /// left by earlier calls delays it.
    pub fn message(
        &mut self,
        src: Endpoint,
        dst: Endpoint,
        bytes: f64,
        ready: f64,
    ) -> (f64, f64) {
        let times = self.transfer_batch(&[FlowReq { src, dst, bytes, ready }]);
        (times[0].send_release, times[0].recv_complete)
    }

    /// Run one communication round: all `reqs` flows are concurrently in
    /// flight and share NIC ports / rack up-links max-min fairly. Returns
    /// per-flow completion times in request order.
    pub fn transfer_batch(&mut self, reqs: &[FlowReq]) -> Vec<FlowTimes> {
        let mut out = vec![FlowTimes::default(); reqs.len()];
        let mut flows = std::mem::take(&mut self.scratch_flows);
        flows.clear();
        for (i, req) in reqs.iter().enumerate() {
            debug_assert!(
                req.ready.is_finite(),
                "FlowReq.ready must be finite (got {}, flow {} -> {})",
                req.ready,
                req.src.node,
                req.dst.node
            );
            debug_assert!(
                req.bytes.is_finite() && req.bytes >= 0.0,
                "FlowReq.bytes must be finite and non-negative (got {})",
                req.bytes
            );
            self.stats.messages += 1;
            self.stats.bytes += req.bytes;

            if req.src.node == req.dst.node {
                // Intra-node path: PCIe P2P or shared memory; no NIC, no
                // shared engine resources (the link is point-to-point).
                let cost = transport::local_message(&self.cluster, req.src.kind, req.bytes);
                let done = req.ready + cost.total(req.bytes);
                out[i] = FlowTimes { send_release: done, recv_complete: done };
                continue;
            }

            if let Some(failed) =
                self.admit_inter_node_flow(&mut flows, i, 0, req.src, req.dst, req.bytes, req.ready)
            {
                out[i] = failed;
            }
        }
        if flows.is_empty() {
            self.scratch_flows = flows;
            return out;
        }

        // Shared tenancy: inject every tenant flow whose arrival falls
        // inside this batch's window. The window closes at the latest
        // *uncontended* finish estimate — deterministic and computable
        // before solving; arrivals in the contention-stretched tail
        // simply join the next batch (their ready times are kept, so
        // nothing is lost). Tenant flows are first-class: they claim
        // their full route and share every link max-min fairly. Tenants
        // draw in attachment order, each from its own generator stream,
        // so multi-tenant realizations stay deterministic.
        if !self.tenants.is_empty() {
            let t_hi =
                flows.iter().map(|f| f.arrival + f.bytes / f.cap).fold(f64::NEG_INFINITY, f64::max);
            let mut tenants = std::mem::take(&mut self.tenants);
            let mut bg_reqs = std::mem::take(&mut self.scratch_bg);
            for (tid, bg) in tenants.iter_mut() {
                bg_reqs.clear();
                bg.flows_until(t_hi, &mut bg_reqs);
                for bf in &bg_reqs {
                    let src = Endpoint { rank: 0, node: bf.src, slot: 0, kind: EndpointKind::Cpu };
                    let dst = Endpoint { rank: 0, node: bf.dst, slot: 0, kind: EndpointKind::Cpu };
                    // A failed background flow has no completion slot;
                    // it is already counted in `failed_flows`.
                    let _ = self.admit_inter_node_flow(
                        &mut flows,
                        BACKGROUND_FLOW,
                        *tid,
                        src,
                        dst,
                        bf.bytes,
                        bf.ready,
                    );
                }
            }
            self.scratch_bg = bg_reqs;
            self.tenants = tenants;
        }

        // Switch-level congestion: concurrent NIC-level flows through the
        // core ~= distinct transmitting nodes in this round (background
        // senders transit the core too and count toward the knee).
        let mut srcs = std::mem::take(&mut self.scratch_srcs);
        srcs.clear();
        srcs.extend(flows.iter().map(|f| f.src_node));
        srcs.sort_unstable();
        srcs.dedup();
        let factor = self.fabric.congestion_factor(srcs.len() as f64);
        self.scratch_srcs = srcs;
        self.stats.peak_concurrent_flows =
            self.stats.peak_concurrent_flows.max(flows.len() as u64);

        // Contention detection: does any resource carry two flows?
        let mut contended = false;
        for f in &flows {
            for id in f.res.iter() {
                self.load[id] += 1;
                if self.load[id] > 1 {
                    contended = true;
                }
            }
        }
        let mut finishes = std::mem::take(&mut self.scratch_finish);
        // An attached fault timeline forces the fluid path: capacity
        // steps must be merged into the event loop even when no two
        // flows share a resource (the closed-form fast path knows
        // nothing about mid-flight capacity changes).
        if contended || self.faults.is_some() {
            self.fluid_finishes(&flows, factor, &mut finishes);
        } else {
            // Fast path: every flow runs at its (congestion-scaled) cap.
            finishes.clear();
            finishes.extend(flows.iter().map(|f| f.arrival + f.bytes / (f.cap * factor)));
        }
        for f in &flows {
            for id in f.res.iter() {
                self.load[id] = 0;
            }
        }

        for (f, &fin) in flows.iter().zip(&finishes) {
            let recv_complete = fin + f.latency + f.recv_overhead;
            if f.req_idx != BACKGROUND_FLOW {
                out[f.req_idx] = FlowTimes { send_release: fin, recv_complete };
            }
            for id in f.res.iter() {
                self.busy_until[id] = self.busy_until[id].max(fin);
            }
            if let Some(trace) = self.trace.as_mut() {
                trace.record(crate::fabric::trace::MessageEvent {
                    src_node: f.src_node,
                    dst_node: f.dst_node,
                    bytes: f.bytes,
                    start: f.arrival,
                    end: recv_complete,
                    inter_rack: f.inter_rack,
                    tenant: f.tenant,
                });
            }
        }
        self.scratch_finish = finishes;
        self.scratch_flows = flows;
        out
    }

    /// Admit one inter-node flow — training or background — into a
    /// batch: draw its ECMP sequence, route it through the topology (the
    /// returned link set replaces the old hard-coded NIC/rack wiring;
    /// with a single spine the hash is trivial, so the counter upkeep is
    /// skipped and the default-topology hot path stays map-free), price
    /// it at the transport layer, floor its arrival by prior occupancy,
    /// and push the [`NetFlow`]. The single admission path is what keeps
    /// tenant and training flows physically identical to the engine;
    /// only stats attribution follows `tenant` (0 = the observing job,
    /// whose flows carry a real `req_idx` completion slot).
    ///
    /// Under an attached fault timeline, a flow whose path is dead at
    /// submission retries on the [`RetryPolicy`] backoff schedule (its
    /// ready time shifts to the first probe at or after the path's
    /// recovery, each probe counted in [`NetStats::retries`]); a flow
    /// whose path outlives the whole retry window is *not* admitted —
    /// the failure is counted in [`NetStats::failed_flows`], warned once
    /// on stderr, and returned as a [`FlowTimes`] at the moment the
    /// transport gave up (`Some` return). Flows that do admit during a
    /// partial spine outage re-hash over the surviving spines
    /// ([`Topology::route_excluding`]); landing on a different spine
    /// than the healthy hash counts in [`NetStats::reroutes`].
    #[allow(clippy::too_many_arguments)]
    fn admit_inter_node_flow(
        &mut self,
        flows: &mut Vec<NetFlow>,
        req_idx: usize,
        tenant: usize,
        src: Endpoint,
        dst: Endpoint,
        bytes: f64,
        ready: f64,
    ) -> Option<FlowTimes> {
        let background = tenant != 0;
        if background {
            self.stats.background_messages += 1;
            self.stats.background_bytes += bytes;
            match self.tenant_traffic.iter_mut().find(|e| e.0 == tenant) {
                Some(e) => {
                    e.1 += 1;
                    e.2 += bytes;
                }
                None => self.tenant_traffic.push((tenant, 1, bytes)),
            }
        } else {
            self.stats.inter_node_messages += 1;
        }
        let seq = if self.topology.n_spines > 1 {
            let e = self.flow_seq.entry((src.node, dst.node)).or_insert(0);
            let s = *e;
            *e += 1;
            s
        } else {
            0
        };
        let mut ready = ready;
        let mut fault_route = None;
        if let Some(fs) = self.faults.as_ref() {
            let tl = &fs.timeline;
            let policy = RetryPolicy::from_opts(&self.opts);
            let dead_at = fs.clock + ready;
            if !tl.path_usable(&self.topology, src.node, dst.node, dead_at) {
                match tl
                    .path_recovery_after(&self.topology, src.node, dst.node, dead_at)
                    .and_then(|rec| policy.first_probe_at(dead_at, rec))
                {
                    Some((k, probe_abs)) => {
                        self.stats.retries += k as u64 + 1;
                        ready = probe_abs - fs.clock;
                    }
                    None => {
                        self.stats.retries += policy.max_retries as u64;
                        self.stats.failed_flows += 1;
                        if !self.fault_fail_warned {
                            self.fault_fail_warned = true;
                            eprintln!(
                                "fabricbench: flow {} -> {} failed (path dead past the \
                                 {}-retry window); failed flows are counted in \
                                 NetStats::failed_flows",
                                src.node, dst.node, policy.max_retries
                            );
                        }
                        let fail_t = ready + policy.total_window();
                        return Some(FlowTimes { send_release: fail_t, recv_complete: fail_t });
                    }
                }
            }
            // Route over the spines surviving at the (possibly shifted)
            // admission time; `path_usable`/recovery guaranteed one.
            let t_abs = fs.clock + ready;
            let (st, dt) = (
                self.topology.tor_of_node(src.node),
                self.topology.tor_of_node(dst.node),
            );
            if st != dt {
                let alive: Vec<bool> = (0..self.topology.n_spines)
                    .map(|s| tl.spine_alive(&self.topology, st, dt, s, t_abs))
                    .collect();
                if alive.iter().any(|&a| !a) {
                    let r = self
                        .topology
                        .route_excluding(src.node, dst.node, seq, &alive)
                        .expect("a surviving spine was guaranteed above");
                    if r.spine != self.topology.route(src.node, dst.node, seq).spine {
                        self.stats.reroutes += 1;
                    }
                    fault_route = Some(r);
                }
            }
        }
        let route =
            fault_route.unwrap_or_else(|| self.topology.route(src.node, dst.node, seq));
        let inter_rack = route.inter_tor;
        if inter_rack && !background {
            self.stats.inter_rack_messages += 1;
        }
        let geo = MessageGeometry {
            bytes,
            inter_rack,
            endpoint: src.kind,
            src_slot: src.slot,
            dst_slot: dst.slot,
        };
        let cost = transport::network_message(&self.fabric, &self.cluster, &self.opts, &geo);
        let mut arrival = ready + cost.send_overhead;
        for id in route.res.iter() {
            arrival = arrival.max(self.busy_until[id]);
        }
        flows.push(NetFlow {
            req_idx,
            tenant,
            src_node: src.node,
            dst_node: dst.node,
            inter_rack,
            arrival,
            bytes,
            cap: cost.bandwidth,
            latency: cost.latency,
            recv_overhead: cost.recv_overhead,
            res: route.res,
            seq,
        });
        None
    }

    /// Event loop over a contended batch: advance virtual time from event
    /// to event (arrival or completion). Flows are first collapsed into
    /// **aggregation units** ([`AggKey`]: same compact route + flow cap +
    /// arrival + bytes; identity mapping when
    /// [`TransportOptions::flow_aggregation`] is off), and the loop runs
    /// over units — a hierarchical-allreduce level that submits thousands
    /// of indistinguishable neighbor transfers costs a handful of units.
    /// Only the bottleneck groups an event touches are re-solved (on the
    /// worker-local solver arenas in parallel when the settled wave is
    /// large enough; bit-identical at any worker count); the next
    /// completion comes from the lazily-invalidated projection heap.
    /// Writes per-flow transfer-finish times into `finish` (same order as
    /// `flows`) by gathering each flow's unit finish — bit-exact
    /// de-aggregation, because unit members are fluid-indistinguishable.
    fn fluid_finishes(&mut self, flows: &[NetFlow], factor: f64, finish: &mut Vec<f64>) {
        let NetSim { fluid, solver, par_solvers, topology, stats, opts, faults, fault_fail_warned, .. } =
            self;
        let n = flows.len();
        // Batch-local time of the first arrival: fault changes at or
        // before it are baked into the initial caps; later ones are
        // merged into the event loop through the `next_fault` cursor.
        let t_start = flows.iter().map(|f| f.arrival).fold(f64::INFINITY, f64::min);
        // Compact the touched resource ids to a dense table through the
        // persistent per-topology remap (built in `try_new`, reset
        // sparsely below) — no sort/binary-search per batch, and a 32k-GPU
        // step never materializes a global link grid: every solve below
        // touches only its bottleneck group's footprint.
        fluid.touched.clear();
        fluid.caps.clear();
        fluid.res.clear();
        fluid.fcaps.clear();
        for flow in flows {
            let mut fr = FlowResources::new();
            for id in flow.res.iter() {
                let mut c = fluid.remap[id];
                if c == u32::MAX {
                    c = fluid.caps.len() as u32;
                    fluid.remap[id] = c;
                    fluid.touched.push(id);
                    let mut cap = topology.caps()[id] * factor;
                    if let Some(fs) = faults.as_ref() {
                        cap *= fs.timeline.mult_at(id, fs.clock + t_start);
                    }
                    fluid.caps.push(cap);
                }
                fr.push(c as usize);
            }
            fluid.res.push(fr);
            fluid.fcaps.push(flow.cap * factor);
        }
        let n_compact = fluid.caps.len();

        // Aggregation pass: first-seen keying keeps unit order a
        // deterministic function of submission order (the map is only
        // probed, never iterated). ECMP multi-spine flows key apart
        // naturally (different spine => different compact route), so no
        // bypass is needed; tracing and per-tenant attribution operate on
        // flows outside this loop and are unaffected.
        fluid.unit_of.clear();
        fluid.u_res.clear();
        fluid.u_fcaps.clear();
        fluid.u_arrival.clear();
        fluid.u_bytes.clear();
        fluid.u_w.clear();
        // Aggregation is disabled under faults: the park/re-route logic
        // below needs unit == flow (a unit's members could otherwise be
        // split by a mid-flight re-route).
        if opts.flow_aggregation && faults.is_none() {
            fluid.agg_map.clear();
            for i in 0..n {
                let key =
                    AggKey::new(fluid.res[i], fluid.fcaps[i], flows[i].arrival, flows[i].bytes);
                let next = fluid.u_fcaps.len() as u32;
                let u = *fluid.agg_map.entry(key).or_insert(next);
                if u == next {
                    fluid.u_res.push(fluid.res[i]);
                    fluid.u_fcaps.push(fluid.fcaps[i]);
                    fluid.u_arrival.push(flows[i].arrival);
                    fluid.u_bytes.push(flows[i].bytes);
                    fluid.u_w.push(1);
                } else {
                    fluid.u_w[u as usize] += 1;
                }
                fluid.unit_of.push(u);
            }
        } else {
            for i in 0..n {
                fluid.u_res.push(fluid.res[i]);
                fluid.u_fcaps.push(fluid.fcaps[i]);
                fluid.u_arrival.push(flows[i].arrival);
                fluid.u_bytes.push(flows[i].bytes);
                fluid.u_w.push(1);
                fluid.unit_of.push(i as u32);
            }
        }
        let m = fluid.u_fcaps.len();
        stats.agg_units += m as u64;
        stats.agg_collapsed += (n - m) as u64;

        {
            let FluidScratch { order, u_arrival, u_bytes, u_finish, rem, t0, rate, active, stamp, group_of, member_pos, heap, .. } =
                &mut *fluid;
            order.clear();
            order.extend(0..m as u32);
            // NaN-safe arrival order: `total_cmp` cannot panic (a NaN
            // arrival is already rejected at `FlowReq` intake by
            // debug_assert).
            order.sort_unstable_by(|&a, &b| {
                u_arrival[a as usize].total_cmp(&u_arrival[b as usize])
            });
            u_finish.clear();
            u_finish.resize(m, 0.0);
            rem.clear();
            rem.extend_from_slice(u_bytes);
            t0.clear();
            t0.resize(m, 0.0);
            rate.clear();
            rate.resize(m, 0.0);
            active.clear();
            active.resize(m, false);
            stamp.clear();
            stamp.resize(m, 0);
            group_of.clear();
            group_of.resize(m, u32::MAX);
            member_pos.clear();
            member_pos.resize(m, 0);
            heap.clear();
        }
        fluid.reset_groups(n_compact);

        // Re-price a (possibly new) route into the batch's compact table
        // at fault-trace time `t_abs`, extending the remap for resources
        // the batch has not touched yet (mid-flight re-routes can claim
        // links no original flow used).
        fn remap_route(
            fluid: &mut FluidScratch,
            topology: &Topology,
            factor: f64,
            tl: &FaultTimeline,
            t_abs: f64,
            route: &crate::fabric::topology::Route,
        ) -> FlowResources {
            let mut fr = FlowResources::new();
            for id in route.res.iter() {
                let mut c = fluid.remap[id];
                if c == u32::MAX {
                    c = fluid.caps.len() as u32;
                    fluid.remap[id] = c;
                    fluid.touched.push(id);
                    fluid.caps.push(topology.caps()[id] * factor * tl.mult_at(id, t_abs));
                    fluid.res_group.push(u32::MAX);
                }
                fr.push(c as usize);
            }
            fr
        }

        // Fault merge state: the next capacity-change instant
        // (batch-local) and the parked units — flows whose path died
        // mid-flight with no surviving spine, waiting on the retry
        // policy's probe schedule: `(unit, batch-local probe time,
        // fails)`. `fails == true` marks the probe as the end of the
        // retry window (the flow fails there). Parked units stay
        // `active` (the loop must not exit under them) but belong to no
        // group and carry rate 0.
        let policy = RetryPolicy::from_opts(opts);
        let mut next_fault: f64 = match faults.as_ref() {
            Some(fs) => fs
                .timeline
                .next_change_after(fs.clock + t_start)
                .map_or(f64::INFINITY, |c| c - fs.clock),
            None => f64::INFINITY,
        };
        let mut parked: Vec<(usize, f64, bool)> = Vec::new();

        let mut ptr = 0usize;
        let mut n_active = 0usize;
        let mut t = fluid.u_arrival[fluid.order[0] as usize];
        // Event budget. The incremental loop terminates in O(units)
        // events by construction: every iteration activates an arrival,
        // retires the heap top (its projection equals the event time, and
        // retirement is matched against event time within `time_eps`), or
        // fail-closes — so unlike the old scan loop it cannot stall when
        // a residual transfer time drops below the fp resolution of `t`
        // (`t + rem/rate == t`; the old loop spun on zero-`dt` events
        // until this budget ran out and *silently* degraded to frozen
        // rates — on random mixed-size batches that happened in ~25% of
        // cases). The budget is therefore pure insurance now, retuned
        // ~5x over the previous `512 + 40e6/(n+64)` since per-event cost
        // dropped about an order of magnitude; if it ever trips, the
        // fallback is deterministic (in-flight units keep their rates,
        // pending ones take their caps), counted in
        // `NetStats::budget_exceeded`, and warned once on stderr so
        // degradation can never be silent again.
        let max_events = fluid.budget_override.unwrap_or(2048 + 200_000_000 / (m + 64));
        let mut events = 0usize;
        loop {
            // Merge fault capacity changes due at t: re-price the
            // touched resources, dirty exactly the groups holding a
            // changed one (the same dirty-tracking arrivals and
            // departures use), and re-route or park the units whose
            // path just died.
            while next_fault <= t + time_eps(t) {
                let fs = faults.as_ref().expect("next_fault is finite only with faults");
                let t_abs = fs.clock + next_fault;
                let mut changed: Vec<u32> = Vec::new();
                for c in 0..fluid.touched.len() {
                    let id = fluid.touched[c];
                    let cap = topology.caps()[id] * factor * fs.timeline.mult_at(id, t_abs);
                    if cap.to_bits() != fluid.caps[c].to_bits() {
                        fluid.caps[c] = cap;
                        changed.push(c as u32);
                    }
                }
                let mut any_dead = false;
                for &c in &changed {
                    if fluid.caps[c as usize] == 0.0 {
                        any_dead = true;
                    }
                    let g = fluid.res_group[c as usize];
                    if g != u32::MAX && fluid.groups[g as usize].live {
                        fluid.mark_dirty(g);
                    }
                }
                if any_dead {
                    for ui in 0..m {
                        if !fluid.active[ui] || fluid.group_of[ui] == u32::MAX {
                            continue;
                        }
                        if !fluid.u_res[ui].iter().any(|c| fluid.caps[c] == 0.0) {
                            continue;
                        }
                        // Aggregation is off under faults: unit == flow.
                        let f = &flows[ui];
                        // Settle progress at the pre-fault rate, then
                        // detach (the unit's group is already dirty via
                        // the dead resource, so survivors re-solve).
                        fluid.rem[ui] -= fluid.rate[ui] * (next_fault - fluid.t0[ui]);
                        fluid.t0[ui] = next_fault;
                        fluid.leave(ui);
                        fluid.rate[ui] = 0.0;
                        fluid.stamp[ui] = fluid.stamp[ui].wrapping_add(1);
                        let (st, dt) =
                            (topology.tor_of_node(f.src_node), topology.tor_of_node(f.dst_node));
                        let nic_ok = fs.timeline.mult_at(topology.tx_id(f.src_node), t_abs) > 0.0
                            && fs.timeline.mult_at(topology.rx_id(f.dst_node), t_abs) > 0.0;
                        let mut rerouted = false;
                        if nic_ok && st != dt {
                            let alive: Vec<bool> = (0..topology.n_spines)
                                .map(|s| fs.timeline.spine_alive(topology, st, dt, s, t_abs))
                                .collect();
                            if let Some(r) =
                                topology.route_excluding(f.src_node, f.dst_node, f.seq, &alive)
                            {
                                fluid.u_res[ui] =
                                    remap_route(fluid, topology, factor, &fs.timeline, t_abs, &r);
                                fluid.join(ui);
                                stats.reroutes += 1;
                                rerouted = true;
                            }
                        }
                        if !rerouted {
                            // No surviving path: park on the retry
                            // policy's probe schedule. Parked units stay
                            // `active` (no group, rate 0) so the loop
                            // cannot exit under them.
                            match fs
                                .timeline
                                .path_recovery_after(topology, f.src_node, f.dst_node, t_abs)
                                .and_then(|rec| policy.first_probe_at(t_abs, rec))
                            {
                                Some((k, probe_abs)) => {
                                    stats.retries += k as u64 + 1;
                                    parked.push((ui, probe_abs - fs.clock, false));
                                }
                                None => {
                                    stats.retries += policy.max_retries as u64;
                                    parked.push((ui, next_fault + policy.total_window(), true));
                                }
                            }
                        }
                    }
                }
                next_fault = fs
                    .timeline
                    .next_change_after(t_abs)
                    .map_or(f64::INFINITY, |c| c - fs.clock);
            }

            // Resume (or fail) parked units whose probe is due.
            let mut pi = 0;
            while pi < parked.len() {
                let (ui, when, fails) = parked[pi];
                if when > t + time_eps(t) {
                    pi += 1;
                    continue;
                }
                parked.swap_remove(pi);
                if fails {
                    fluid.u_finish[ui] = when;
                    fluid.active[ui] = false;
                    n_active -= 1;
                    stats.failed_flows += 1;
                    if !*fault_fail_warned {
                        *fault_fail_warned = true;
                        eprintln!(
                            "fabricbench: in-flight flow {} -> {} failed (path dead past the \
                             {}-retry window); failed flows are counted in \
                             NetStats::failed_flows",
                            flows[ui].src_node, flows[ui].dst_node, policy.max_retries
                        );
                    }
                    continue;
                }
                let fs = faults.as_ref().expect("parked units exist only with faults");
                let f = &flows[ui];
                let t_abs = fs.clock + t;
                if fs.timeline.path_usable(topology, f.src_node, f.dst_node, t_abs) {
                    let (st, dt) =
                        (topology.tor_of_node(f.src_node), topology.tor_of_node(f.dst_node));
                    let route = if st != dt {
                        let alive: Vec<bool> = (0..topology.n_spines)
                            .map(|s| fs.timeline.spine_alive(topology, st, dt, s, t_abs))
                            .collect();
                        topology
                            .route_excluding(f.src_node, f.dst_node, f.seq, &alive)
                            .expect("path_usable guaranteed a surviving spine")
                    } else {
                        topology.route(f.src_node, f.dst_node, f.seq)
                    };
                    fluid.u_res[ui] =
                        remap_route(fluid, topology, factor, &fs.timeline, t_abs, &route);
                    fluid.t0[ui] = t;
                    fluid.join(ui);
                } else {
                    // The path died again before this probe landed:
                    // recompute the schedule from here (retries keep
                    // accruing; termination is guaranteed because every
                    // re-park moves strictly forward and the trace has
                    // finitely many changes).
                    match fs
                        .timeline
                        .path_recovery_after(topology, f.src_node, f.dst_node, t_abs)
                        .and_then(|rec| policy.first_probe_at(t_abs, rec))
                    {
                        Some((k, probe_abs)) => {
                            stats.retries += k as u64 + 1;
                            parked.push((ui, probe_abs - fs.clock, false));
                        }
                        None => {
                            stats.retries += policy.max_retries as u64;
                            parked.push((ui, t + policy.total_window(), true));
                        }
                    }
                }
            }

            // Activate units whose arrival is due (ties within epsilon).
            while ptr < m && fluid.u_arrival[fluid.order[ptr] as usize] <= t + time_eps(t) {
                let ui = fluid.order[ptr] as usize;
                ptr += 1;
                if fluid.rem[ui] <= byte_eps(fluid.u_bytes[ui]) {
                    fluid.u_finish[ui] = fluid.u_arrival[ui]; // zero-byte unit
                } else {
                    fluid.active[ui] = true;
                    n_active += 1;
                    fluid.t0[ui] = t;
                    fluid.join(ui);
                }
            }
            if n_active == 0 {
                if ptr >= m {
                    break;
                }
                // Jump to the next arrival — but never over a pending
                // fault change, or later arrivals would join against
                // stale capacities. (Parked units stay `active`, so
                // reaching here means none are waiting.)
                let a = fluid.u_arrival[fluid.order[ptr] as usize];
                t = if next_fault < a { next_fault } else { a };
                continue;
            }

            // Re-solve only the groups the last events touched. Phase A
            // (sequential): settle their members to `t` and collect the
            // wave. Phase B: recompute max-min rates per group — on the
            // worker pool when the wave is large enough, since bottleneck
            // groups are independent by construction. Phase C
            // (sequential, wave order): scatter rates, bump stamps,
            // refresh completion projections (stale heap entries die by
            // stamp) — so the heap-op sequence is identical at any worker
            // count. Runs before the budget check, like the reference
            // loop, so a budget trip always sees real rates for
            // just-arrived units.
            fluid.wave.clear();
            for di in 0..fluid.dirty.len() {
                let g = fluid.dirty[di] as usize;
                if !fluid.groups[g].live || !fluid.groups[g].dirty {
                    continue;
                }
                fluid.groups[g].dirty = false;
                let m_len = fluid.groups[g].members.len();
                for k in 0..m_len {
                    let ui = fluid.groups[g].members[k] as usize;
                    fluid.rem[ui] -= fluid.rate[ui] * (t - fluid.t0[ui]);
                    fluid.t0[ui] = t;
                }
                fluid.wave.push(g as u32);
            }
            fluid.dirty.clear();

            let wave_members: usize =
                fluid.wave.iter().map(|&g| fluid.groups[g as usize].members.len()).sum();
            if par_solvers.len() > 1
                && fluid.wave.len() > 1
                && wave_members >= PAR_SOLVE_MIN_MEMBERS
            {
                let solved = {
                    let FluidScratch { wave, groups, caps, u_fcaps, u_res, u_w, .. } = &*fluid;
                    crate::util::pool::map_steal_with(
                        par_solvers.len(),
                        par_solvers,
                        wave.len(),
                        |scratch, wi| {
                            let g = wave[wi] as usize;
                            let before = (scratch.solves, scratch.rounds);
                            let rates = scratch
                                .solve_member_order(
                                    caps,
                                    u_fcaps,
                                    u_res,
                                    Some(u_w),
                                    &groups[g].members,
                                )
                                .to_vec();
                            (rates, scratch.solves - before.0, scratch.rounds - before.1)
                        },
                    )
                };
                for (wi, (rates, d_solves, d_rounds)) in solved.into_iter().enumerate() {
                    solver.solves += d_solves;
                    solver.rounds += d_rounds;
                    let g = fluid.wave[wi] as usize;
                    for (k, &mu) in fluid.groups[g].members.iter().enumerate() {
                        let ui = mu as usize;
                        fluid.rate[ui] = rates[k];
                        fluid.stamp[ui] = fluid.stamp[ui].wrapping_add(1);
                        if rates[k] > 0.0 {
                            let key = t + fluid.rem[ui] / rates[k];
                            fluid.heap.push(HeapEntry { key, flow: mu, stamp: fluid.stamp[ui] });
                        }
                    }
                }
            } else {
                for wi in 0..fluid.wave.len() {
                    let g = fluid.wave[wi] as usize;
                    solver.solve_weighted(
                        &fluid.caps,
                        &fluid.u_fcaps,
                        &fluid.u_res,
                        &fluid.u_w,
                        &fluid.groups[g].members,
                        &mut fluid.rate,
                    );
                    let m_len = fluid.groups[g].members.len();
                    for k in 0..m_len {
                        let ui = fluid.groups[g].members[k] as usize;
                        fluid.stamp[ui] = fluid.stamp[ui].wrapping_add(1);
                        if fluid.rate[ui] > 0.0 {
                            let key = t + fluid.rem[ui] / fluid.rate[ui];
                            fluid
                                .heap
                                .push(HeapEntry { key, flow: ui as u32, stamp: fluid.stamp[ui] });
                        }
                    }
                }
            }

            events += 1;
            if events > max_events {
                // Budget exhausted: freeze the current fair allocation.
                stats.budget_exceeded += 1;
                if !fluid.budget_warned {
                    fluid.budget_warned = true;
                    eprintln!(
                        "fabricbench: fluid event budget exceeded ({n} flows / {m} units, \
                         {max_events} events) — batch finished with frozen rates; degraded \
                         batches are counted in NetStats::budget_exceeded"
                    );
                }
                for ui in 0..m {
                    if fluid.active[ui] {
                        let rm = fluid.rem[ui] - fluid.rate[ui] * (t - fluid.t0[ui]);
                        fluid.u_finish[ui] =
                            if fluid.rate[ui] > 0.0 { t + rm / fluid.rate[ui] } else { t };
                    }
                }
                while ptr < m {
                    let ui = fluid.order[ptr] as usize;
                    ptr += 1;
                    fluid.u_finish[ui] = fluid.u_arrival[ui]
                        + fluid.u_bytes[ui] / fluid.u_fcaps[ui].max(f64::MIN_POSITIVE);
                }
                break;
            }

            // Next event: earliest valid projected completion vs. the
            // next arrival.
            while let Some(e) = fluid.heap.peek().copied() {
                if !fluid.active[e.flow as usize] || e.stamp != fluid.stamp[e.flow as usize] {
                    fluid.heap.pop();
                } else {
                    break;
                }
            }
            let mut t_next = fluid.heap.peek().map(|e| e.key).unwrap_or(f64::INFINITY);
            if ptr < m {
                let a = fluid.u_arrival[fluid.order[ptr] as usize];
                if a < t_next {
                    t_next = a;
                }
            }
            if next_fault < t_next {
                t_next = next_fault;
            }
            for &(_, when, _) in &parked {
                if when < t_next {
                    t_next = when;
                }
            }
            if !t_next.is_finite() {
                // Every active unit is rate-0 (zero flow cap) and nothing
                // arrives before them; fail closed.
                for ui in 0..m {
                    if fluid.active[ui] {
                        fluid.u_finish[ui] = t;
                        fluid.active[ui] = false;
                        n_active -= 1;
                        if fluid.group_of[ui] != u32::MAX {
                            fluid.leave(ui);
                        }
                    }
                }
                if ptr >= m {
                    break;
                }
                t = fluid.u_arrival[fluid.order[ptr] as usize];
                continue;
            }
            t = t_next;

            // Retire completions due at t (ties within epsilon finish
            // together, like the reference scan).
            while let Some(e) = fluid.heap.peek().copied() {
                if !fluid.active[e.flow as usize] || e.stamp != fluid.stamp[e.flow as usize] {
                    fluid.heap.pop();
                    continue;
                }
                if e.key <= t + time_eps(t) {
                    fluid.heap.pop();
                    let ui = e.flow as usize;
                    fluid.u_finish[ui] = t;
                    fluid.active[ui] = false;
                    n_active -= 1;
                    fluid.leave(ui);
                } else {
                    break;
                }
            }
            if n_active == 0 && ptr >= m {
                break;
            }
        }
        stats.fluid_events += events as u64;
        // De-aggregate: every member of a unit shares its finish (they
        // are indistinguishable to the fluid model — bit-exact by
        // construction, pinned by `tests/aggregation_properties.rs`).
        finish.clear();
        for i in 0..n {
            finish.push(fluid.u_finish[fluid.unit_of[i] as usize]);
        }
        // Sparse remap reset: the table is clean for the next batch.
        for &id in &fluid.touched {
            fluid.remap[id] = u32::MAX;
        }
    }

    /// One-shot convenience: time for a single message with an idle network.
    pub fn one_way_time(
        &mut self,
        placement: &Placement,
        src: usize,
        dst: usize,
        bytes: f64,
    ) -> f64 {
        self.reset();
        let (_, done) =
            self.message(placement.endpoints[src], placement.endpoints[dst], bytes, 0.0);
        done
    }

    /// Endpoint constructor for tests / microbenches.
    pub fn endpoint(node: usize, slot: usize, kind: EndpointKind) -> Endpoint {
        Endpoint { rank: 0, node, slot, kind }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::FabricKind;
    use crate::fabric::contention::max_min_rates;
    use crate::util::prop;

    fn sim(kind: FabricKind) -> NetSim {
        NetSim::new(fabric(kind), ClusterSpec::txgaia(), TransportOptions::default())
    }

    fn cpu_ep(node: usize) -> Endpoint {
        NetSim::endpoint(node, 0, EndpointKind::Cpu)
    }

    impl NetSim {
        /// The pre-PR4 event loop, kept as an *independent* oracle for
        /// the heap/dirty-group engine: full linear completion scan and a
        /// monolithic re-solve of every active flow at every event — no
        /// heap, no groups, no aggregation, so it shares no machinery
        /// with the code it checks. Two long-standing bugs are fixed
        /// (they made the oracle weaker than the engine, not wrong the
        /// other way): it retired flows only on the byte residual
        /// `remaining <= byte_eps`, so when a residual transfer time
        /// dropped below the fp resolution of `t` (`t + q == t`, i.e.
        /// `dt == 0`) nothing ever retired and the loop burned its whole
        /// hardcoded 50k-event budget before *silently* freezing rates —
        /// on random mixed-size batches that happened in ~25% of trials.
        /// Now each step also retires any flow whose projected completion
        /// `t + remaining/rate` is within `time_eps` of the advanced
        /// event time (the same tie rule the engine's heap uses), which
        /// retires at least the argmin flow every event, so the loop
        /// terminates in O(flows) events; the budget (now the engine's
        /// own formula instead of the hardcoded constant) is pure
        /// insurance, and a trip is counted in
        /// `NetStats::budget_exceeded` instead of vanishing. Returns
        /// `(finish, budget_hit)`.
        fn fluid_finishes_reference(&mut self, flows: &[NetFlow], factor: f64) -> (Vec<f64>, bool) {
            let n = flows.len();
            let mut ids: Vec<usize> = flows.iter().flat_map(|f| f.res.iter()).collect();
            ids.sort_unstable();
            ids.dedup();
            let caps: Vec<f64> =
                ids.iter().map(|&id| self.topology.caps()[id] * factor).collect();
            let res: Vec<FlowResources> = flows
                .iter()
                .map(|f| {
                    let mut fr = FlowResources::new();
                    for id in f.res.iter() {
                        fr.push(ids.binary_search(&id).unwrap());
                    }
                    fr
                })
                .collect();
            let fcaps: Vec<f64> = flows.iter().map(|f| f.cap * factor).collect();

            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| flows[a].arrival.total_cmp(&flows[b].arrival));

            let mut finish = vec![0.0f64; n];
            let mut remaining: Vec<f64> = flows.iter().map(|f| f.bytes).collect();
            let mut active: Vec<usize> = Vec::new();
            let mut ptr = 0usize;
            let mut t = flows[order[0]].arrival;
            // Same insurance formula (and test override hook) as the
            // production loop: the old hardcoded 50k existed only because
            // the scan loop could genuinely stall; with projection
            // retirement it cannot.
            let max_events =
                self.fluid.budget_override.unwrap_or(2048 + 200_000_000 / (n + 64));
            let mut events = 0usize;
            let mut budget_hit = false;
            let mut a_caps: Vec<f64> = Vec::new();
            let mut a_res: Vec<FlowResources> = Vec::new();
            loop {
                while ptr < n && flows[order[ptr]].arrival <= t + time_eps(t) {
                    let fi = order[ptr];
                    ptr += 1;
                    if remaining[fi] <= byte_eps(flows[fi].bytes) {
                        finish[fi] = flows[fi].arrival;
                    } else {
                        active.push(fi);
                    }
                }
                if active.is_empty() {
                    if ptr >= n {
                        break;
                    }
                    t = flows[order[ptr]].arrival;
                    continue;
                }

                a_caps.clear();
                a_res.clear();
                for &fi in &active {
                    a_caps.push(fcaps[fi]);
                    a_res.push(res[fi]);
                }
                let rates = max_min_rates(&caps, &a_caps, &a_res);

                events += 1;
                if events > max_events {
                    budget_hit = true;
                    self.stats.budget_exceeded += 1;
                    for (k, &fi) in active.iter().enumerate() {
                        finish[fi] =
                            if rates[k] > 0.0 { t + remaining[fi] / rates[k] } else { t };
                    }
                    while ptr < n {
                        let fi = order[ptr];
                        ptr += 1;
                        finish[fi] = flows[fi].arrival
                            + flows[fi].bytes / fcaps[fi].max(f64::MIN_POSITIVE);
                    }
                    break;
                }

                let mut t_next = f64::INFINITY;
                for (k, &fi) in active.iter().enumerate() {
                    if rates[k] > 0.0 {
                        t_next = t_next.min(t + remaining[fi] / rates[k]);
                    }
                }
                if ptr < n {
                    t_next = t_next.min(flows[order[ptr]].arrival);
                }
                if !t_next.is_finite() {
                    for &fi in &active {
                        finish[fi] = t;
                    }
                    active.clear();
                    continue;
                }

                let dt = (t_next - t).max(0.0);
                let mut still = Vec::with_capacity(active.len());
                for (k, &fi) in active.iter().enumerate() {
                    // Projection retirement: the flow's completion was
                    // projected at `t + remaining/rate`; when the event
                    // time reaches that projection within `time_eps` (the
                    // same tie rule the engine's heap uses) the flow is
                    // done, even if the byte residual stays positive by a
                    // sub-ulp crumb (`remaining - rate*dt > 0` with
                    // `dt == 0` — the zero-progress stall this oracle
                    // used to spin on). At least the argmin flow retires
                    // every completion event, so the loop terminates in
                    // O(flows) events.
                    let proj =
                        if rates[k] > 0.0 { t + remaining[fi] / rates[k] } else { f64::INFINITY };
                    remaining[fi] -= rates[k] * dt;
                    if remaining[fi] <= byte_eps(flows[fi].bytes)
                        || proj <= t_next + time_eps(t_next)
                    {
                        finish[fi] = t_next;
                    } else {
                        still.push(fi);
                    }
                }
                t = t_next;
                active = still;
                if active.is_empty() && ptr >= n {
                    break;
                }
            }
            (finish, budget_hit)
        }
    }

    #[test]
    fn latency_dominates_small_messages() {
        let mut s = sim(FabricKind::OmniPath100);
        let (_, t) = s.message(cpu_ep(0), cpu_ep(1), 8.0, 0.0);
        assert!(t < 5.0e-6, "small message took {t}");
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 256.0 * 1024.0 * 1024.0;
        let (_, t) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let model = bytes / s.fabric.effective_bandwidth();
        assert!((t - model).abs() / model < 0.05, "t={t} model={model}");
    }

    #[test]
    fn opa_faster_than_ethernet_at_all_sizes() {
        for bytes in [8.0, 1024.0, 65536.0, 16.0 * 1024.0 * 1024.0] {
            let mut e = sim(FabricKind::EthernetRoce25);
            let mut o = sim(FabricKind::OmniPath100);
            let (_, te) = e.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
            let (_, to) = o.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
            assert!(to < te, "bytes={bytes}: opa {to} !< eth {te}");
        }
    }

    #[test]
    fn single_flow_matches_closed_form_exactly() {
        // Event-engine parity: an uncontended flow must land within 1e-9 s
        // of the analytic latency/bandwidth model, for every fabric and a
        // span of sizes crossing the eager/rendezvous threshold.
        for kind in [
            FabricKind::EthernetRoce25,
            FabricKind::EthernetTcp25,
            FabricKind::OmniPath100,
            FabricKind::InfinibandEdr100,
        ] {
            for bytes in [0.0, 8.0, 4096.0, 65536.0, 1e6, 64.0 * 1024.0 * 1024.0] {
                let mut s = sim(kind);
                let (_, t) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
                let geo = MessageGeometry {
                    bytes,
                    inter_rack: false,
                    endpoint: EndpointKind::Cpu,
                    src_slot: 0,
                    dst_slot: 0,
                };
                let cost =
                    transport::network_message(&s.fabric, &s.cluster, &s.opts, &geo);
                let model = cost.total(bytes);
                assert!(
                    (t - model).abs() < 1e-9,
                    "{kind:?} {bytes}B: engine {t} vs model {model}"
                );
            }
        }
    }

    #[test]
    fn nic_occupancy_serializes_fanout() {
        // Node 0 sending to two different nodes: second flow queues on tx.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let (_, t2) = s.message(cpu_ep(0), cpu_ep(2), bytes, 0.0);
        assert!(t2 > t1 * 1.8, "fanout must serialize: t1={t1} t2={t2}");
    }

    #[test]
    fn concurrent_fanout_shares_fairly() {
        // Same fanout submitted as ONE round: the two flows share the tx
        // port max-min fairly, finish together, and take ~2x a lone flow.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, lone) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        s.reset();
        let times = s.transfer_batch(&[
            FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes, ready: 0.0 },
            FlowReq { src: cpu_ep(0), dst: cpu_ep(2), bytes, ready: 0.0 },
        ]);
        let (a, b) = (times[0].recv_complete, times[1].recv_complete);
        assert!((a - b).abs() < 1e-9, "fair sharing must finish together: {a} vs {b}");
        assert!(a > 1.8 * lone && a < 2.2 * lone, "shared {a} vs lone {lone}");
    }

    #[test]
    fn staggered_contention_is_event_accurate() {
        // Flow B arrives halfway through flow A on the same tx port. A
        // runs alone, then both share, then B finishes alone: both take
        // longer than solo, and A finishes first.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, solo) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        s.reset();
        let times = s.transfer_batch(&[
            FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes, ready: 0.0 },
            FlowReq { src: cpu_ep(0), dst: cpu_ep(2), bytes, ready: solo / 2.0 },
        ]);
        let (a, b) = (times[0].recv_complete, times[1].recv_complete);
        assert!(a > solo * 1.2 && a < solo * 1.8, "A shared half its life: {a} vs solo {solo}");
        assert!(b > a, "B arrived later and must finish later: {b} !> {a}");
        // Work conservation: the port moved 2x bytes in total; B cannot
        // finish before the aggregate drain time.
        assert!(b > 1.9 * solo, "aggregate drain violated: {b} vs {solo}");
    }

    #[test]
    fn disjoint_pairs_run_in_parallel() {
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let (_, t2) = s.message(cpu_ep(2), cpu_ep(3), bytes, 0.0);
        assert!((t1 - t2).abs() < 1e-9, "disjoint flows must not interfere");
    }

    #[test]
    fn disjoint_batch_matches_sequential_disjoint() {
        // A round of disjoint pairs must time exactly like each pair alone.
        let mut s = sim(FabricKind::OmniPath100);
        let bytes = 1e6;
        let (_, alone) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        s.reset();
        let times = s.transfer_batch(&[
            FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes, ready: 0.0 },
            FlowReq { src: cpu_ep(2), dst: cpu_ep(3), bytes, ready: 0.0 },
            FlowReq { src: cpu_ep(4), dst: cpu_ep(5), bytes, ready: 0.0 },
        ]);
        for ft in &times {
            assert!((ft.recv_complete - alone).abs() < 1e-12);
        }
    }

    #[test]
    fn rack_uplink_contends_inter_rack_flows() {
        // Many simultaneous flows from rack 0 to rack 1 share the up-link;
        // the same count of intra-rack flows only share distinct NICs.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 16.0 * 1024.0 * 1024.0;
        let n = 16; // 16 * 2.875 GB/s >> 23 GB/s uplink
        let cross: Vec<FlowReq> = (0..n)
            .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(32 + i), bytes, ready: 0.0 })
            .collect();
        let t_cross = s
            .transfer_batch(&cross)
            .iter()
            .map(|f| f.recv_complete)
            .fold(0.0, f64::max);
        s.reset();
        let local: Vec<FlowReq> = (0..n)
            .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(16 + i), bytes, ready: 0.0 })
            .collect();
        let t_local = s
            .transfer_batch(&local)
            .iter()
            .map(|f| f.recv_complete)
            .fold(0.0, f64::max);
        assert!(
            t_cross > 1.5 * t_local,
            "uplink contention missing: cross {t_cross} vs local {t_local}"
        );
    }

    #[test]
    fn intra_node_faster_than_inter_node() {
        let mut s = sim(FabricKind::OmniPath100);
        let gpu0 = NetSim::endpoint(0, 0, EndpointKind::Gpu);
        let gpu1 = NetSim::endpoint(0, 1, EndpointKind::Gpu);
        let gpu2 = NetSim::endpoint(1, 0, EndpointKind::Gpu);
        let bytes = 1024.0 * 1024.0;
        let (_, local) = s.message(gpu0, gpu1, bytes, 0.0);
        s.reset();
        let (_, remote) = s.message(gpu0, gpu2, bytes, 0.0);
        assert!(local < remote);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = sim(FabricKind::OmniPath100);
        s.message(cpu_ep(0), cpu_ep(1), 100.0, 0.0);
        s.message(cpu_ep(0), cpu_ep(40), 100.0, 0.0); // node 40 = rack 1
        let gpu0 = NetSim::endpoint(0, 0, EndpointKind::Gpu);
        let gpu1 = NetSim::endpoint(0, 1, EndpointKind::Gpu);
        s.message(gpu0, gpu1, 100.0, 0.0);
        assert_eq!(s.stats.messages, 3);
        assert_eq!(s.stats.inter_node_messages, 2);
        assert_eq!(s.stats.inter_rack_messages, 1);
        assert_eq!(s.stats.bytes, 300.0);
        assert_eq!(s.stats.peak_concurrent_flows, 1);
        assert_eq!(s.stats.budget_exceeded, 0);
    }

    #[test]
    fn message_time_monotone_in_size() {
        let gen = |r: &mut crate::util::rng::Rng| (r.below(24) as i32, r.below(1_000_000) as f64);
        prop::forall(31, 128, gen, |&(shift, base)| {
            let mut s = sim(FabricKind::EthernetRoce25);
            let b1 = base + 1.0;
            let b2 = b1 * (1.0 + (shift as f64 + 1.0) / 4.0);
            let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), b1, 0.0);
            s.reset();
            let (_, t2) = s.message(cpu_ep(0), cpu_ep(1), b2, 0.0);
            if t2 + 1e-15 < t1 {
                return Err(format!("time not monotone: {b1}B->{t1}s, {b2}B->{t2}s"));
            }
            Ok(())
        });
    }

    #[test]
    fn ready_time_shifts_completion() {
        let mut s = sim(FabricKind::OmniPath100);
        let (_, t0) = s.message(cpu_ep(0), cpu_ep(1), 1000.0, 0.0);
        s.reset();
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), 1000.0, 1.0);
        assert!((t1 - t0 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn oversubscription_throttles_cross_rack_rounds() {
        // 16 symmetric rack0 -> rack1 flows: tightening the leaf->spine
        // taper must never speed the batch up, and 8:1 must be clearly
        // slower than full bisection.
        let bytes = 16.0 * 1024.0 * 1024.0;
        let mut last = 0.0;
        let mut times = Vec::new();
        for ratio in [1.0, 2.0, 4.0, 8.0] {
            let mut f = fabric(FabricKind::EthernetRoce25);
            f.topology.oversubscription = Some(ratio);
            let mut s = NetSim::new(f, ClusterSpec::txgaia(), TransportOptions::default());
            let reqs: Vec<FlowReq> = (0..16)
                .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(32 + i), bytes, ready: 0.0 })
                .collect();
            let t = s
                .transfer_batch(&reqs)
                .iter()
                .map(|ft| ft.recv_complete)
                .fold(0.0, f64::max);
            assert!(t + 1e-12 >= last, "ratio {ratio}: batch sped up ({t} < {last})");
            last = t;
            times.push(t);
        }
        assert!(times[3] > 1.5 * times[0], "8:1 should clearly throttle: {times:?}");
    }

    #[test]
    fn ecmp_routes_are_replayable_after_reset() {
        // Same submission sequence after reset() -> bit-identical times:
        // per-pair flow sequencing restarts and ECMP replays.
        let mut f = fabric(FabricKind::EthernetRoce25);
        f.topology.spines = 4;
        f.topology.oversubscription = Some(4.0);
        let mut s = NetSim::new(f, ClusterSpec::txgaia(), TransportOptions::default());
        let reqs: Vec<FlowReq> = (0..8)
            .map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(40 + i), bytes: 1e6, ready: 0.0 })
            .collect();
        let a: Vec<f64> = s.transfer_batch(&reqs).iter().map(|t| t.recv_complete).collect();
        s.reset();
        let b: Vec<f64> = s.transfer_batch(&reqs).iter().map(|t| t.recv_complete).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "reset did not replay routes");
        }
    }

    #[test]
    fn trace_records_batch_events() {
        let mut s = sim(FabricKind::OmniPath100);
        s.enable_trace();
        s.transfer_batch(&[
            FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes: 1e6, ready: 0.0 },
            FlowReq { src: cpu_ep(0), dst: cpu_ep(40), bytes: 1e6, ready: 0.0 },
        ]);
        let trace = s.trace.as_ref().unwrap();
        assert_eq!(trace.len(), 2);
        assert!(trace.events.iter().any(|e| e.inter_rack));
        assert!(trace.events.iter().all(|e| e.end > e.start));
    }

    // -----------------------------------------------------------------
    // Heap/dirty-group event loop vs. the retained reference scan loop.
    // -----------------------------------------------------------------

    fn random_flows(s: &mut NetSim, rng: &mut crate::util::rng::Rng, n: usize) -> Vec<NetFlow> {
        let mut flows = Vec::with_capacity(n);
        for i in 0..n {
            let src = rng.below(96) as usize;
            let mut dst = rng.below(96) as usize;
            if dst == src {
                dst = (dst + 1) % 96;
            }
            let route = s.topology.route(src, dst, 0);
            let bytes = match rng.below(5) {
                0 => 0.0,
                1 => 4096.0,
                2 => 1e6,
                3 => 16.0 * 1024.0 * 1024.0,
                _ => 64.0 * 1024.0 * 1024.0,
            };
            let arrival = if rng.below(2) == 0 { 0.0 } else { rng.uniform_in(0.0, 2e-2) };
            flows.push(NetFlow {
                req_idx: i,
                tenant: 0,
                src_node: src,
                dst_node: dst,
                inter_rack: route.inter_tor,
                arrival,
                bytes,
                cap: s.fabric.effective_bandwidth() * rng.uniform_in(0.4, 1.0),
                latency: 0.0,
                recv_overhead: 0.0,
                res: route.res,
                seq: 0,
            });
        }
        flows
    }

    #[test]
    fn incremental_event_loop_matches_reference_scan() {
        // The dirty-group + projection-heap loop must agree with the
        // monolithic reference loop to within solver re-association noise
        // (component-local vs. global filling rounds): <= 1e-9 relative.
        // Since the oracle's zero-progress stall was fixed (projection
        // retirement — see `fluid_finishes_reference`), EVERY trial is
        // compared: no skipped/degraded bucket remains, and neither loop
        // may touch its event budget.
        let mut rng = crate::util::rng::Rng::new(0xE7E7);
        for trial in 0..60 {
            let kind = if trial % 2 == 0 {
                FabricKind::EthernetRoce25
            } else {
                FabricKind::OmniPath100
            };
            let mut s = sim(kind);
            let n = [2, 3, 5, 9, 17, 33, 64][trial % 7];
            let flows = random_flows(&mut s, &mut rng, n);
            let (want, oracle_degraded) = s.fluid_finishes_reference(&flows, 1.0);
            assert!(
                !oracle_degraded,
                "trial {trial}: fixed oracle must not stall into its budget"
            );
            let mut got = Vec::new();
            s.fluid_finishes(&flows, 1.0, &mut got);
            assert_eq!(s.stats.budget_exceeded, 0, "neither loop may trip the budget");
            assert!(got.iter().all(|x| x.is_finite()));
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                let denom = a.abs().max(b.abs()).max(1e-12);
                assert!(
                    (a - b).abs() / denom < 1e-9,
                    "trial {trial} flow {i}: reference {a} vs incremental {b}"
                );
            }
        }
    }

    #[test]
    fn reference_scan_budget_trip_is_counted() {
        // Satellite regression: the oracle's budget fallback must be
        // *accounted* in `NetStats::budget_exceeded`, never silent (the
        // pre-fix loop dropped its `budget_hit` on the floor). The fixed
        // loop cannot stall structurally, so drive the fallback through
        // the shared test override hook.
        let mut s = sim(FabricKind::EthernetRoce25);
        let flows = random_flows(&mut s, &mut crate::util::rng::Rng::new(0xB06), 8);
        let (finish, hit) = s.fluid_finishes_reference(&flows, 1.0);
        assert!(!hit, "clean batch must not trip");
        assert_eq!(s.stats.budget_exceeded, 0, "no trip => no count");
        assert!(finish.iter().all(|f| f.is_finite()));
        s.fluid.budget_override = Some(1);
        let (degraded, hit) = s.fluid_finishes_reference(&flows, 1.0);
        assert!(hit, "override must trip the oracle's budget");
        assert_eq!(s.stats.budget_exceeded, 1, "oracle trip must be counted");
        assert!(degraded.iter().all(|f| f.is_finite()), "fallback must stay finite");
    }

    #[test]
    fn heap_entry_ordering_is_total_on_degenerate_keys() {
        // Satellite regression (PR 6 NaN-sort hardening follow-up): the
        // completion heap's ordering is a `total_cmp`-based `Ord`, so
        // NaN / ±0.0 / ±inf keys can never panic or violate strict weak
        // ordering, and NaN projections sink to the END of the reversed
        // (min-first) pop order instead of poisoning the heap.
        let keys = [f64::NAN, 1.0, f64::NEG_INFINITY, 0.0, -0.0, f64::INFINITY, -1.0];
        let mut heap = std::collections::BinaryHeap::new();
        for (i, &k) in keys.iter().enumerate() {
            heap.push(HeapEntry { key: k, flow: i as u32, stamp: 0 });
        }
        let popped: Vec<f64> = std::iter::from_fn(|| heap.pop().map(|e| e.key)).collect();
        let want = [f64::NEG_INFINITY, -1.0, -0.0, 0.0, 1.0, f64::INFINITY, f64::NAN];
        assert_eq!(popped.len(), want.len());
        for (i, (a, b)) in popped.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "pop {i}: got {a}, want {b}");
        }
        // Ord/PartialEq consistency on the degenerate keys (what a
        // hand-written partial_cmp got wrong historically).
        let nan = HeapEntry { key: f64::NAN, flow: 0, stamp: 0 };
        assert_eq!(nan.cmp(&nan), Ordering::Equal);
        assert!(nan == nan);
        let z = HeapEntry { key: 0.0, flow: 0, stamp: 0 };
        let nz = HeapEntry { key: -0.0, flow: 0, stamp: 0 };
        assert_ne!(z.cmp(&nz), Ordering::Equal, "total order separates ±0.0");
    }

    #[test]
    fn aggregation_is_bit_exact_and_counts_units() {
        // One mixed batch: 8 identical same-route flows (one unit), a
        // singleton sharing their tx port, 3 identical flows on a
        // disjoint pair, and 2 staggered-ready copies of the first route
        // (distinct arrival => distinct unit). Aggregation on vs off must
        // be bit-identical per flow — the weighted solve gives each
        // member exactly its individual rate — with identical event/solve
        // counts, while the unit counters record the collapse.
        let bytes = 8.0 * 1024.0 * 1024.0;
        let mut reqs: Vec<FlowReq> =
            (0..8).map(|_| FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes, ready: 0.0 }).collect();
        reqs.push(FlowReq { src: cpu_ep(0), dst: cpu_ep(2), bytes: bytes / 2.0, ready: 0.0 });
        reqs.extend((0..3).map(|_| FlowReq { src: cpu_ep(5), dst: cpu_ep(6), bytes, ready: 0.0 }));
        reqs.extend(
            (0..2).map(|_| FlowReq { src: cpu_ep(0), dst: cpu_ep(1), bytes, ready: 1e-3 }),
        );

        let mut on = sim(FabricKind::EthernetRoce25);
        assert!(on.opts.flow_aggregation, "aggregation must default on");
        let got_on: Vec<u64> =
            on.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();

        let mut o = TransportOptions::default();
        o.flow_aggregation = false;
        let mut off =
            NetSim::new(fabric(FabricKind::EthernetRoce25), ClusterSpec::txgaia(), o);
        let got_off: Vec<u64> =
            off.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();

        assert_eq!(got_on, got_off, "aggregated vs unaggregated timing must be bit-exact");
        // 14 flows collapse to 4 units: {0->1 @0}, {0->2}, {5->6}, {0->1 @1ms}.
        assert_eq!(on.stats.agg_units, 4);
        assert_eq!(on.stats.agg_collapsed, 10);
        assert_eq!(off.stats.agg_units, 14, "identity mapping when off");
        assert_eq!(off.stats.agg_collapsed, 0);
        // The unit loop replays the same events and group solves the
        // expanded loop would (members of a unit activate/retire
        // together), so the perf counters cannot drift apart.
        assert_eq!(on.stats.fluid_events, off.stats.fluid_events);
        assert_eq!(on.solver.solves, off.solver.solves);
        assert_eq!(on.solver.rounds, off.solver.rounds);
        assert_eq!(on.stats.budget_exceeded, 0);
        assert_eq!(off.stats.budget_exceeded, 0);
    }

    #[test]
    fn incremental_loop_is_repeatable_and_scratch_clean() {
        // Running the same contended batch twice through one sim (reset
        // between) must be bit-identical: the arenas leak no state.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 8.0 * 1024.0 * 1024.0;
        let reqs: Vec<FlowReq> = (0..24)
            .map(|i| FlowReq {
                src: cpu_ep(i % 8),
                dst: cpu_ep(32 + (i % 16)),
                bytes: bytes * (1.0 + (i % 3) as f64),
                ready: 1e-4 * (i % 5) as f64,
            })
            .collect();
        let a: Vec<u64> =
            s.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        s.reset();
        let b: Vec<u64> =
            s.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        assert_eq!(a, b);
        assert!(s.stats.fluid_events > 0, "contended batch must run the event loop");
    }

    #[test]
    fn disjoint_groups_do_not_resolve_each_other() {
        // Two disjoint contended pairs in one batch: each pair shares a tx
        // port (contended), but the pairs never interact — the dirty-group
        // engine must time each exactly like the pair alone in its own
        // batch.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 32.0 * 1024.0 * 1024.0;
        let pair = |src: usize, d1: usize, d2: usize| {
            [
                FlowReq { src: cpu_ep(src), dst: cpu_ep(d1), bytes, ready: 0.0 },
                FlowReq { src: cpu_ep(src), dst: cpu_ep(d2), bytes: bytes / 2.0, ready: 0.0 },
            ]
        };
        let alone: Vec<u64> = s
            .transfer_batch(&pair(0, 1, 2))
            .iter()
            .map(|t| t.recv_complete.to_bits())
            .collect();
        s.reset();
        let mut reqs = pair(0, 1, 2).to_vec();
        reqs.extend(pair(8, 9, 10));
        reqs.extend(pair(16, 17, 18));
        let merged: Vec<u64> =
            s.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        assert_eq!(&merged[..2], &alone[..], "disjoint group timing changed in a merged batch");
    }

    // -----------------------------------------------------------------
    // Shared-tenancy cross-traffic (fabric::tenancy) at the engine level.
    // -----------------------------------------------------------------

    fn background(
        load: f64,
        sim: &NetSim,
        run_seed: u64,
    ) -> crate::fabric::tenancy::BackgroundTraffic {
        crate::fabric::tenancy::BackgroundTraffic::new(
            &crate::config::TenancySpec::neighbor_incast(load),
            &sim.fabric,
            &sim.cluster,
            run_seed,
        )
        .unwrap()
    }

    /// Training-side traffic that receives on the default incast's
    /// destination nodes (0..8), so the tenant genuinely shares NIC rx
    /// ports with it. Large payloads keep the injection window tens of
    /// milliseconds wide — dozens of tenant arrivals at any tested load.
    fn incast_victim_batch() -> Vec<FlowReq> {
        let bytes = 64.0 * 1024.0 * 1024.0;
        (0..8).map(|i| FlowReq { src: cpu_ep(8 + i), dst: cpu_ep(i), bytes, ready: 0.0 }).collect()
    }

    #[test]
    fn background_traffic_slows_contended_training_flows() {
        let reqs = incast_victim_batch();
        let mut quiet = sim(FabricKind::EthernetRoce25);
        let t_quiet =
            quiet.transfer_batch(&reqs).iter().map(|t| t.recv_complete).fold(0.0, f64::max);
        let mut shared = sim(FabricKind::EthernetRoce25);
        let bg = background(0.6, &shared, 7);
        shared.set_background(bg);
        let t_shared =
            shared.transfer_batch(&reqs).iter().map(|t| t.recv_complete).fold(0.0, f64::max);
        assert!(shared.stats.background_messages > 0, "tenant must have injected flows");
        assert!(shared.stats.background_bytes > 0.0);
        assert!(
            t_shared > t_quiet,
            "shared NIC rx ports must slow the batch: {t_shared} !> {t_quiet}"
        );
        // Attribution split: training counters are identical either way.
        assert_eq!(shared.stats.messages, quiet.stats.messages);
        assert_eq!(shared.stats.bytes.to_bits(), quiet.stats.bytes.to_bits());
        assert_eq!(shared.stats.inter_node_messages, quiet.stats.inter_node_messages);
    }

    #[test]
    fn background_is_deterministic_per_seed_and_epoch() {
        let reqs = incast_victim_batch();
        let run = |seed: u64| -> Vec<u64> {
            let mut s = sim(FabricKind::EthernetRoce25);
            let bg = background(0.5, &s, seed);
            s.set_background(bg);
            let first: Vec<u64> =
                s.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
            s.reset(); // epoch advance: a fresh realization
            let second: Vec<u64> =
                s.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
            first.into_iter().chain(second).collect()
        };
        assert_eq!(run(3), run(3), "same seed must replay both epochs bit-identically");
        assert_ne!(run(3), run(4), "the tenancy seed must matter");
    }

    #[test]
    fn zero_pressure_batches_see_no_background_resources() {
        // A dedicated sim and a shared sim whose tenant never touches the
        // batch's links (disjoint racks, far-away sets) time identically:
        // background flows are just flows, they steal nothing they don't
        // share. (The congestion knee needs >160 senders to bite.)
        let reqs: Vec<FlowReq> = (0..4)
            .map(|i| FlowReq {
                src: cpu_ep(128 + i),
                dst: cpu_ep(160 + i),
                bytes: 64.0 * 1024.0 * 1024.0,
                ready: 0.0,
            })
            .collect();
        let mut quiet = sim(FabricKind::EthernetRoce25);
        let want: Vec<u64> =
            quiet.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        let mut shared = sim(FabricKind::EthernetRoce25);
        let bg = background(0.4, &shared, 1);
        shared.set_background(bg);
        let got: Vec<u64> =
            shared.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        assert!(shared.stats.background_messages > 0);
        assert_eq!(want, got, "a tenant on disjoint links must not move training times");
    }

    #[test]
    fn background_gates_timing_cache() {
        let mut s = sim(FabricKind::EthernetRoce25);
        assert!(s.timing_cache_usable());
        let bg = background(0.2, &s, 1);
        s.set_background(bg);
        assert!(!s.timing_cache_usable(), "generator cursor is uncaptured engine state");
        assert_ne!(s.background_signature(), 0);
        s.clear_background();
        assert!(s.timing_cache_usable());
        assert_eq!(s.background_signature(), 0);
    }

    #[test]
    fn attributed_tenants_split_counters_and_trace() {
        // Two attributed tenants (ids 7 and 9) with different seeds: the
        // aggregate background counters must equal the per-tenant sums,
        // and trace events must carry the owning tenant id.
        let reqs = incast_victim_batch();
        let mut s = sim(FabricKind::EthernetRoce25);
        s.enable_trace();
        s.add_tenant(7, background(0.5, &s, 3));
        s.add_tenant(9, background(0.3, &s, 4));
        assert!(s.background_active());
        assert!(!s.timing_cache_usable());
        s.transfer_batch(&reqs);
        let per: Vec<(usize, u64, f64)> = s.tenant_traffic().to_vec();
        assert_eq!(per.len(), 2, "both tenants must inject in a 60ms window");
        assert!(per.iter().any(|e| e.0 == 7) && per.iter().any(|e| e.0 == 9));
        let (msgs, bytes) = per.iter().fold((0u64, 0.0), |a, e| (a.0 + e.1, a.1 + e.2));
        assert_eq!(msgs, s.stats.background_messages);
        assert_eq!(bytes.to_bits(), s.stats.background_bytes.to_bits());
        let trace = s.trace.as_ref().unwrap();
        let by_tenant = trace.bytes_by_tenant();
        assert_eq!(by_tenant.len(), 3, "tenants 0, 7, 9: {by_tenant:?}");
        assert_eq!(by_tenant[0].0, 0);
        assert_eq!(by_tenant[1], (7, per.iter().find(|e| e.0 == 7).unwrap().2));
        assert_eq!(by_tenant[2], (9, per.iter().find(|e| e.0 == 9).unwrap().2));
        // reset() clears the per-tenant counters with the aggregates.
        s.reset();
        assert!(s.tenant_traffic().is_empty());
    }

    #[test]
    fn attributed_tenant_set_hashes_apart_from_anonymous() {
        let mut a = sim(FabricKind::EthernetRoce25);
        let mut b = sim(FabricKind::EthernetRoce25);
        let bg = background(0.4, &a, 1);
        a.set_background(bg.clone());
        b.add_tenant(2, bg);
        assert_ne!(a.background_signature(), 0);
        assert_ne!(b.background_signature(), 0);
        assert_ne!(
            a.background_signature(),
            b.background_signature(),
            "tenant ids are part of the world signature"
        );
    }

    #[test]
    fn event_budget_fallback_counts_and_stays_finite() {
        // The incremental loop terminates in O(flows) events, so the
        // normal budget can never trip on this batch...
        let reqs: Vec<FlowReq> = (0..64)
            .map(|i| FlowReq {
                src: cpu_ep(i % 16),
                dst: cpu_ep(32 + i % 8),
                bytes: 1e6 * (1.0 + i as f64),
                ready: 1e-5 * i as f64,
            })
            .collect();
        let mut s = sim(FabricKind::EthernetRoce25);
        let exact = s.transfer_batch(&reqs);
        assert!(exact.iter().all(|t| t.recv_complete.is_finite()));
        assert_eq!(s.stats.budget_exceeded, 0, "64-flow batch must fit the event budget");

        // ...so drive the frozen-rate fallback through the test hook: a
        // budget of 1 trips after the first event with real rates (dirty
        // groups are solved before the budget check).
        let mut d = sim(FabricKind::EthernetRoce25);
        d.fluid.budget_override = Some(1);
        let degraded = d.transfer_batch(&reqs);
        assert!(d.stats.budget_exceeded >= 1, "override must trip the budget");
        for (i, (req, ft)) in reqs.iter().zip(&degraded).enumerate() {
            assert!(ft.recv_complete.is_finite(), "flow {i} not finite under fallback");
            assert!(
                ft.recv_complete > req.ready,
                "flow {i} finished before it was ready under fallback"
            );
        }
        // Degradation slows flows down (frozen shared rates / cap fills),
        // it never teleports the batch ahead of the exact engine's start.
        let exact_last = exact.iter().map(|t| t.recv_complete).fold(0.0, f64::max);
        let degr_last = degraded.iter().map(|t| t.recv_complete).fold(0.0, f64::max);
        assert!(degr_last > 0.1 * exact_last, "fallback times implausibly small");

        // The warning fires once per sim lifetime, surviving reset():
        // the counter resets, the warned flag does not.
        d.reset();
        d.transfer_batch(&reqs);
        assert!(d.stats.budget_exceeded >= 1);
        assert!(d.fluid.budget_warned);
    }

    // -----------------------------------------------------------------
    // Fault injection (fabric::faults) at the engine level.
    // -----------------------------------------------------------------

    use crate::fabric::faults::{FaultEvent, FaultSpec, FaultTarget};

    fn spined_sim(spines: usize, over: f64) -> NetSim {
        let mut f = fabric(FabricKind::EthernetRoce25);
        f.topology.spines = spines;
        f.topology.oversubscription = Some(over);
        NetSim::new(f, ClusterSpec::txgaia(), TransportOptions::default())
    }

    fn cross_rack_reqs(n: usize, bytes: f64) -> Vec<FlowReq> {
        (0..n).map(|i| FlowReq { src: cpu_ep(i), dst: cpu_ep(40 + i), bytes, ready: 0.0 }).collect()
    }

    #[test]
    fn neutral_fault_spec_is_bit_identical() {
        // `faults = none` must leave the engine on the exact pre-fault
        // code path: attaching the default (inactive) spec is a no-op.
        let reqs = cross_rack_reqs(12, 8.0 * 1024.0 * 1024.0);
        let mut a = spined_sim(4, 4.0);
        let mut b = spined_sim(4, 4.0);
        b.set_faults(&FaultSpec::default()).unwrap();
        assert!(!b.faults_active(), "default spec must not attach a timeline");
        let ta: Vec<u64> = a.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        let tb: Vec<u64> = b.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        assert_eq!(ta, tb);
        assert_eq!(b.stats.retries + b.stats.reroutes + b.stats.failed_flows, 0);
    }

    #[test]
    fn mid_batch_spine_down_reroutes_and_slows() {
        // A spine dying mid-batch on a 4-spine fat-tree: flows crossing
        // it re-route over the survivors (counted), nothing fails, and
        // the batch finishes no earlier than the healthy run.
        let bytes = 32.0 * 1024.0 * 1024.0;
        let reqs = cross_rack_reqs(16, bytes);
        let mut healthy = spined_sim(4, 4.0);
        let ht: Vec<f64> =
            healthy.transfer_batch(&reqs).iter().map(|t| t.recv_complete).collect();
        let h_last = ht.iter().fold(0.0f64, |a, &b| a.max(b));

        let mut faulted = spined_sim(4, 4.0);
        // Down from mid-batch until well past the healthy finish.
        faulted.set_faults(&FaultSpec::spine_down(0, h_last * 0.25, h_last * 4.0)).unwrap();
        let ft: Vec<f64> =
            faulted.transfer_batch(&reqs).iter().map(|t| t.recv_complete).collect();
        let f_last = ft.iter().fold(0.0f64, |a, &b| a.max(b));
        assert_eq!(faulted.stats.failed_flows, 0, "ECMP survivors must absorb the flows");
        assert!(faulted.stats.reroutes > 0, "some flow must have crossed the dead spine");
        assert!(
            f_last > h_last * (1.0 + 1e-9),
            "losing 1/4 of the bisection mid-batch must slow the batch: {f_last} vs {h_last}"
        );
        for t in &ft {
            assert!(t.is_finite() && *t > 0.0);
        }
    }

    #[test]
    fn faulted_batches_are_deterministic() {
        // Same spec + same submissions -> bitwise-equal times, and
        // reset() replays (the fault clock is untouched by batches).
        let reqs = cross_rack_reqs(16, 16.0 * 1024.0 * 1024.0);
        let spec = FaultSpec::random(40.0, 0xDEAD);
        let mut a = spined_sim(4, 4.0);
        a.set_faults(&spec).unwrap();
        let ta: Vec<u64> = a.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        a.reset();
        let tb: Vec<u64> = a.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        let mut c = spined_sim(4, 4.0);
        c.set_faults(&spec).unwrap();
        let tc: Vec<u64> = c.transfer_batch(&reqs).iter().map(|t| t.recv_complete.to_bits()).collect();
        assert_eq!(ta, tb, "reset() must replay the faulted batch");
        assert_eq!(ta, tc, "a fresh sim with the same spec must agree");
    }

    #[test]
    fn nic_down_parks_and_retries_within_window() {
        // The destination NIC is down at submission and repairs inside
        // the retry window: the flow is admitted at the first probe at
        // or after the repair (retries counted), and completes.
        let mut s = sim(FabricKind::EthernetRoce25);
        let down = FaultSpec {
            events: vec![FaultEvent {
                target: FaultTarget::Nic(1),
                at: 0.0,
                duration: 0.0035,
                factor: 0.0,
            }],
            ..FaultSpec::default()
        };
        s.set_faults(&down).unwrap();
        let (_, healthy_done) = {
            let mut h = sim(FabricKind::EthernetRoce25);
            h.message(cpu_ep(0), cpu_ep(1), 1e6, 0.0)
        };
        let (_, done) = s.message(cpu_ep(0), cpu_ep(1), 1e6, 0.0);
        assert!(s.stats.retries > 0, "a down NIC at submission must cost probes");
        assert_eq!(s.stats.failed_flows, 0);
        // Default policy: probes at 1,3,7,15 ms...; repair at 3.5 ms ->
        // first usable probe is 7 ms.
        assert!(
            done >= 0.007 && done < 0.007 + 2.0 * healthy_done + 1e-3,
            "flow should start at the 7 ms probe: done={done}"
        );
    }

    #[test]
    fn nic_down_past_retry_window_fails_loudly_in_stats() {
        // A NIC dead longer than the whole retry window: the flow fails,
        // is counted, and returns a finite give-up time.
        let mut s = sim(FabricKind::EthernetRoce25);
        let down = FaultSpec {
            events: vec![FaultEvent {
                target: FaultTarget::Nic(1),
                at: 0.0,
                duration: 1e6,
                factor: 0.0,
            }],
            ..FaultSpec::default()
        };
        s.set_faults(&down).unwrap();
        let (_, done) = s.message(cpu_ep(0), cpu_ep(1), 1e6, 0.0);
        assert_eq!(s.stats.failed_flows, 1);
        assert!(done.is_finite());
        // Give-up time is the end of the retry window (~1.023 s under
        // the defaults), not an arbitrary sentinel.
        assert!(done > 0.5 && done < 2.0, "give-up time should be ~1 s: {done}");
    }

    #[test]
    fn brownout_severity_is_monotone() {
        // Deeper brownouts (smaller surviving factor) on every uplink
        // can only slow a cross-rack batch down.
        let bytes = 16.0 * 1024.0 * 1024.0;
        let reqs = cross_rack_reqs(8, bytes);
        let mut last = 0.0f64;
        for &factor in &[1.0, 0.5, 0.25, 0.1] {
            let mut s = spined_sim(1, 4.0);
            if factor < 1.0 {
                let mut events = Vec::new();
                for tor in 0..s.topology.n_tors {
                    events.push(FaultEvent {
                        target: FaultTarget::Link { tor, spine: 0 },
                        at: 0.0,
                        duration: 1e6,
                        factor,
                    });
                }
                s.set_faults(&FaultSpec { events, ..FaultSpec::default() }).unwrap();
            }
            let t = s
                .transfer_batch(&reqs)
                .iter()
                .map(|ft| ft.recv_complete)
                .fold(0.0, f64::max);
            assert!(
                t + 1e-12 >= last,
                "factor {factor}: brownout sped the batch up ({t} < {last})"
            );
            last = t;
        }
    }
}
