//! The fabric simulator: virtual-time message delivery with per-node NIC
//! occupancy. This is the object every collective and the CFD halo
//! exchange talk to.

use crate::cluster::{Endpoint, EndpointKind, Placement};
use crate::config::{ClusterSpec, FabricSpec, TransportOptions};
use crate::fabric::contention::Resource;
use crate::fabric::transport::{self, MessageGeometry};

/// Aggregate statistics for a simulation run.
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    pub messages: u64,
    pub bytes: f64,
    pub inter_node_messages: u64,
    pub inter_rack_messages: u64,
}

/// Flow-level network simulator for one fabric + cluster + transport
/// configuration. Virtual time is `f64` seconds; rank clocks are owned by
/// [`crate::fabric::Comm`], not by the simulator.
pub struct NetSim {
    pub fabric: FabricSpec,
    pub cluster: ClusterSpec,
    pub opts: TransportOptions,
    /// Per-node NIC transmit/receive occupancy (full duplex: separate
    /// resources). Indexed by node id; grown on demand.
    nic_tx: Vec<Resource>,
    nic_rx: Vec<Resource>,
    /// Estimate of simultaneously active flows through the core switch,
    /// set by the collective layer (e.g. ring => one flow per node).
    active_flows: f64,
    pub stats: NetStats,
    /// Optional message-level trace (enable with [`NetSim::enable_trace`]).
    pub trace: Option<crate::fabric::trace::Trace>,
}

impl NetSim {
    pub fn new(fabric: FabricSpec, cluster: ClusterSpec, opts: TransportOptions) -> Self {
        let nodes = cluster.nodes;
        NetSim {
            fabric,
            cluster,
            opts,
            nic_tx: (0..nodes).map(|_| Resource::new(1.0)).collect(),
            nic_rx: (0..nodes).map(|_| Resource::new(1.0)).collect(),
            active_flows: 1.0,
            stats: NetStats::default(),
            trace: None,
        }
    }

    /// Start recording every delivered message.
    pub fn enable_trace(&mut self) {
        self.trace = Some(crate::fabric::trace::Trace::default());
    }

    /// Reset occupancy and stats between experiments (keeps specs).
    pub fn reset(&mut self) {
        for r in self.nic_tx.iter_mut().chain(self.nic_rx.iter_mut()) {
            r.reset();
        }
        self.stats = NetStats::default();
        self.active_flows = 1.0;
    }

    /// Tell the congestion model how many flows are concurrently active.
    pub fn set_active_flows(&mut self, flows: f64) {
        self.active_flows = flows.max(1.0);
    }

    /// Deliver one message; returns (send_release_time, recv_complete_time).
    ///
    /// `ready` is when the payload is available on the sender. The sender
    /// may continue at `send_release_time` (after overhead + NIC
    /// serialization); the receiver owns the data at `recv_complete_time`.
    pub fn message(
        &mut self,
        src: Endpoint,
        dst: Endpoint,
        bytes: f64,
        ready: f64,
    ) -> (f64, f64) {
        self.stats.messages += 1;
        self.stats.bytes += bytes;

        if src.node == dst.node {
            // Intra-node path: PCIe P2P or shared memory; no NIC.
            let cost = transport::local_message(&self.cluster, src.kind, bytes);
            let done = ready + cost.total(bytes);
            return (done, done);
        }

        self.stats.inter_node_messages += 1;
        let inter_rack = self.cluster.rack_of_node(src.node) != self.cluster.rack_of_node(dst.node);
        if inter_rack {
            self.stats.inter_rack_messages += 1;
        }
        let geo = MessageGeometry {
            bytes,
            inter_rack,
            endpoint: src.kind,
            src_slot: src.slot,
            dst_slot: dst.slot,
            active_flows: self.active_flows,
        };
        let cost = transport::network_message(&self.fabric, &self.cluster, &self.opts, &geo);

        // Sender-side: software overhead, then NIC tx serialization.
        let tx_ready = ready + cost.send_overhead;
        let ser_bytes = bytes; // wire bytes ~= payload (headers negligible at MiB scale)
        let tx = &mut self.nic_tx[src.node];
        tx.bandwidth = cost.bandwidth;
        let (tx_start, tx_ser) = tx.reserve(tx_ready, ser_bytes);

        // Receive side: the payload lands after wire latency; rx port must
        // also be free for the serialization window.
        let rx = &mut self.nic_rx[dst.node];
        rx.bandwidth = cost.bandwidth;
        let (rx_start, rx_ser) = rx.reserve(tx_start + cost.latency, ser_bytes);

        let send_release = tx_start + tx_ser;
        let recv_complete = rx_start + rx_ser + cost.recv_overhead;
        if let Some(trace) = self.trace.as_mut() {
            trace.record(crate::fabric::trace::MessageEvent {
                src_node: src.node,
                dst_node: dst.node,
                bytes,
                start: tx_start,
                end: recv_complete,
                inter_rack,
            });
        }
        (send_release, recv_complete)
    }

    /// One-shot convenience: time for a single message with an idle network.
    pub fn one_way_time(&mut self, placement: &Placement, src: usize, dst: usize, bytes: f64) -> f64 {
        self.reset();
        let (_, done) = self.message(placement.endpoints[src], placement.endpoints[dst], bytes, 0.0);
        done
    }

    /// Endpoint constructor for tests / microbenches.
    pub fn endpoint(node: usize, slot: usize, kind: EndpointKind) -> Endpoint {
        Endpoint { rank: 0, node, slot, kind }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::FabricKind;
    use crate::util::prop;

    fn sim(kind: FabricKind) -> NetSim {
        NetSim::new(fabric(kind), ClusterSpec::txgaia(), TransportOptions::default())
    }

    fn cpu_ep(node: usize) -> Endpoint {
        NetSim::endpoint(node, 0, EndpointKind::Cpu)
    }

    #[test]
    fn latency_dominates_small_messages() {
        let mut s = sim(FabricKind::OmniPath100);
        let (_, t) = s.message(cpu_ep(0), cpu_ep(1), 8.0, 0.0);
        assert!(t < 5.0e-6, "small message took {t}");
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 256.0 * 1024.0 * 1024.0;
        let (_, t) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let model = bytes / s.fabric.effective_bandwidth();
        assert!((t - model).abs() / model < 0.05, "t={t} model={model}");
    }

    #[test]
    fn opa_faster_than_ethernet_at_all_sizes() {
        for bytes in [8.0, 1024.0, 65536.0, 16.0 * 1024.0 * 1024.0] {
            let mut e = sim(FabricKind::EthernetRoce25);
            let mut o = sim(FabricKind::OmniPath100);
            let (_, te) = e.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
            let (_, to) = o.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
            assert!(to < te, "bytes={bytes}: opa {to} !< eth {te}");
        }
    }

    #[test]
    fn nic_occupancy_serializes_fanout() {
        // Node 0 sending to two different nodes: second flow queues on tx.
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let (_, t2) = s.message(cpu_ep(0), cpu_ep(2), bytes, 0.0);
        assert!(t2 > t1 * 1.8, "fanout must serialize: t1={t1} t2={t2}");
    }

    #[test]
    fn disjoint_pairs_run_in_parallel() {
        let mut s = sim(FabricKind::EthernetRoce25);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), bytes, 0.0);
        let (_, t2) = s.message(cpu_ep(2), cpu_ep(3), bytes, 0.0);
        assert!((t1 - t2).abs() < 1e-9, "disjoint flows must not interfere");
    }

    #[test]
    fn intra_node_faster_than_inter_node() {
        let mut s = sim(FabricKind::OmniPath100);
        let gpu0 = NetSim::endpoint(0, 0, EndpointKind::Gpu);
        let gpu1 = NetSim::endpoint(0, 1, EndpointKind::Gpu);
        let gpu2 = NetSim::endpoint(1, 0, EndpointKind::Gpu);
        let bytes = 1024.0 * 1024.0;
        let (_, local) = s.message(gpu0, gpu1, bytes, 0.0);
        s.reset();
        let (_, remote) = s.message(gpu0, gpu2, bytes, 0.0);
        assert!(local < remote);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = sim(FabricKind::OmniPath100);
        s.message(cpu_ep(0), cpu_ep(1), 100.0, 0.0);
        s.message(cpu_ep(0), cpu_ep(40), 100.0, 0.0); // node 40 = rack 1
        let gpu0 = NetSim::endpoint(0, 0, EndpointKind::Gpu);
        let gpu1 = NetSim::endpoint(0, 1, EndpointKind::Gpu);
        s.message(gpu0, gpu1, 100.0, 0.0);
        assert_eq!(s.stats.messages, 3);
        assert_eq!(s.stats.inter_node_messages, 2);
        assert_eq!(s.stats.inter_rack_messages, 1);
        assert_eq!(s.stats.bytes, 300.0);
    }

    #[test]
    fn message_time_monotone_in_size() {
        prop::forall(31, 128, |r| (r.below(24) as i32, r.below(1_000_000) as f64), |&(shift, base)| {
            let mut s = sim(FabricKind::EthernetRoce25);
            let b1 = base + 1.0;
            let b2 = b1 * (1.0 + (shift as f64 + 1.0) / 4.0);
            let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), b1, 0.0);
            s.reset();
            let (_, t2) = s.message(cpu_ep(0), cpu_ep(1), b2, 0.0);
            if t2 + 1e-15 < t1 {
                return Err(format!("time not monotone: {b1}B->{t1}s, {b2}B->{t2}s"));
            }
            Ok(())
        });
    }

    #[test]
    fn ready_time_shifts_completion() {
        let mut s = sim(FabricKind::OmniPath100);
        let (_, t0) = s.message(cpu_ep(0), cpu_ep(1), 1000.0, 0.0);
        s.reset();
        let (_, t1) = s.message(cpu_ep(0), cpu_ep(1), 1000.0, 1.0);
        assert!((t1 - t0 - 1.0).abs() < 1e-12);
    }
}
