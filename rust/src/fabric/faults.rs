//! Fault injection: deterministic, seeded traces of link/NIC/spine events
//! compiled into a capacity-multiplier timeline the fluid engine merges
//! into its event loop.
//!
//! Three event classes, all expressed as *capacity changes* on the shared
//! link resources of [`Topology`]:
//!
//! * **hard-down with repair** — factor `0.0` over `[at, at + duration)`;
//! * **bandwidth brownout** — factor in `(0, 1)` over the window;
//! * **flapping** — a spine that cycles down/up `count` times with period
//!   `period` (down for the first half of each cycle).
//!
//! Targets: a whole **spine** (every ToR's up/down port through it), a
//! single **link** (one ToR's up/down pair on one spine), or a **NIC**
//! (a node's tx/rx ports; a hard NIC-down also marks the *node* dead for
//! the window, which the collectives use for leader election). Dragonfly
//! global links are not fault targets — the spine tier they feed already
//! covers the inter-group path.
//!
//! Traces come from two sources that compose: a scripted event list (the
//! `[faults]` TOML arrays, or programmatic [`FaultSpec::events`]) and a
//! seeded Poisson process (`rate` events/sec up to `horizon_secs`,
//! exponential durations, a `brownout_frac` coin per event). Compilation
//! is pure: the same `(spec, topology)` pair always yields the same
//! timeline, so every downstream consumer (engine, collectives, sweeps)
//! is bitwise reproducible.
//!
//! The neutral spec (`FaultSpec::default()`, i.e. `faults = none`) is
//! *inactive*: `NetSim` never attaches a timeline, and the engine is
//! bit-for-bit the pre-fault engine (pinned by `tests/fault_properties.rs`).

use crate::fabric::topology::Topology;
use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// What a fault event hits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultTarget {
    /// A whole spine switch: every ToR's up/down port through it.
    Spine(usize),
    /// One ToR's up/down link pair on one spine.
    Link { tor: usize, spine: usize },
    /// A node's NIC (tx and rx). A hard-down also marks the node dead.
    Nic(usize),
}

/// One fault: the target's capacity is multiplied by `factor` over
/// `[at, at + duration)`. `factor == 0.0` is a hard-down with repair at
/// the window's end; `0 < factor < 1` is a brownout. Overlapping events
/// on the same resource multiply (any hard-down wins).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub target: FaultTarget,
    /// Start, in seconds on the fault clock.
    pub at: f64,
    /// Window length in seconds (> 0).
    pub duration: f64,
    /// Capacity multiplier in [0, 1).
    pub factor: f64,
}

/// Declarative fault configuration (`[faults]` in TOML). Inactive by
/// default — [`FaultSpec::active`] gates every engine hook, so the
/// neutral spec costs nothing and changes no bits.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    /// Random fault events per second (0 = scripted events only).
    pub rate: f64,
    /// Seed for the random trace.
    pub seed: u64,
    /// Mean duration of a random event, seconds (exponential).
    pub mean_duration: f64,
    /// Random-trace horizon, seconds: no random event starts after this.
    pub horizon: f64,
    /// Fraction of random events that brown out instead of hard-down.
    pub brownout_frac: f64,
    /// Capacity multiplier used by random brownouts.
    pub brownout_factor: f64,
    /// Scripted events (parsed from the TOML arrays, or set directly).
    pub events: Vec<FaultEvent>,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            rate: 0.0,
            seed: 0xFA_017,
            mean_duration: 0.05,
            horizon: 60.0,
            brownout_frac: 0.5,
            brownout_factor: 0.25,
            events: Vec::new(),
        }
    }
}

impl FaultSpec {
    /// Does this spec inject anything? `false` = the engine never
    /// attaches a timeline and is bit-for-bit the pre-fault engine.
    pub fn active(&self) -> bool {
        self.rate > 0.0 || !self.events.is_empty()
    }

    /// Preset: one scripted spine hard-down with repair.
    pub fn spine_down(spine: usize, at: f64, duration: f64) -> FaultSpec {
        FaultSpec {
            events: vec![FaultEvent { target: FaultTarget::Spine(spine), at, duration, factor: 0.0 }],
            ..Default::default()
        }
    }

    /// Preset: seeded Poisson trace at `rate` events/sec.
    pub fn random(rate: f64, seed: u64) -> FaultSpec {
        FaultSpec { rate, seed, ..Default::default() }
    }

    /// Stable hash of the fault configuration (folded into schedule-cache
    /// world signatures so faulted and healthy worlds can never alias).
    pub fn signature(&self) -> u64 {
        // One fold per field (see TenancySpec::signature for why not XOR).
        let mut h = fnv1a_u64(FNV_OFFSET, self.rate.to_bits());
        h = fnv1a_u64(h, self.seed);
        h = fnv1a_u64(h, self.mean_duration.to_bits());
        h = fnv1a_u64(h, self.horizon.to_bits());
        h = fnv1a_u64(h, self.brownout_frac.to_bits());
        h = fnv1a_u64(h, self.brownout_factor.to_bits());
        for e in &self.events {
            let (tag, a, b) = match e.target {
                FaultTarget::Spine(s) => (1u64, s as u64, 0),
                FaultTarget::Link { tor, spine } => (2, tor as u64, spine as u64),
                FaultTarget::Nic(n) => (3, n as u64, 0),
            };
            h = fnv1a_u64(h, tag);
            h = fnv1a_u64(h, a);
            h = fnv1a_u64(h, b);
            h = fnv1a_u64(h, e.at.to_bits());
            h = fnv1a_u64(h, e.duration.to_bits());
            h = fnv1a_u64(h, e.factor.to_bits());
        }
        h
    }

    /// Build from a parsed TOML `[faults]` table, filling defaults. A key
    /// present with the wrong type or shape is a loud error, not a
    /// silently kept default (same contract as `[transport]`/`[tenancy]`).
    ///
    /// Scripted events are arrays of fixed-arity number rows, times in
    /// milliseconds:
    ///
    /// ```toml
    /// [faults]
    /// spine_down = [[0, 10.0, 50.0]]        # [spine, at_ms, duration_ms]
    /// link_down  = [[0, 1, 10.0, 50.0]]     # [tor, spine, at_ms, duration_ms]
    /// nic_down   = [[3, 10.0, 50.0]]        # [node, at_ms, duration_ms]
    /// brownout   = [[0, 1, 10.0, 50.0, 0.5]]# [tor, spine, at_ms, dur_ms, factor]
    /// flap       = [[1, 10.0, 20.0, 4]]     # [spine, first_ms, period_ms, count]
    /// ```
    pub fn from_toml(v: &Json) -> Result<FaultSpec> {
        let getf = |key: &str| -> Result<Option<f64>> {
            match v.get(key) {
                None => Ok(None),
                Some(x) => match x.as_f64() {
                    Some(f) => Ok(Some(f)),
                    None => bail!("faults.{key} must be a number"),
                },
            }
        };
        let rows = |key: &str, arity: usize| -> Result<Vec<Vec<f64>>> {
            let Some(x) = v.get(key) else { return Ok(Vec::new()) };
            let Some(items) = x.as_arr() else {
                bail!("faults.{key} must be an array of {arity}-number rows");
            };
            let mut out = Vec::with_capacity(items.len());
            for (i, row) in items.iter().enumerate() {
                let Some(cells) = row.as_arr() else {
                    bail!("faults.{key}[{i}] must be a {arity}-number row, got a scalar");
                };
                if cells.len() != arity {
                    bail!("faults.{key}[{i}] must have {arity} numbers, got {}", cells.len());
                }
                let mut r = Vec::with_capacity(arity);
                for (j, c) in cells.iter().enumerate() {
                    match c.as_f64() {
                        Some(f) => r.push(f),
                        None => bail!("faults.{key}[{i}][{j}] must be a number"),
                    }
                }
                out.push(r);
            }
            Ok(out)
        };
        let idx = |key: &str, x: f64| -> Result<usize> {
            if x.fract() != 0.0 || x < 0.0 {
                bail!("faults.{key} index must be a non-negative integer, got {x}");
            }
            Ok(x as usize)
        };
        let mut t = FaultSpec::default();
        if let Some(x) = getf("rate")? {
            t.rate = x;
        }
        if let Some(k) = v.get("seed") {
            match k.as_f64() {
                Some(f) if f.fract() == 0.0 && f >= 0.0 => {
                    // Same 2^53 guard as tenancy.seed: the TOML layer
                    // carries numbers as f64.
                    if f >= (1u64 << 53) as f64 {
                        bail!("faults.seed {f} is not exactly representable (must be < 2^53)");
                    }
                    t.seed = f as u64;
                }
                _ => bail!("faults.seed must be a non-negative integer"),
            }
        }
        if let Some(x) = getf("mean_duration_ms")? {
            t.mean_duration = x * 1e-3;
        }
        if let Some(x) = getf("horizon_secs")? {
            t.horizon = x;
        }
        if let Some(x) = getf("brownout_frac")? {
            t.brownout_frac = x;
        }
        if let Some(x) = getf("brownout_factor")? {
            t.brownout_factor = x;
        }
        for r in rows("spine_down", 3)? {
            t.events.push(FaultEvent {
                target: FaultTarget::Spine(idx("spine_down", r[0])?),
                at: r[1] * 1e-3,
                duration: r[2] * 1e-3,
                factor: 0.0,
            });
        }
        for r in rows("link_down", 4)? {
            t.events.push(FaultEvent {
                target: FaultTarget::Link {
                    tor: idx("link_down", r[0])?,
                    spine: idx("link_down", r[1])?,
                },
                at: r[2] * 1e-3,
                duration: r[3] * 1e-3,
                factor: 0.0,
            });
        }
        for r in rows("nic_down", 3)? {
            t.events.push(FaultEvent {
                target: FaultTarget::Nic(idx("nic_down", r[0])?),
                at: r[1] * 1e-3,
                duration: r[2] * 1e-3,
                factor: 0.0,
            });
        }
        for r in rows("brownout", 5)? {
            t.events.push(FaultEvent {
                target: FaultTarget::Link {
                    tor: idx("brownout", r[0])?,
                    spine: idx("brownout", r[1])?,
                },
                at: r[2] * 1e-3,
                duration: r[3] * 1e-3,
                factor: r[4],
            });
        }
        for r in rows("flap", 4)? {
            let spine = idx("flap", r[0])?;
            let (first, period) = (r[1] * 1e-3, r[2] * 1e-3);
            let count = idx("flap", r[3])?;
            if !period.is_finite() || period <= 0.0 {
                bail!("faults.flap period_ms must be positive, got {}", r[2]);
            }
            if count == 0 || count > 10_000 {
                bail!("faults.flap count must be in [1, 10000], got {count}");
            }
            // Each flap cycle: down for the first half-period, up for the
            // second.
            for j in 0..count {
                t.events.push(FaultEvent {
                    target: FaultTarget::Spine(spine),
                    at: first + j as f64 * period,
                    duration: period * 0.5,
                    factor: 0.0,
                });
            }
        }
        t.validate()?;
        Ok(t)
    }

    /// Parse a CLI fault spec `RATE[:SEED]` (e.g. `2.0:99` — two random
    /// events per second, seed 99) onto this spec.
    pub fn apply_cli(&mut self, s: &str) -> Result<()> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.is_empty() || parts.len() > 2 {
            bail!("--faults expects RATE[:SEED], got '{s}'");
        }
        self.rate = parts[0]
            .parse()
            .map_err(|_| anyhow::anyhow!("--faults RATE must be a number, got '{}'", parts[0]))?;
        if let Some(p) = parts.get(1) {
            self.seed = p
                .parse()
                .map_err(|_| anyhow::anyhow!("--faults SEED must be an integer, got '{p}'"))?;
        }
        self.validate()
    }

    /// Cluster-independent validation (event *indices* are checked
    /// against the concrete topology at [`FaultTimeline::compile`] time).
    pub fn validate(&self) -> Result<()> {
        if !self.rate.is_finite() || self.rate < 0.0 {
            bail!("faults: rate {} must be a finite non-negative number", self.rate);
        }
        if !self.mean_duration.is_finite() || self.mean_duration <= 0.0 {
            bail!("faults: mean_duration_ms must be positive");
        }
        if !self.horizon.is_finite() || self.horizon <= 0.0 {
            bail!("faults: horizon_secs must be positive");
        }
        // The random trace materializes ~rate * horizon events; keep the
        // compiled timeline's size sane.
        if self.rate * self.horizon > 100_000.0 {
            bail!(
                "faults: rate {} x horizon {}s would generate > 100k events",
                self.rate,
                self.horizon
            );
        }
        if !self.brownout_frac.is_finite() || !(0.0..=1.0).contains(&self.brownout_frac) {
            bail!("faults: brownout_frac {} must be in [0, 1]", self.brownout_frac);
        }
        if !self.brownout_factor.is_finite() || !(0.0..1.0).contains(&self.brownout_factor) {
            bail!(
                "faults: brownout_factor {} must be in [0, 1) (1 would be a no-op fault)",
                self.brownout_factor
            );
        }
        for (i, e) in self.events.iter().enumerate() {
            if !e.at.is_finite() || e.at < 0.0 {
                bail!("faults: event {i} start {} must be finite and >= 0", e.at);
            }
            if !e.duration.is_finite() || e.duration <= 0.0 {
                bail!("faults: event {i} duration {} must be positive", e.duration);
            }
            if !e.factor.is_finite() || !(0.0..1.0).contains(&e.factor) {
                bail!("faults: event {i} factor {} must be in [0, 1)", e.factor);
            }
        }
        Ok(())
    }
}

/// A compiled fault timeline against one concrete [`Topology`]: for every
/// touched resource, a sorted step function of capacity multipliers, plus
/// the global list of change times the event loop merges against, node
/// liveness windows, and the merged "some fault is active" intervals the
/// trainer's exposure metric integrates.
#[derive(Clone, Debug, Default)]
pub struct FaultTimeline {
    /// Per-resource step function: `(t, mult)` sorted by `t`; the
    /// multiplier applies from `t` (inclusive). Before the first entry
    /// the multiplier is 1.
    steps: HashMap<usize, Vec<(f64, f64)>>,
    /// Union of every step time, sorted and deduplicated.
    changes: Vec<f64>,
    /// Per-node merged dead windows `[start, end)` (hard NIC-downs).
    node_down: HashMap<usize, Vec<(f64, f64)>>,
    /// Merged `[start, end)` windows where at least one fault is active.
    degraded: Vec<(f64, f64)>,
    /// Total expanded event count (after flap/random expansion).
    n_events: usize,
}

/// Merge possibly-overlapping `[start, end)` intervals in place.
fn merge_intervals(iv: &mut Vec<(f64, f64)>) {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for &(s, e) in iv.iter() {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    *iv = out;
}

/// Sorted `(t, mult)` step function from one resource's fault intervals
/// `(start, end, factor)`: the multiplier at time t is the product of the
/// factors of every interval covering t (so overlapping brownouts
/// compound and any hard-down forces 0). Consecutive bitwise-equal steps
/// are collapsed.
fn step_function(intervals: &[(f64, f64, f64)]) -> Vec<(f64, f64)> {
    // Breakpoints: (t, is_start, interval index). Ends sort before starts
    // at equal t so a repair and a new fault at the same instant don't
    // fabricate a zero-width overlap.
    let mut pts: Vec<(f64, bool, usize)> = Vec::with_capacity(2 * intervals.len());
    for (i, &(s, e, _)) in intervals.iter().enumerate() {
        pts.push((s, true, i));
        pts.push((e, false, i));
    }
    pts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut alive = vec![false; intervals.len()];
    let mut out: Vec<(f64, f64)> = Vec::new();
    let mut k = 0;
    while k < pts.len() {
        let t = pts[k].0;
        while k < pts.len() && pts[k].0 == t {
            alive[pts[k].2] = pts[k].1;
            k += 1;
        }
        let mut mult = 1.0;
        for (i, &a) in alive.iter().enumerate() {
            if a {
                mult *= intervals[i].2;
            }
        }
        if out.last().map_or(1.0f64.to_bits() != mult.to_bits(), |l| l.1.to_bits() != mult.to_bits())
        {
            out.push((t, mult));
        }
    }
    out
}

impl FaultTimeline {
    /// Expand a spec's scripted + random events against a concrete
    /// topology into per-resource capacity step functions. Pure and
    /// deterministic; scripted indices out of range are loud errors.
    pub fn compile(spec: &FaultSpec, topo: &Topology) -> Result<FaultTimeline> {
        spec.validate()?;
        let mut events = spec.events.clone();
        if spec.rate > 0.0 {
            let mut rng = Rng::new(spec.seed ^ 0x00FA_017F_A017_FA01);
            let mut t = 0.0;
            loop {
                t += rng.exponential(1.0 / spec.rate);
                if t > spec.horizon {
                    break;
                }
                let duration = rng.exponential(spec.mean_duration).max(1e-6);
                let factor =
                    if rng.uniform() < spec.brownout_frac { spec.brownout_factor } else { 0.0 };
                // Mix: mostly single-link faults, some NICs, rare whole
                // spines — roughly the blast-radius ordering of real
                // fabric incidents.
                let target = match rng.below(10) {
                    0..=4 => FaultTarget::Link {
                        tor: rng.below(topo.n_tors as u64) as usize,
                        spine: rng.below(topo.n_spines as u64) as usize,
                    },
                    5..=7 => FaultTarget::Nic(rng.below(topo.n_nodes as u64) as usize),
                    _ => FaultTarget::Spine(rng.below(topo.n_spines as u64) as usize),
                };
                events.push(FaultEvent { target, at: t, duration, factor });
            }
        }
        // Expand targets to resource-level intervals.
        let mut by_res: HashMap<usize, Vec<(f64, f64, f64)>> = HashMap::new();
        let mut node_down: HashMap<usize, Vec<(f64, f64)>> = HashMap::new();
        let mut degraded: Vec<(f64, f64)> = Vec::new();
        for (i, e) in events.iter().enumerate() {
            let (s, end) = (e.at, e.at + e.duration);
            let mut push = |id: usize| by_res.entry(id).or_default().push((s, end, e.factor));
            match e.target {
                FaultTarget::Spine(sp) => {
                    if sp >= topo.n_spines {
                        bail!(
                            "faults: event {i} targets spine {sp}, topology has {}",
                            topo.n_spines
                        );
                    }
                    for tor in 0..topo.n_tors {
                        push(topo.up_id(tor, sp));
                        push(topo.down_id(tor, sp));
                    }
                }
                FaultTarget::Link { tor, spine } => {
                    if tor >= topo.n_tors || spine >= topo.n_spines {
                        bail!(
                            "faults: event {i} targets link (tor {tor}, spine {spine}), topology \
                             has {} tors x {} spines",
                            topo.n_tors,
                            topo.n_spines
                        );
                    }
                    push(topo.up_id(tor, spine));
                    push(topo.down_id(tor, spine));
                }
                FaultTarget::Nic(node) => {
                    if node >= topo.n_nodes {
                        bail!("faults: event {i} targets node {node}, topology has {}", topo.n_nodes);
                    }
                    push(topo.tx_id(node));
                    push(topo.rx_id(node));
                    if e.factor == 0.0 {
                        node_down.entry(node).or_default().push((s, end));
                    }
                }
            }
            degraded.push((s, end));
        }
        let mut steps: HashMap<usize, Vec<(f64, f64)>> = HashMap::with_capacity(by_res.len());
        let mut changes: Vec<f64> = Vec::new();
        for (id, iv) in by_res {
            let sf = step_function(&iv);
            changes.extend(sf.iter().map(|&(t, _)| t));
            steps.insert(id, sf);
        }
        changes.sort_by(f64::total_cmp);
        changes.dedup_by(|a, b| a.to_bits() == b.to_bits());
        for iv in node_down.values_mut() {
            merge_intervals(iv);
        }
        merge_intervals(&mut degraded);
        Ok(FaultTimeline { steps, changes, node_down, degraded, n_events: events.len() })
    }

    /// Capacity multiplier on resource `res` at fault-clock time `t`
    /// (1.0 when the resource is untouched by the trace).
    pub fn mult_at(&self, res: usize, t: f64) -> f64 {
        match self.steps.get(&res) {
            None => 1.0,
            Some(s) => match s.partition_point(|&(st, _)| st <= t) {
                0 => 1.0,
                k => s[k - 1].1,
            },
        }
    }

    /// The first capacity-change time strictly after `t`, if any.
    pub fn next_change_after(&self, t: f64) -> Option<f64> {
        let k = self.changes.partition_point(|&c| c <= t);
        self.changes.get(k).copied()
    }

    /// Is the node's NIC free of a hard-down at time `t`?
    pub fn node_alive(&self, node: usize, t: f64) -> bool {
        match self.node_down.get(&node) {
            None => true,
            Some(iv) => {
                let k = iv.partition_point(|&(s, _)| s <= t);
                k == 0 || t >= iv[k - 1].1
            }
        }
    }

    /// Seconds of `[a, b]` during which at least one fault is active —
    /// the trainer's per-step fault exposure integrand.
    pub fn degraded_overlap(&self, a: f64, b: f64) -> f64 {
        let mut acc = 0.0;
        for &(s, e) in &self.degraded {
            if s >= b {
                break;
            }
            acc += (e.min(b) - s.max(a)).max(0.0);
        }
        acc
    }

    /// Is spine `s` usable between the two ToRs at time `t` (both the up
    /// port at the source ToR and the down port at the destination)?
    pub fn spine_alive(&self, topo: &Topology, src_tor: usize, dst_tor: usize, s: usize, t: f64) -> bool {
        self.mult_at(topo.up_id(src_tor, s), t) > 0.0
            && self.mult_at(topo.down_id(dst_tor, s), t) > 0.0
    }

    /// Can a flow from `src` to `dst` make progress at time `t`: both
    /// NICs up and, across ToRs, at least one surviving spine.
    pub fn path_usable(&self, topo: &Topology, src: usize, dst: usize, t: f64) -> bool {
        if self.mult_at(topo.tx_id(src), t) <= 0.0 || self.mult_at(topo.rx_id(dst), t) <= 0.0 {
            return false;
        }
        let (st, dt) = (topo.tor_of_node(src), topo.tor_of_node(dst));
        if st == dt {
            return true;
        }
        (0..topo.n_spines).any(|s| self.spine_alive(topo, st, dt, s, t))
    }

    /// The earliest time >= `t` at which the `src -> dst` path is usable
    /// again (only change times need checking — the path's state is
    /// constant between them). `None` if it never recovers within the
    /// trace.
    pub fn path_recovery_after(&self, topo: &Topology, src: usize, dst: usize, t: f64) -> Option<f64> {
        if self.path_usable(topo, src, dst, t) {
            return Some(t);
        }
        let mut k = self.changes.partition_point(|&c| c <= t);
        while k < self.changes.len() {
            let c = self.changes[k];
            if self.path_usable(topo, src, dst, c) {
                return Some(c);
            }
            k += 1;
        }
        None
    }

    /// Does the trace ever touch this resource?
    pub fn touches(&self, res: usize) -> bool {
        self.steps.contains_key(&res)
    }

    /// Expanded event count (after flap/random expansion).
    pub fn n_events(&self) -> usize {
        self.n_events
    }

    /// Number of distinct capacity-change instants.
    pub fn n_changes(&self) -> usize {
        self.changes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::{ClusterSpec, FabricKind, TopologySpec};

    fn topo(spines: usize) -> Topology {
        let cluster = ClusterSpec::txgaia();
        let spec =
            TopologySpec { spines, oversubscription: Some(1.0), ..Default::default() };
        Topology::build(&spec, &fabric(FabricKind::EthernetRoce25), &cluster).unwrap()
    }

    #[test]
    fn default_spec_is_inactive() {
        let s = FaultSpec::default();
        assert!(!s.active());
        s.validate().unwrap();
        let tl = FaultTimeline::compile(&s, &topo(2)).unwrap();
        assert_eq!(tl.n_events(), 0);
        assert_eq!(tl.n_changes(), 0);
        assert!(tl.next_change_after(0.0).is_none());
        assert_eq!(tl.mult_at(0, 1.0), 1.0);
    }

    #[test]
    fn scripted_spine_down_zeroes_every_tor_port() {
        let t = topo(2);
        let s = FaultSpec::spine_down(1, 0.01, 0.05);
        assert!(s.active());
        let tl = FaultTimeline::compile(&s, &t).unwrap();
        for tor in 0..t.n_tors {
            for id in [t.up_id(tor, 1), t.down_id(tor, 1)] {
                assert_eq!(tl.mult_at(id, 0.0), 1.0);
                assert_eq!(tl.mult_at(id, 0.02), 0.0);
                assert_eq!(tl.mult_at(id, 0.07), 1.0);
            }
            // Spine 0 untouched.
            assert_eq!(tl.mult_at(t.up_id(tor, 0), 0.02), 1.0);
        }
        assert_eq!(tl.n_changes(), 2);
        assert_eq!(tl.next_change_after(0.0), Some(0.01));
        assert_eq!(tl.next_change_after(0.01), Some(0.06));
        assert!((tl.degraded_overlap(0.0, 0.1) - 0.05).abs() < 1e-12);
        assert!((tl.degraded_overlap(0.02, 0.04) - 0.02).abs() < 1e-12);
    }

    #[test]
    fn overlapping_brownouts_compound_and_hard_down_wins() {
        let t = topo(2);
        let mk = |factor, at, duration| FaultEvent {
            target: FaultTarget::Link { tor: 0, spine: 0 },
            at,
            duration,
            factor,
        };
        let s = FaultSpec {
            events: vec![mk(0.5, 0.0, 0.10), mk(0.5, 0.05, 0.10), mk(0.0, 0.08, 0.01)],
            ..Default::default()
        };
        let tl = FaultTimeline::compile(&s, &t).unwrap();
        let id = t.up_id(0, 0);
        assert_eq!(tl.mult_at(id, 0.01), 0.5);
        assert_eq!(tl.mult_at(id, 0.06), 0.25);
        assert_eq!(tl.mult_at(id, 0.085), 0.0);
        assert_eq!(tl.mult_at(id, 0.095), 0.25);
        assert_eq!(tl.mult_at(id, 0.12), 0.5);
        assert_eq!(tl.mult_at(id, 0.2), 1.0);
    }

    #[test]
    fn nic_down_marks_node_dead_and_path_unusable() {
        let t = topo(2);
        let s = FaultSpec {
            events: vec![FaultEvent {
                target: FaultTarget::Nic(3),
                at: 0.01,
                duration: 0.02,
                factor: 0.0,
            }],
            ..Default::default()
        };
        let tl = FaultTimeline::compile(&s, &t).unwrap();
        assert!(tl.node_alive(3, 0.0));
        assert!(!tl.node_alive(3, 0.02));
        assert!(tl.node_alive(3, 0.03));
        assert!(tl.node_alive(0, 0.02));
        assert!(!tl.path_usable(&t, 3, 40, 0.02));
        assert!(!tl.path_usable(&t, 40, 3, 0.02));
        assert!(tl.path_usable(&t, 0, 40, 0.02));
        assert_eq!(tl.path_recovery_after(&t, 3, 40, 0.02), Some(0.01 + 0.02));
    }

    #[test]
    fn all_spines_down_kills_inter_tor_but_not_intra_tor() {
        let t = topo(2);
        let s = FaultSpec {
            events: (0..2)
                .map(|sp| FaultEvent {
                    target: FaultTarget::Spine(sp),
                    at: 0.0,
                    duration: 0.1,
                    factor: 0.0,
                })
                .collect(),
            ..Default::default()
        };
        let tl = FaultTimeline::compile(&s, &t).unwrap();
        // Node 0 and 3 share a ToR on txgaia (nodes_per_rack >= 4).
        assert!(tl.path_usable(&t, 0, 3, 0.05));
        assert!(!tl.path_usable(&t, 0, 40, 0.05));
        assert_eq!(tl.path_recovery_after(&t, 0, 40, 0.05), Some(0.1));
    }

    #[test]
    fn random_trace_is_deterministic_and_seed_sensitive() {
        let t = topo(4);
        let a = FaultTimeline::compile(&FaultSpec::random(20.0, 7), &t).unwrap();
        let b = FaultTimeline::compile(&FaultSpec::random(20.0, 7), &t).unwrap();
        let c = FaultTimeline::compile(&FaultSpec::random(20.0, 8), &t).unwrap();
        assert!(a.n_events() > 0);
        assert_eq!(a.n_events(), b.n_events());
        assert_eq!(a.changes, b.changes);
        assert_ne!(a.changes, c.changes);
    }

    #[test]
    fn from_toml_parses_every_event_kind() {
        let doc = crate::config::toml::parse(
            r#"
[faults]
rate = 0.5
seed = 99
mean_duration_ms = 40
horizon_secs = 10
spine_down = [[0, 10.0, 50.0]]
link_down = [[0, 1, 10.0, 50.0]]
nic_down = [[3, 10.0, 50.0]]
brownout = [[0, 1, 10.0, 50.0, 0.5]]
flap = [[1, 10.0, 20.0, 4]]
"#,
        )
        .unwrap();
        let s = FaultSpec::from_toml(doc.get("faults").unwrap()).unwrap();
        assert_eq!(s.rate, 0.5);
        assert_eq!(s.seed, 99);
        assert!((s.mean_duration - 0.04).abs() < 1e-12);
        // 1 spine + 1 link + 1 nic + 1 brownout + 4 flap windows.
        assert_eq!(s.events.len(), 8);
        assert_eq!(s.events[0].target, FaultTarget::Spine(0));
        assert!((s.events[0].at - 0.01).abs() < 1e-12);
        assert_eq!(s.events[3].factor, 0.5);
        let flap = &s.events[4..];
        assert!(flap.iter().all(|e| e.target == FaultTarget::Spine(1) && e.factor == 0.0));
        assert!((flap[1].at - flap[0].at - 0.02).abs() < 1e-12);
        assert!((flap[0].duration - 0.01).abs() < 1e-12);
    }

    #[test]
    fn from_toml_rejects_malformed_rows_loudly() {
        for (body, needle) in [
            ("spine_down = 3", "must be an array"),
            ("spine_down = [[0, 10.0]]", "must have 3 numbers"),
            ("spine_down = [[0.5, 10.0, 50.0]]", "non-negative integer"),
            ("nic_down = [[\"a\", 10.0, 50.0]]", "must be a number"),
            ("rate = \"fast\"", "faults.rate must be a number"),
            ("seed = -1", "non-negative integer"),
            ("flap = [[0, 1.0, 0.0, 2]]", "period_ms must be positive"),
        ] {
            let doc = crate::config::toml::parse(&format!("[faults]\n{body}\n")).unwrap();
            let err = FaultSpec::from_toml(doc.get("faults").unwrap()).unwrap_err().to_string();
            assert!(err.contains(needle), "body {body:?}: error {err:?} missing {needle:?}");
        }
    }

    #[test]
    fn compile_rejects_out_of_range_targets() {
        let t = topo(2);
        let s = FaultSpec::spine_down(2, 0.0, 0.1);
        let err = FaultTimeline::compile(&s, &t).unwrap_err().to_string();
        assert!(err.contains("spine 2"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_numbers() {
        for (mutate, needle) in [
            (
                Box::new(|s: &mut FaultSpec| s.rate = -1.0) as Box<dyn Fn(&mut FaultSpec)>,
                "rate",
            ),
            (Box::new(|s: &mut FaultSpec| s.brownout_factor = 1.0), "brownout_factor"),
            (Box::new(|s: &mut FaultSpec| s.horizon = 0.0), "horizon"),
            (
                Box::new(|s: &mut FaultSpec| {
                    s.rate = 100.0;
                    s.horizon = 1e9;
                }),
                "100k events",
            ),
            (
                Box::new(|s: &mut FaultSpec| {
                    s.events.push(FaultEvent {
                        target: FaultTarget::Spine(0),
                        at: 0.0,
                        duration: 0.0,
                        factor: 0.0,
                    })
                }),
                "duration",
            ),
        ] {
            let mut s = FaultSpec::default();
            mutate(&mut s);
            let err = s.validate().unwrap_err().to_string();
            assert!(err.contains(needle), "error {err:?} missing {needle:?}");
        }
    }

    #[test]
    fn apply_cli_parses_rate_and_seed() {
        let mut s = FaultSpec::default();
        s.apply_cli("2.5:42").unwrap();
        assert_eq!(s.rate, 2.5);
        assert_eq!(s.seed, 42);
        assert!(s.apply_cli("fast").is_err());
        assert!(s.apply_cli("1.0:x").is_err());
    }

    #[test]
    fn signature_distinguishes_specs() {
        let a = FaultSpec::default();
        let b = FaultSpec::random(1.0, 7);
        let c = FaultSpec::random(1.0, 8);
        let d = FaultSpec::spine_down(0, 0.0, 0.1);
        let sigs = [a.signature(), b.signature(), c.signature(), d.signature()];
        for i in 0..sigs.len() {
            for j in i + 1..sigs.len() {
                assert_ne!(sigs[i], sigs[j], "specs {i} and {j} alias");
            }
        }
        assert_eq!(a.signature(), FaultSpec::default().signature());
    }
}
