//! Network fabric simulation: the substitute for TX-GAIA's physical
//! 25 GbE-RoCE and 100 Gb OmniPath fabrics.
//!
//! Model family: flow-level LogGP-style costs with resource occupancy.
//! A point-to-point message pays
//!
//! ```text
//! t = o_send + L(path) + rendezvous + staging + bytes / bw(path) + o_recv
//! ```
//!
//! where `L(path)` includes switch hops for inter-rack traffic, `staging`
//! models GPUDirect-vs-host-copy PCIe/UPI segments, and `bw(path)` is the
//! minimum along NIC / PCIe / UPI segments scaled by a congestion factor.
//! NIC serialization is tracked as per-node occupancy so concurrent flows
//! through one endpoint queue rather than teleport (see [`contention`]).

pub mod contention;
pub mod mpi;
pub mod sim;
pub mod trace;
pub mod transport;

pub use mpi::Comm;
pub use sim::{NetSim, NetStats};
pub use trace::{MessageEvent, Trace};
pub use transport::MessageCost;
