//! Network fabric simulation: the substitute for TX-GAIA's physical
//! 25 GbE-RoCE and 100 Gb OmniPath fabrics.
//!
//! Model family: a **discrete-event fluid-flow engine** on top of
//! LogGP-style per-message costs. An uncontended point-to-point message
//! pays
//!
//! ```text
//! t = o_send + bytes / bw(path) + L(path) + rendezvous + staging + o_recv
//! ```
//!
//! where `L(path)` includes switch hops for inter-rack traffic, `staging`
//! models GPUDirect-vs-host-copy PCIe/UPI segments, and `bw(path)` is the
//! minimum along NIC / PCIe / UPI segments. Messages submitted together
//! as one round are concurrent *flows*: each claims every link of its
//! deterministic route through the configured [`topology`] (NIC tx/rx
//! ports, leaf up/down-links on the ECMP-chosen spine, dragonfly global
//! links), and the engine advances virtual time event by event,
//! recomputing **max-min fair** rates on every flow arrival/departure
//! (see [`contention`] and the module docs in [`sim`] /
//! `fabric/README.md`).
//!
//! Batches accept **heterogeneous per-flow ready times**, which is what
//! lets the trainer's multi-stream scheduler
//! ([`crate::trainer::scheduler`]) submit the next rounds of several
//! concurrent collectives as a single batch: flows join the fluid model
//! when their stream reaches them and share ports fairly from that
//! instant. Point-to-point transfers follow MPI's eager/rendezvous split
//! (see [`mpi`]): rendezvous-sized messages wait for the receiver's
//! recv-post before the payload moves.
//!
//! On a **shared** system (the paper's actual setting), the engine also
//! injects deterministic background cross-traffic from other tenants
//! (see [`tenancy`]): seeded poisson/on-off sources over configurable
//! node sets whose flows join the same batches and share every link
//! max-min fairly with the training job.
//!
//! Fabrics can also be **faulted** (see [`faults`]): deterministic seeded
//! traces of link/NIC/spine hard-downs, brownouts and flaps compile into
//! a capacity timeline merged into the fluid event loop; mid-flight flows
//! re-route over surviving ECMP spines or retry with exponential backoff
//! under the `[transport]` timeout policy (see [`mpi::RetryPolicy`]).

pub mod contention;
pub mod faults;
pub mod mpi;
pub mod sim;
pub mod tenancy;
pub mod topology;
pub mod trace;
pub mod transport;

pub use faults::{FaultEvent, FaultSpec, FaultTarget, FaultTimeline};
pub use mpi::{Comm, CommOp, RetryPolicy};
pub use sim::{FlowReq, FlowTimes, NetSim, NetStats};
pub use tenancy::{BackgroundTraffic, BgFlow};
pub use topology::{Route, Topology};
pub use trace::{MessageEvent, Trace};
pub use transport::MessageCost;
