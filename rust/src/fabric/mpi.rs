//! MPI-like communicator over the fabric simulator: per-rank virtual
//! clocks, point-to-point semantics, and a barrier. Collectives and the
//! CFD halo exchange are written against this layer.
//!
//! # Eager vs rendezvous point-to-point
//!
//! [`Comm::p2p`] models the MPI transport split: messages at or below the
//! rendezvous threshold (the fabric's `eager_threshold`, overridable via
//! [`TransportOptions::rendezvous_threshold`]) are *eager* — the sender
//! fires as soon as its own clock allows and the payload lands in the
//! receiver's bounce buffer. Above the threshold the transfer is
//! *rendezvous*: the payload cannot move before the receiver has posted
//! its recv, so the flow's ready time is `max(t[src], t[dst])`. (Before
//! this gate existed a rendezvous-sized message could "complete" at a
//! receiver whose clock had not yet reached its recv-post — the PR 1
//! latent bug.)
//!
//! # Op recording
//!
//! [`Comm::recorder`] builds a communicator that captures the *schedule*
//! of a collective (which rounds / point-to-points it issues, in order)
//! without touching the event engine or the clocks. The multi-stream
//! scheduler ([`crate::trainer::scheduler`]) replays recorded schedules
//! from several streams as merged event-engine batches so concurrent
//! collectives genuinely contend for NIC and up-link bandwidth.

use crate::cluster::Placement;
use crate::config::TransportOptions;
use crate::fabric::sim::{FlowReq, FlowTimes};
use crate::fabric::NetSim;

/// One entry of a recorded communication schedule (see [`Comm::recorder`]).
#[derive(Clone, Debug)]
pub enum CommOp {
    /// A synchronized round of concurrent messages (src, dst, bytes).
    Round(Vec<(usize, usize, f64)>),
    /// A blocking send/recv pair.
    P2p(usize, usize, f64),
    /// A simultaneous pairwise exchange.
    Sendrecv(usize, usize, f64),
    /// All clocks jump to the global maximum (end of a barrier).
    SyncAll,
}

/// Apply one finished round's flow times to the rank clocks, exactly as
/// [`Comm::round`] does (shared so the multi-stream scheduler's replay is
/// bit-identical to direct execution).
pub(crate) fn apply_round(
    t: &mut [f64],
    snapshot: &[f64],
    msgs: &[(usize, usize, f64)],
    times: &[FlowTimes],
) {
    for (&(src, dst, _), ft) in msgs.iter().zip(times) {
        t[src] = t[src].max(ft.send_release);
        t[dst] = t[dst].max(ft.recv_complete.max(snapshot[dst]));
    }
}

/// Does a `bytes`-sized point-to-point use the rendezvous protocol (and
/// therefore gate on the receiver having posted its recv)?
pub(crate) fn is_rendezvous(opts: &TransportOptions, eager_threshold: f64, bytes: f64) -> bool {
    bytes > opts.rendezvous_threshold.unwrap_or(eager_threshold)
}

/// Timeout/retry transport semantics under faults (the `[transport]`
/// `retry_timeout_ms` / `retry_backoff` / `max_retries` knobs).
///
/// When a flow's path is fault-dead at submission (or dies mid-flight),
/// the rendezvous handshake times out after [`RetryPolicy::wait`]`(0)`
/// seconds and is re-attempted with exponentially growing waits; probe
/// `k` (0-based) happens `timeout * backoff^0 + ... + timeout *
/// backoff^k` seconds after the first failure. A flow that exhausts
/// `max_retries` probes without finding a live path fails loudly and is
/// counted in `NetStats::failed_flows`. The probe schedule is a pure
/// function of the policy, so faulted runs stay bitwise deterministic.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Base rendezvous timeout, seconds.
    pub timeout: f64,
    /// Wait multiplier between consecutive probes (>= 1).
    pub backoff: f64,
    /// Probes before the flow is declared failed.
    pub max_retries: u32,
}

impl RetryPolicy {
    pub fn from_opts(opts: &TransportOptions) -> RetryPolicy {
        RetryPolicy {
            timeout: opts.retry_timeout,
            backoff: opts.retry_backoff,
            max_retries: opts.max_retries as u32,
        }
    }

    /// Wait before 0-based probe `k`: `timeout * backoff^k`.
    pub fn wait(&self, k: u32) -> f64 {
        self.timeout * self.backoff.powi(k as i32)
    }

    /// Offset of 0-based probe `k` from the moment the path was found
    /// dead: the sum of every wait up to and including `wait(k)`.
    pub fn probe_offset(&self, k: u32) -> f64 {
        (0..=k).map(|i| self.wait(i)).sum()
    }

    /// The whole retry window: a flow that has not found a live path
    /// this long after the first dead probe fails.
    pub fn total_window(&self) -> f64 {
        self.probe_offset(self.max_retries.saturating_sub(1))
    }

    /// The earliest probe (index, absolute time) at or after `recovery`,
    /// for a path first found dead at `dead_at` — `None` when the path
    /// recovers too late for the probe schedule (the flow fails at
    /// `dead_at + total_window()`). Probe indices are 0-based; the
    /// retry *count* charged to `NetStats::retries` is `index + 1`.
    pub fn first_probe_at(&self, dead_at: f64, recovery: f64) -> Option<(u32, f64)> {
        let mut at = dead_at;
        for k in 0..self.max_retries {
            at += self.wait(k);
            if at >= recovery {
                return Some((k, at));
            }
        }
        None
    }
}

/// A communicator: placement + one virtual clock per rank.
pub struct Comm<'a> {
    pub net: &'a mut NetSim,
    pub placement: &'a Placement,
    /// Virtual time at which each rank is next free.
    pub t: Vec<f64>,
    /// When set, operations are recorded instead of executed.
    record: Option<Vec<CommOp>>,
}

impl<'a> Comm<'a> {
    pub fn new(net: &'a mut NetSim, placement: &'a Placement) -> Self {
        let n = placement.len();
        Comm { net, placement, t: vec![0.0; n], record: None }
    }

    /// Start every rank's clock at the given times (e.g. staggered compute
    /// completion for comm/compute overlap studies).
    pub fn with_start(net: &'a mut NetSim, placement: &'a Placement, start: &[f64]) -> Self {
        assert_eq!(start.len(), placement.len());
        Comm { net, placement, t: start.to_vec(), record: None }
    }

    /// A recording communicator: collective algorithms run against it to
    /// capture their message schedule (clocks stay at zero, the event
    /// engine is never called). Retrieve the ops with [`Comm::take_record`].
    pub fn recorder(net: &'a mut NetSim, placement: &'a Placement) -> Self {
        let n = placement.len();
        Comm { net, placement, t: vec![0.0; n], record: Some(Vec::new()) }
    }

    /// The ops captured since construction (recording communicators only).
    pub fn take_record(&mut self) -> Option<Vec<CommOp>> {
        self.record.take()
    }

    pub fn size(&self) -> usize {
        self.placement.len()
    }

    /// Blocking send/recv pair: the receiver's clock advances to message
    /// completion; the sender's clock advances past its send-side cost.
    /// Rendezvous-sized messages (see the module docs) additionally wait
    /// for the receiver's clock before the payload moves.
    pub fn p2p(&mut self, src: usize, dst: usize, bytes: f64) {
        assert_ne!(src, dst, "p2p to self");
        if let Some(rec) = self.record.as_mut() {
            rec.push(CommOp::P2p(src, dst, bytes));
            return;
        }
        let ready = if is_rendezvous(&self.net.opts, self.net.fabric.eager_threshold, bytes) {
            // Rendezvous: the payload moves only once the receiver has
            // posted its recv.
            self.t[src].max(self.t[dst])
        } else {
            self.t[src] // eager: sender-gated
        };
        let (send_release, recv_complete) = self.net.message(
            self.placement.endpoints[src],
            self.placement.endpoints[dst],
            bytes,
            ready,
        );
        self.t[src] = self.t[src].max(send_release);
        // Receiver must have posted the recv: completion can't precede its
        // own clock.
        self.t[dst] = self.t[dst].max(recv_complete);
    }

    /// Simultaneous exchange (MPI_Sendrecv): both ranks send `bytes` to
    /// each other; both clocks advance to the later completion. The two
    /// flows are submitted as one event-engine batch, so they genuinely
    /// overlap in virtual time (full duplex on disjoint tx/rx ports).
    pub fn sendrecv(&mut self, a: usize, b: usize, bytes: f64) {
        assert_ne!(a, b, "sendrecv with self");
        if let Some(rec) = self.record.as_mut() {
            rec.push(CommOp::Sendrecv(a, b, bytes));
            return;
        }
        let ready = self.t[a].max(self.t[b]);
        let times = self.net.transfer_batch(&[
            FlowReq {
                src: self.placement.endpoints[a],
                dst: self.placement.endpoints[b],
                bytes,
                ready,
            },
            FlowReq {
                src: self.placement.endpoints[b],
                dst: self.placement.endpoints[a],
                bytes,
                ready,
            },
        ]);
        let done = times[0].recv_complete.max(times[1].recv_complete);
        self.t[a] = done;
        self.t[b] = done;
    }

    /// A synchronized communication round: all messages see the rank
    /// clocks as they were when the round started (every rank sends and
    /// receives simultaneously, as in a ring step) and are submitted to
    /// the event engine as ONE batch — concurrently in-flight flows share
    /// NIC ports and rack up-links max-min fairly instead of paying the
    /// old scalar congestion estimate.
    pub fn round(&mut self, msgs: &[(usize, usize, f64)]) {
        if let Some(rec) = self.record.as_mut() {
            for &(src, dst, _) in msgs {
                assert_ne!(src, dst, "round message to self");
            }
            rec.push(CommOp::Round(msgs.to_vec()));
            return;
        }
        let snapshot = self.t.clone();
        let reqs: Vec<FlowReq> = msgs
            .iter()
            .map(|&(src, dst, bytes)| {
                assert_ne!(src, dst, "round message to self");
                FlowReq {
                    src: self.placement.endpoints[src],
                    dst: self.placement.endpoints[dst],
                    bytes,
                    ready: snapshot[src],
                }
            })
            .collect();
        let times = self.net.transfer_batch(&reqs);
        apply_round(&mut self.t, &snapshot, msgs, &times);
    }

    /// Dissemination barrier (log2 rounds of 0-byte exchanges); every
    /// round's notifications are one concurrent batch.
    pub fn barrier(&mut self) {
        let p = self.size();
        if p <= 1 {
            return;
        }
        let mut dist = 1;
        while dist < p {
            let msgs: Vec<(usize, usize, f64)> =
                (0..p).map(|r| (r, (r + dist) % p, 0.0)).collect();
            self.round(&msgs);
            dist *= 2;
        }
        if let Some(rec) = self.record.as_mut() {
            rec.push(CommOp::SyncAll);
            return;
        }
        let tmax = self.t.iter().cloned().fold(0.0, f64::max);
        for t in self.t.iter_mut() {
            *t = tmax;
        }
    }

    /// Latest rank clock — "the collective finished at".
    pub fn max_time(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }

    /// Whether a message between ranks a and b leaves the source ToR —
    /// the engine's own classification ([`crate::fabric::topology`]),
    /// which may differ from the cluster's rack scalar when a
    /// `[topology]` table overrides `leaf_ports`.
    pub fn crosses_rack(&self, a: usize, b: usize) -> bool {
        let topo = &self.net.topology;
        topo.tor_of_node(self.placement.endpoints[a].node)
            != topo.tor_of_node(self.placement.endpoints[b].node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::{ClusterSpec, FabricKind, TransportOptions};

    fn setup(ranks: usize) -> (NetSim, Placement) {
        let cluster = ClusterSpec::txgaia();
        let placement = Placement::cores(&cluster, ranks).unwrap();
        let net = NetSim::new(
            fabric(FabricKind::OmniPath100),
            cluster,
            TransportOptions::default(),
        );
        (net, placement)
    }

    #[test]
    fn p2p_advances_receiver_more_than_sender() {
        let (mut net, placement) = setup(80);
        let mut comm = Comm::new(&mut net, &placement);
        comm.p2p(0, 79, 1e6); // cross-node
        assert!(comm.t[79] > comm.t[0]);
        assert!(comm.t[0] > 0.0, "sender pays send-side cost");
    }

    #[test]
    fn sendrecv_symmetric() {
        let (mut net, placement) = setup(80);
        let mut comm = Comm::new(&mut net, &placement);
        comm.sendrecv(0, 45, 1e5);
        assert_eq!(comm.t[0], comm.t[45]);
        assert!(comm.t[0] > 0.0);
    }

    #[test]
    fn barrier_synchronizes_clocks() {
        let (mut net, placement) = setup(16);
        let mut comm = Comm::new(&mut net, &placement);
        comm.t[3] = 1.0; // straggler
        comm.barrier();
        let t0 = comm.t[0];
        assert!(comm.t.iter().all(|&t| (t - t0).abs() < 1e-12));
        assert!(t0 >= 1.0);
    }

    #[test]
    fn with_start_respects_initial_clocks() {
        let (mut net, placement) = setup(4);
        let start = vec![0.5, 0.1, 0.2, 0.3];
        let comm = Comm::with_start(&mut net, &placement, &start);
        assert_eq!(comm.t, start);
    }

    #[test]
    fn barrier_trivial_for_one_rank() {
        let (mut net, placement) = setup(1);
        let mut comm = Comm::new(&mut net, &placement);
        comm.barrier();
        assert_eq!(comm.t[0], 0.0);
    }

    #[test]
    fn rendezvous_waits_for_receiver_post() {
        // Large (rendezvous-sized) message to a busy receiver: the payload
        // cannot move before the receiver's clock, so the *sender* is held
        // past the receiver's recv-post time too.
        let (mut net, placement) = setup(80);
        let big = 2.0 * net.fabric.eager_threshold;
        let mut comm = Comm::new(&mut net, &placement);
        comm.t[79] = 1.0; // receiver busy until t=1
        comm.p2p(0, 79, big);
        assert!(comm.t[0] >= 1.0, "rendezvous sender released at {} < recv post", comm.t[0]);
        assert!(comm.t[79] > 1.0);
    }

    #[test]
    fn eager_message_is_sender_gated() {
        // Small (eager) message: the sender fires immediately regardless
        // of the receiver's clock; the receiver keeps its later clock.
        let (mut net, placement) = setup(80);
        let small = 64.0; // well below every preset's eager threshold
        let mut comm = Comm::new(&mut net, &placement);
        comm.t[79] = 1.0;
        comm.p2p(0, 79, small);
        assert!(comm.t[0] < 1e-3, "eager sender must not wait: {}", comm.t[0]);
        assert_eq!(comm.t[79], 1.0);
    }

    #[test]
    fn rendezvous_threshold_override_respected() {
        let cluster = ClusterSpec::txgaia();
        let placement = Placement::cores(&cluster, 80).unwrap();
        let opts = TransportOptions {
            rendezvous_threshold: Some(1e12), // everything eager
            ..Default::default()
        };
        let mut net = NetSim::new(fabric(FabricKind::OmniPath100), cluster, opts);
        let big = 1e8;
        let mut comm = Comm::new(&mut net, &placement);
        comm.t[79] = 10.0;
        comm.p2p(0, 79, big);
        assert!(comm.t[0] < 10.0, "override must keep the transfer eager");
    }

    #[test]
    fn retry_policy_schedule_is_exponential() {
        let p = RetryPolicy { timeout: 1e-3, backoff: 2.0, max_retries: 4 };
        assert_eq!(p.wait(0), 1e-3);
        assert_eq!(p.wait(2), 4e-3);
        assert!((p.probe_offset(2) - 7e-3).abs() < 1e-15);
        assert!((p.total_window() - 15e-3).abs() < 1e-15);
        // Path recovers at +2.5ms: probes at +1, +3 ms -> probe 1 lands.
        let (k, at) = p.first_probe_at(10.0, 10.0025).unwrap();
        assert_eq!(k, 1);
        assert!((at - 10.003).abs() < 1e-12);
        // Instant recovery still pays one timeout.
        let (k, at) = p.first_probe_at(10.0, 10.0).unwrap();
        assert_eq!(k, 0);
        assert!((at - 10.001).abs() < 1e-12);
        // Recovery after the window: no probe reaches it.
        assert!(p.first_probe_at(10.0, 10.1).is_none());
    }

    #[test]
    fn retry_policy_from_opts_mirrors_transport_knobs() {
        let opts = TransportOptions {
            retry_timeout: 2e-3,
            retry_backoff: 3.0,
            max_retries: 5,
            ..Default::default()
        };
        let p = RetryPolicy::from_opts(&opts);
        assert_eq!(p.timeout, 2e-3);
        assert_eq!(p.backoff, 3.0);
        assert_eq!(p.max_retries, 5);
    }

    #[test]
    fn recorder_captures_schedule_without_time() {
        let (mut net, placement) = setup(8);
        let mut comm = Comm::recorder(&mut net, &placement);
        comm.p2p(0, 1, 100.0);
        comm.sendrecv(2, 3, 50.0);
        comm.round(&[(0, 4, 10.0), (1, 5, 10.0)]);
        comm.barrier();
        assert!(comm.t.iter().all(|&t| t == 0.0), "recording must not advance clocks");
        let ops = comm.take_record().unwrap();
        assert!(matches!(ops[0], CommOp::P2p(0, 1, _)));
        assert!(matches!(ops[1], CommOp::Sendrecv(2, 3, _)));
        assert!(matches!(ops[2], CommOp::Round(ref m) if m.len() == 2));
        // Barrier = log2(8) notification rounds + the final clock sync.
        assert!(matches!(ops.last(), Some(CommOp::SyncAll)));
        assert_eq!(ops.len(), 3 + 3 + 1);
        assert_eq!(net.stats.messages, 0, "recording must not touch the engine");
    }
}
