//! MPI-like communicator over the fabric simulator: per-rank virtual
//! clocks, point-to-point semantics, and a barrier. Collectives and the
//! CFD halo exchange are written against this layer.

use crate::cluster::Placement;
use crate::config::ClusterSpec;
use crate::fabric::sim::FlowReq;
use crate::fabric::NetSim;

/// A communicator: placement + one virtual clock per rank.
pub struct Comm<'a> {
    pub net: &'a mut NetSim,
    pub placement: &'a Placement,
    /// Virtual time at which each rank is next free.
    pub t: Vec<f64>,
}

impl<'a> Comm<'a> {
    pub fn new(net: &'a mut NetSim, placement: &'a Placement) -> Self {
        let n = placement.len();
        Comm { net, placement, t: vec![0.0; n] }
    }

    /// Start every rank's clock at the given times (e.g. staggered compute
    /// completion for comm/compute overlap studies).
    pub fn with_start(net: &'a mut NetSim, placement: &'a Placement, start: &[f64]) -> Self {
        assert_eq!(start.len(), placement.len());
        Comm { net, placement, t: start.to_vec() }
    }

    pub fn size(&self) -> usize {
        self.placement.len()
    }

    /// Blocking send/recv pair: the receiver's clock advances to message
    /// completion; the sender's clock advances past its send-side cost.
    /// (Matches MPI_Send/MPI_Recv with an eager/rendezvous transport.)
    pub fn p2p(&mut self, src: usize, dst: usize, bytes: f64) {
        assert_ne!(src, dst, "p2p to self");
        let ready = self.t[src]; // sender-gated
        let (send_release, recv_complete) = self.net.message(
            self.placement.endpoints[src],
            self.placement.endpoints[dst],
            bytes,
            ready,
        );
        self.t[src] = self.t[src].max(send_release);
        // Receiver must have posted the recv: completion can't precede its
        // own clock.
        self.t[dst] = self.t[dst].max(recv_complete);
    }

    /// Simultaneous exchange (MPI_Sendrecv): both ranks send `bytes` to
    /// each other; both clocks advance to the later completion. The two
    /// flows are submitted as one event-engine batch, so they genuinely
    /// overlap in virtual time (full duplex on disjoint tx/rx ports).
    pub fn sendrecv(&mut self, a: usize, b: usize, bytes: f64) {
        assert_ne!(a, b, "sendrecv with self");
        let ready = self.t[a].max(self.t[b]);
        let times = self.net.transfer_batch(&[
            FlowReq {
                src: self.placement.endpoints[a],
                dst: self.placement.endpoints[b],
                bytes,
                ready,
            },
            FlowReq {
                src: self.placement.endpoints[b],
                dst: self.placement.endpoints[a],
                bytes,
                ready,
            },
        ]);
        let done = times[0].recv_complete.max(times[1].recv_complete);
        self.t[a] = done;
        self.t[b] = done;
    }

    /// A synchronized communication round: all messages see the rank
    /// clocks as they were when the round started (every rank sends and
    /// receives simultaneously, as in a ring step) and are submitted to
    /// the event engine as ONE batch — concurrently in-flight flows share
    /// NIC ports and rack up-links max-min fairly instead of paying the
    /// old scalar congestion estimate.
    pub fn round(&mut self, msgs: &[(usize, usize, f64)]) {
        let snapshot = self.t.clone();
        let reqs: Vec<FlowReq> = msgs
            .iter()
            .map(|&(src, dst, bytes)| {
                assert_ne!(src, dst, "round message to self");
                FlowReq {
                    src: self.placement.endpoints[src],
                    dst: self.placement.endpoints[dst],
                    bytes,
                    ready: snapshot[src],
                }
            })
            .collect();
        let times = self.net.transfer_batch(&reqs);
        let mut new_t = snapshot.clone();
        for (&(src, dst, _), ft) in msgs.iter().zip(&times) {
            new_t[src] = new_t[src].max(ft.send_release);
            new_t[dst] = new_t[dst].max(ft.recv_complete.max(snapshot[dst]));
        }
        self.t = new_t;
    }

    /// Dissemination barrier (log2 rounds of 0-byte exchanges); every
    /// round's notifications are one concurrent batch.
    pub fn barrier(&mut self) {
        let p = self.size();
        if p <= 1 {
            return;
        }
        let mut dist = 1;
        while dist < p {
            let msgs: Vec<(usize, usize, f64)> =
                (0..p).map(|r| (r, (r + dist) % p, 0.0)).collect();
            self.round(&msgs);
            dist *= 2;
        }
        let tmax = self.t.iter().cloned().fold(0.0, f64::max);
        for t in self.t.iter_mut() {
            *t = tmax;
        }
    }

    /// Latest rank clock — "the collective finished at".
    pub fn max_time(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }

    /// Whether ranks a and b are in different racks.
    pub fn crosses_rack(&self, cluster: &ClusterSpec, a: usize, b: usize) -> bool {
        self.placement.crosses_rack(cluster, a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::{ClusterSpec, FabricKind, TransportOptions};

    fn setup(ranks: usize) -> (NetSim, Placement) {
        let cluster = ClusterSpec::txgaia();
        let placement = Placement::cores(&cluster, ranks).unwrap();
        let net = NetSim::new(
            fabric(FabricKind::OmniPath100),
            cluster,
            TransportOptions::default(),
        );
        (net, placement)
    }

    #[test]
    fn p2p_advances_receiver_more_than_sender() {
        let (mut net, placement) = setup(80);
        let mut comm = Comm::new(&mut net, &placement);
        comm.p2p(0, 79, 1e6); // cross-node
        assert!(comm.t[79] > comm.t[0]);
        assert!(comm.t[0] > 0.0, "sender pays send-side cost");
    }

    #[test]
    fn sendrecv_symmetric() {
        let (mut net, placement) = setup(80);
        let mut comm = Comm::new(&mut net, &placement);
        comm.sendrecv(0, 45, 1e5);
        assert_eq!(comm.t[0], comm.t[45]);
        assert!(comm.t[0] > 0.0);
    }

    #[test]
    fn barrier_synchronizes_clocks() {
        let (mut net, placement) = setup(16);
        let mut comm = Comm::new(&mut net, &placement);
        comm.t[3] = 1.0; // straggler
        comm.barrier();
        let t0 = comm.t[0];
        assert!(comm.t.iter().all(|&t| (t - t0).abs() < 1e-12));
        assert!(t0 >= 1.0);
    }

    #[test]
    fn with_start_respects_initial_clocks() {
        let (mut net, placement) = setup(4);
        let start = vec![0.5, 0.1, 0.2, 0.3];
        let comm = Comm::with_start(&mut net, &placement, &start);
        assert_eq!(comm.t, start);
    }

    #[test]
    fn barrier_trivial_for_one_rank() {
        let (mut net, placement) = setup(1);
        let mut comm = Comm::new(&mut net, &placement);
        comm.barrier();
        assert_eq!(comm.t[0], 0.0);
    }
}
