//! Command-line argument parsing (no external crates): subcommand plus
//! `--flag`, `--key value` and `--key=value` options.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut options = BTreeMap::new();
        let mut positional = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else {
                    // Value-taking if the next token isn't another flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            // peek() just said Some; if that invariant
                            // ever breaks, fail loudly instead of
                            // panicking on unwrap.
                            let Some(v) = it.next() else {
                                bail!("--{stripped}: expected a value but the argument list ended");
                            };
                            options.insert(stripped.to_string(), v);
                        }
                        _ => {
                            options.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                positional.push(arg);
            }
        }
        Ok(Args { command, options, positional })
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        matches!(self.options.get(name).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// A string option restricted to a fixed value set (e.g.
    /// `--placement pack|spread|topology`); anything else errors with
    /// the full list instead of flowing downstream as a bad string.
    pub fn get_choice(&self, name: &str, allowed: &[&str]) -> Result<Option<&str>> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) if allowed.contains(&v.as_str()) => Ok(Some(v.as_str())),
            Some(v) => {
                bail!("--{name} must be one of {}, got '{v}'", allowed.join("|"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["fig4", "--quick", "--gpus", "64", "--lr=0.1", "extra"]);
        assert_eq!(a.command, "fig4");
        assert!(a.flag("quick"));
        assert_eq!(a.get_usize("gpus", 8).unwrap(), 64);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.1);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&["table1"]);
        assert!(!a.flag("quick"));
        assert_eq!(a.get_usize("gpus", 8).unwrap(), 8);
        assert!(a.get("missing").is_none());
    }

    #[test]
    fn empty_is_help() {
        let a = Args::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["x", "--gpus", "lots"]);
        assert!(a.get_usize("gpus", 1).is_err());
    }

    #[test]
    fn choice_options_validate_their_set() {
        let a = parse(&["fleet", "--placement", "spread"]);
        let allowed = ["pack", "spread", "topology"];
        assert_eq!(a.get_choice("placement", &allowed).unwrap(), Some("spread"));
        assert_eq!(a.get_choice("missing", &allowed).unwrap(), None);
        let bad = parse(&["fleet", "--placement", "random"]);
        let err = bad.get_choice("placement", &allowed).unwrap_err().to_string();
        assert!(err.contains("pack|spread|topology"), "unexpected: {err}");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--quick", "--verbose"]);
        assert!(a.flag("quick"));
        assert!(a.flag("verbose"));
    }
}
