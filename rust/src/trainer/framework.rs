//! Framework execution profiles. The paper benchmarks both TensorFlow
//! (Horovod+NCCL) and PyTorch; the frameworks differ not in math but in
//! coordination machinery — fusion-buffer policy, per-collective
//! negotiation, and per-step launcher overhead. A profile bundles those
//! constants so experiments can compare "the same model under different
//! framework runtimes".

use crate::util::units::MIB;

#[derive(Clone, Debug)]
pub struct FrameworkProfile {
    pub name: &'static str,
    /// Gradient bucketing capacity.
    pub fusion_bytes: f64,
    /// Per-collective negotiation + launch cost on the comm stream.
    pub coordination_overhead: f64,
    /// Fixed per-step overhead outside compute/comm (session run, python
    /// dispatch, optimizer hooks).
    pub step_overhead: f64,
}

/// TensorFlow 1.14 + Horovod + NCCL (the paper's primary stack):
/// 64 MiB fusion, ~1 ms Horovod cycle, heavyweight session dispatch.
pub fn horovod_tf() -> FrameworkProfile {
    FrameworkProfile {
        name: "tf-horovod",
        fusion_bytes: 64.0 * MIB,
        coordination_overhead: 1.0e-3,
        step_overhead: 1.5e-3,
    }
}

/// PyTorch DistributedDataParallel: 25 MiB gradient buckets, lighter
/// autograd-hook-driven launches.
pub fn pytorch_ddp() -> FrameworkProfile {
    FrameworkProfile {
        name: "pytorch-ddp",
        fusion_bytes: 25.0 * MIB,
        coordination_overhead: 0.3e-3,
        step_overhead: 1.0e-3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_differ_in_the_right_direction() {
        let tf = horovod_tf();
        let pt = pytorch_ddp();
        assert!(tf.fusion_bytes > pt.fusion_bytes);
        assert!(tf.coordination_overhead > pt.coordination_overhead);
    }
}
