//! Synthetic labeled dataset: the input-pipeline substrate.
//!
//! The paper stored ImageNet as 1024 large TFRecord files specifically so
//! that input I/O would *not* confound the fabric comparison. We keep
//! that property by generating data deterministically in memory: class k
//! is a fixed random template plus per-sample noise — learnable by the
//! MiniCNN in a few hundred steps, shardable across data-parallel workers
//! without overlap.

use crate::util::rng::Rng;

/// Image dimensions must match python/compile/model.py (the manifest is
/// the authority at runtime; these are the defaults).
pub const IMAGE_H: usize = 16;
pub const IMAGE_W: usize = 16;
pub const IMAGE_C: usize = 3;
pub const CLASSES: usize = 10;
pub const IMAGE_ELEMS: usize = IMAGE_H * IMAGE_W * IMAGE_C;

/// Deterministic synthetic dataset generator.
pub struct SyntheticDataset {
    templates: Vec<Vec<f32>>, // CLASSES x IMAGE_ELEMS
    noise: f64,
    seed: u64,
}

impl SyntheticDataset {
    pub fn new(seed: u64, noise: f64) -> Self {
        let mut rng = Rng::new(seed ^ 0x7E3A_11CE);
        let templates = (0..CLASSES)
            .map(|_| (0..IMAGE_ELEMS).map(|_| rng.uniform() as f32).collect())
            .collect();
        SyntheticDataset { templates, noise, seed }
    }

    /// Batch `index` for `worker` of `workers`: disjoint shards — worker w
    /// sees sample stream (step, w), so no two workers train on the same
    /// batch in the same step.
    pub fn batch(
        &self,
        step: u64,
        worker: u64,
        workers: u64,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        assert!(worker < workers);
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(step * workers + worker),
        );
        let mut xs = Vec::with_capacity(batch * IMAGE_ELEMS);
        let mut ys = Vec::with_capacity(batch);
        for _ in 0..batch {
            let label = rng.below(CLASSES as u64) as usize;
            ys.push(label as i32);
            let tpl = &self.templates[label];
            for &t in tpl {
                let v = t as f64 + self.noise * rng.normal();
                xs.push(v.clamp(0.0, 1.0) as f32);
            }
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let d1 = SyntheticDataset::new(7, 0.25);
        let d2 = SyntheticDataset::new(7, 0.25);
        let (x1, y1) = d1.batch(3, 0, 4, 8);
        let (x2, y2) = d2.batch(3, 0, 4, 8);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn shards_disjoint() {
        let d = SyntheticDataset::new(7, 0.25);
        let (x0, _) = d.batch(0, 0, 4, 8);
        let (x1, _) = d.batch(0, 1, 4, 8);
        assert_ne!(x0, x1);
    }

    #[test]
    fn different_steps_differ() {
        let d = SyntheticDataset::new(7, 0.25);
        let (x0, _) = d.batch(0, 0, 1, 8);
        let (x1, _) = d.batch(1, 0, 1, 8);
        assert_ne!(x0, x1);
    }

    #[test]
    fn values_in_unit_range_and_labels_valid() {
        let d = SyntheticDataset::new(3, 0.5);
        let (x, y) = d.batch(0, 0, 1, 64);
        assert_eq!(x.len(), 64 * IMAGE_ELEMS);
        assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(y.iter().all(|&l| (0..CLASSES as i32).contains(&l)));
        // All classes appear in a large batch with overwhelming probability.
        let mut seen = [false; CLASSES];
        for &l in &y {
            seen[l as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 7);
    }

    #[test]
    fn noiseless_batch_equals_template() {
        let d = SyntheticDataset::new(11, 0.0);
        let (x, y) = d.batch(0, 0, 1, 4);
        for (i, &label) in y.iter().enumerate() {
            let img = &x[i * IMAGE_ELEMS..(i + 1) * IMAGE_ELEMS];
            assert_eq!(img, &d.templates[label as usize][..]);
        }
    }
}
