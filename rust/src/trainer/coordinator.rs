//! The data-parallel training coordinator (simulated timeline).
//!
//! Horovod-like execution per synchronous step:
//!
//! 1. every GPU computes forward+backward (compute time from the
//!    calibrated perf model, with lognormal jitter per rank);
//! 2. gradients become available *during* the backward pass in backward
//!    layer order; the fusion buffer coalesces them into buckets;
//! 3. buckets are all-reduced over the simulated fabric by the
//!    multi-stream scheduler ([`crate::trainer::scheduler`]): with
//!    `opts.num_streams == 1` collectives serialize exactly like
//!    Horovod's coordinator; with more streams, logically independent
//!    buckets overlap on the fabric like NCCL channels;
//! 4. the optimizer applies updates; the step ends when the slowest rank
//!    finishes.
//!
//! Overlap of (2) and (3) is the `overlap` knob — one of the paper-adjacent
//! ablations. Exposed communication time is measured as the union of the
//! collectives' busy intervals past the end of compute (overlapping
//! streams are not double-counted).

use crate::cluster::Placement;
use crate::collectives::{fuse, Collective, BYTES_PER_ELEM};
use crate::config::{
    ClusterSpec, FabricSpec, ParallelismKind, RunSpec, TenancySpec, TransportOptions,
    WorkloadSpec,
};
use crate::fabric::tenancy::BackgroundTraffic;
use crate::fabric::{FaultSpec, NetSim};
use crate::models::perf::{step_cost, Precision};
use crate::models::Arch;
use crate::trainer::scheduler::{self, BucketWork, SchedulerConfig};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::units::MIB;
use crate::workload;

/// Simulated trainer configuration.
pub struct TrainerSim {
    pub arch: Arch,
    pub fabric: FabricSpec,
    pub cluster: ClusterSpec,
    pub opts: TransportOptions,
    pub strategy: Box<dyn Collective>,
    pub per_gpu_batch: usize,
    pub precision: Precision,
    /// Horovod fusion buffer capacity in bytes (default 64 MiB).
    pub fusion_bytes: f64,
    /// Overlap backprop with gradient all-reduce.
    pub overlap: bool,
    /// Fixed per-step overhead outside compute and communication
    /// (framework dispatch); see [`crate::trainer::framework`].
    pub step_overhead: f64,
    /// Fixed serial cost per collective on the communication stream:
    /// Horovod's coordinator negotiation cycle + NCCL launch (Horovod's
    /// default cycle time is ~1 ms). This is what makes pathologically
    /// small fusion buffers lose, exactly as Horovod's tuning guide warns.
    pub coordination_overhead: f64,
    /// Shared-tenancy model: background cross-traffic on the fabric and
    /// compute-side stragglers. [`TenancySpec::default`] is a dedicated,
    /// homogeneous system and is bit-for-bit the pre-tenancy trainer.
    pub tenancy: TenancySpec,
    /// Parallelism strategy: how each step compiles to a
    /// [`crate::workload::WorkloadGraph`]. [`WorkloadSpec::default`]
    /// (bucketed DP) is bit-for-bit the pre-IR trainer.
    pub workload: WorkloadSpec,
    /// Fabric fault trace injected into every step's engine.
    /// [`FaultSpec::default`] (inactive) is bit-for-bit the pre-fault
    /// trainer; an active spec walks its timeline across steps (the
    /// fault clock advances by each step's wall time).
    pub faults: FaultSpec,
}

/// Default per-collective coordination overhead, seconds (Horovod cycle).
pub const DEFAULT_COORDINATION_OVERHEAD: f64 = 1.0e-3;

/// Result of a throughput measurement.
#[derive(Clone, Debug)]
pub struct ThroughputResult {
    pub gpus: usize,
    pub images_per_sec: f64,
    pub step_time_mean: f64,
    pub step_time_p95: f64,
    /// Mean fraction of the step spent in non-overlapped communication.
    pub comm_fraction: f64,
    /// Ideal images/sec if scaling were perfectly linear from 1 GPU.
    pub linear_images_per_sec: f64,
    /// Mean fraction of each measured step during which at least one
    /// fabric fault was active (0.0 on a healthy fabric).
    pub fault_exposure: f64,
}

impl ThroughputResult {
    pub fn scaling_efficiency(&self) -> f64 {
        self.images_per_sec / self.linear_images_per_sec
    }
}

impl TrainerSim {
    /// Simulate training on `gpus` GPUs (block placement) and return
    /// throughput statistics.
    pub fn run(&self, gpus: usize, run: &RunSpec) -> anyhow::Result<ThroughputResult> {
        anyhow::ensure!(gpus >= 1, "need at least one GPU");
        let placement = Placement::gpus(&self.cluster, gpus)?;
        self.run_placed(&placement, run, &[])
    }

    /// Simulate training on an explicit placement, with zero or more
    /// *attributed* co-tenant traffic generators (the fleet scheduler's
    /// path: each generator is a neighbor job's traffic, keyed by a
    /// non-zero tenant id unique within the call). With a block
    /// placement and no tenants this is bit-for-bit [`TrainerSim::run`]
    /// — every RNG seed is keyed on the rank count, not the node ids.
    pub fn run_placed(
        &self,
        placement: &Placement,
        run: &RunSpec,
        tenants: &[(usize, BackgroundTraffic)],
    ) -> anyhow::Result<ThroughputResult> {
        self.run_placed_with_faults(placement, run, tenants, &self.faults)
    }

    /// [`TrainerSim::run_placed`] with an explicit fault spec overriding
    /// the trainer's own — the fleet scheduler's path, which merges the
    /// configured trace with NIC-down events for nodes inside their
    /// repair window.
    pub fn run_placed_with_faults(
        &self,
        placement: &Placement,
        run: &RunSpec,
        tenants: &[(usize, BackgroundTraffic)],
        faults: &FaultSpec,
    ) -> anyhow::Result<ThroughputResult> {
        let gpus = placement.len();
        anyhow::ensure!(gpus >= 1, "need at least one GPU");
        self.workload.validate_for_gpus(gpus)?;
        let mut net = NetSim::try_new(self.fabric.clone(), self.cluster.clone(), self.opts)?;
        net.set_faults(faults)?;
        if self.tenancy.background_active() {
            let bg = BackgroundTraffic::new(&self.tenancy, &net.fabric, &net.cluster, run.seed)?;
            net.set_background(bg);
        }
        for (id, bg) in tenants {
            net.add_tenant(*id, bg.clone());
        }
        let mut rng = Rng::new(run.seed ^ (gpus as u64) << 32 ^ self.arch.total_params());
        // Straggler model: persistent per-rank slowdowns plus (optional)
        // extra per-step jitter from a tenancy-private RNG stream — the
        // main stream's draw sequence is untouched, so a unit-slowdown
        // config is bit-identical to the pre-tenancy trainer.
        let slowdowns = self.tenancy.rank_slowdowns(gpus, run.seed);
        let mut straggler_rng = Rng::new(self.tenancy.seed ^ run.seed ^ 0x57A6_61E5);

        let cost = step_cost(
            &self.arch,
            &crate::cluster::gpu::V100,
            self.per_gpu_batch,
            self.precision,
            None,
        );
        let buckets = fuse(&self.arch.gradient_tensor_bytes(), self.fusion_bytes);

        let mut step_times = Vec::with_capacity(run.measure_steps);
        let mut comm_fracs = Vec::with_capacity(run.measure_steps);
        let mut exposures = Vec::with_capacity(run.measure_steps);
        for step in 0..run.warmup_steps + run.measure_steps {
            net.reset();
            let (step_time, comm_frac) = self.simulate_step(
                &mut net,
                placement,
                &cost,
                &buckets,
                &mut rng,
                &slowdowns,
                &mut straggler_rng,
                gpus,
            );
            if step >= run.warmup_steps {
                step_times.push(step_time);
                comm_fracs.push(comm_frac);
                exposures.push(if step_time > 0.0 {
                    net.fault_exposure(0.0, step_time) / step_time
                } else {
                    0.0
                });
            }
            // Warmup steps advance the trace too: wall time passes.
            net.advance_fault_clock(step_time);
        }

        let mean = stats::mean(&step_times);
        let single = {
            // 1-GPU reference for scaling efficiency: pure compute.
            self.per_gpu_batch as f64 / cost.total()
        };
        Ok(ThroughputResult {
            gpus,
            images_per_sec: gpus as f64 * self.per_gpu_batch as f64 / mean,
            step_time_mean: mean,
            step_time_p95: stats::percentile(&step_times, 95.0),
            comm_fraction: stats::mean(&comm_fracs),
            linear_images_per_sec: single * gpus as f64,
            fault_exposure: stats::mean(&exposures),
        })
    }

    /// One synchronous step; returns (step_time, comm_fraction).
    #[allow(clippy::too_many_arguments)]
    fn simulate_step(
        &self,
        net: &mut NetSim,
        placement: &Placement,
        cost: &crate::models::perf::StepCost,
        buckets: &[crate::collectives::Bucket],
        rng: &mut Rng,
        slowdowns: &[f64],
        straggler_rng: &mut Rng,
        gpus: usize,
    ) -> (f64, f64) {
        // Per-rank compute times: baseline jitter, scaled by the tenancy
        // model's persistent slowdown and (when configured) extra
        // per-step straggler jitter. Both multipliers are exactly 1.0 on
        // a homogeneous system (and the extra draw is skipped entirely),
        // so the dedicated path stays bit-identical.
        let sigma = self.tenancy.straggler_jitter;
        let jitter: Vec<f64> = (0..gpus)
            .map(|r| {
                let extra =
                    if sigma > 0.0 { straggler_rng.lognormal_median(1.0, sigma) } else { 1.0 };
                rng.lognormal_median(1.0, 0.02) * slowdowns[r] * extra
            })
            .collect();
        let fwd: Vec<f64> = jitter.iter().map(|j| cost.fwd * j).collect();
        let bwd: Vec<f64> = jitter.iter().map(|j| cost.bwd * j).collect();
        let compute_done: Vec<f64> =
            fwd.iter().zip(&bwd).map(|(f, b)| f + b).collect();

        if gpus == 1 {
            return (compute_done[0] + cost.optimizer + self.step_overhead, 0.0);
        }

        let cfg = SchedulerConfig {
            num_streams: self.opts.num_streams,
            coordination_overhead: self.coordination_overhead,
            chunk_bytes: self.opts.chunk_bytes,
        };
        // Bucket b's gradients are ready on rank r at
        // fwd[r] + bwd[r] * ready_frac(b) (backward produces gradients
        // progressively). Without overlap, everything waits for compute.
        let works: Vec<BucketWork> = buckets
            .iter()
            .map(|bucket| BucketWork {
                elems: (bucket.bytes / BYTES_PER_ELEM).ceil() as usize,
                bytes: bucket.bytes,
                ready: (0..gpus)
                    .map(|r| {
                        if self.overlap {
                            fwd[r] + bwd[r] * bucket.ready_frac
                        } else {
                            compute_done[r]
                        }
                    })
                    .collect(),
            })
            .collect();
        let compute_max = compute_done.iter().cloned().fold(0.0, f64::max);

        match self.workload.parallelism {
            ParallelismKind::Dp => {
                let timeline =
                    scheduler::run_step(net, placement, self.strategy.as_ref(), &works, &cfg);
                let end = (0..gpus)
                    .map(|r| timeline.comm_done[r].max(compute_done[r]) + cost.optimizer)
                    .fold(0.0, f64::max)
                    + self.step_overhead;
                // Exposed communication: the merged busy-interval union of
                // the collectives, clipped to the region after compute
                // ends. (The old per-bucket span sum over-counted once
                // buckets overlapped across streams, and silently folded
                // coordination gaps into "comm".)
                let exposed = scheduler::exposed_after(&timeline.intervals, compute_max);
                (end, exposed / end)
            }
            ParallelismKind::Zero => {
                // ZeRO: each bucket reduce-scatters, every rank updates
                // its 1/world shard (compute node inside the graph), then
                // all-gathers the fresh parameters — the optimizer cost
                // is in-graph and must not be re-added here.
                let graph =
                    workload::lower_zero(&works, gpus, cost.optimizer, self.opts.num_streams);
                let out =
                    scheduler::execute(net, placement, self.strategy.as_ref(), &graph, &cfg);
                let end = (0..gpus)
                    .map(|r| out.done[r].max(compute_done[r]))
                    .fold(0.0, f64::max)
                    + self.step_overhead;
                let threshold =
                    compute_max.max(out.compute_done.iter().cloned().fold(0.0, f64::max));
                let exposed = scheduler::exposed_after(&out.comm_intervals, threshold);
                (end, exposed / end)
            }
            ParallelismKind::Pipeline => {
                // 1F1B: per-rank fwd/bwd costs are spread over the
                // stage × microbatch grid inside the lowering; the step's
                // compute and p2p activation traffic all live in-graph.
                let grad_elems: usize = works.iter().map(|w| w.elems).sum();
                let graph = workload::lower_pipeline(
                    gpus,
                    self.workload.pipeline_stages,
                    self.workload.microbatches,
                    &fwd,
                    &bwd,
                    self.workload.activation_mib * MIB,
                    grad_elems,
                )
                .expect("workload shape validated at run start");
                let out =
                    scheduler::execute(net, placement, self.strategy.as_ref(), &graph, &cfg);
                let end = out.done.iter().cloned().fold(0.0, f64::max)
                    + cost.optimizer
                    + self.step_overhead;
                let threshold = out.compute_done.iter().cloned().fold(0.0, f64::max);
                let exposed = scheduler::exposed_after(&out.comm_intervals, threshold);
                (end, exposed / end)
            }
            ParallelismKind::Moe => {
                // MoE: expert dispatch/combine all-to-alls interleave the
                // forward and backward compute segments; the gradient
                // allreduce of every bucket waits on the backward chain.
                let bucket_elems: Vec<usize> = works.iter().map(|w| w.elems).collect();
                let a2a_elems =
                    (self.workload.moe_expert_mib * MIB / BYTES_PER_ELEM).ceil() as usize;
                let graph = workload::lower_moe(
                    gpus,
                    &fwd,
                    &bwd,
                    &bucket_elems,
                    self.workload.moe_layers,
                    a2a_elems,
                    self.opts.num_streams,
                )
                .expect("workload shape validated at run start");
                let out =
                    scheduler::execute(net, placement, self.strategy.as_ref(), &graph, &cfg);
                let end = out.done.iter().cloned().fold(0.0, f64::max)
                    + cost.optimizer
                    + self.step_overhead;
                let threshold = out.compute_done.iter().cloned().fold(0.0, f64::max);
                let exposed = scheduler::exposed_after(&out.comm_intervals, threshold);
                (end, exposed / end)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{Hierarchical, RingAllreduce};
    use crate::config::presets::fabric;
    use crate::config::spec::FabricKind;
    use crate::models::zoo::{resnet50, resnet50_v15};
    use crate::util::units::MIB;

    fn trainer(kind: FabricKind, overlap: bool) -> TrainerSim {
        TrainerSim {
            arch: resnet50(),
            fabric: fabric(kind),
            cluster: ClusterSpec::txgaia(),
            opts: TransportOptions::default(),
            strategy: Box::new(RingAllreduce),
            per_gpu_batch: 64,
            precision: Precision::Fp32,
            fusion_bytes: 64.0 * MIB,
            overlap,
            step_overhead: 0.0,
            coordination_overhead: DEFAULT_COORDINATION_OVERHEAD,
            tenancy: TenancySpec::default(),
            workload: WorkloadSpec::default(),
            faults: FaultSpec::default(),
        }
    }

    #[test]
    fn single_gpu_matches_calibration() {
        let t = trainer(FabricKind::OmniPath100, true);
        let r = t.run(1, &RunSpec::default()).unwrap();
        let want = t.arch.v100_fp32_images_per_sec;
        assert!(
            (r.images_per_sec - want).abs() / want < 0.08,
            "1-GPU {} vs calibration {}",
            r.images_per_sec,
            want
        );
    }

    #[test]
    fn throughput_increases_with_gpus() {
        let t = trainer(FabricKind::OmniPath100, true);
        let spec = RunSpec { measure_steps: 10, ..Default::default() };
        let r2 = t.run(2, &spec).unwrap();
        let r8 = t.run(8, &spec).unwrap();
        let r32 = t.run(32, &spec).unwrap();
        assert!(r8.images_per_sec > 2.0 * r2.images_per_sec);
        assert!(r32.images_per_sec > 2.0 * r8.images_per_sec);
    }

    #[test]
    fn scaling_efficiency_reasonable_at_64() {
        let t = trainer(FabricKind::OmniPath100, true);
        let spec = RunSpec { measure_steps: 8, ..Default::default() };
        let r = t.run(64, &spec).unwrap();
        let eff = r.scaling_efficiency();
        assert!(eff > 0.6 && eff <= 1.0, "efficiency {eff}");
    }

    #[test]
    fn ethernet_slower_than_opa() {
        let spec = RunSpec { measure_steps: 8, ..Default::default() };
        let eth = trainer(FabricKind::EthernetRoce25, true).run(32, &spec).unwrap();
        let opa = trainer(FabricKind::OmniPath100, true).run(32, &spec).unwrap();
        assert!(
            eth.images_per_sec < opa.images_per_sec,
            "eth {} !< opa {}",
            eth.images_per_sec,
            opa.images_per_sec
        );
    }

    #[test]
    fn overlap_helps() {
        let spec = RunSpec { measure_steps: 8, ..Default::default() };
        let with = trainer(FabricKind::EthernetRoce25, true).run(32, &spec).unwrap();
        let without = trainer(FabricKind::EthernetRoce25, false).run(32, &spec).unwrap();
        assert!(with.images_per_sec > without.images_per_sec);
    }

    #[test]
    fn hierarchical_strategy_runs() {
        let mut t = trainer(FabricKind::EthernetRoce25, true);
        t.strategy = Box::new(Hierarchical::default());
        let spec = RunSpec { measure_steps: 5, ..Default::default() };
        let r = t.run(16, &spec).unwrap();
        assert!(r.images_per_sec > 0.0);
    }

    #[test]
    fn v15_slower_than_v1_per_gpu() {
        let spec = RunSpec { measure_steps: 5, ..Default::default() };
        let mut t = trainer(FabricKind::OmniPath100, true);
        let v1 = t.run(8, &spec).unwrap();
        t.arch = resnet50_v15();
        let v15 = t.run(8, &spec).unwrap();
        assert!(v15.images_per_sec < v1.images_per_sec);
    }

    #[test]
    fn comm_fraction_grows_on_slower_fabric() {
        let spec = RunSpec { measure_steps: 8, ..Default::default() };
        let eth = trainer(FabricKind::EthernetRoce25, false).run(64, &spec).unwrap();
        let opa = trainer(FabricKind::OmniPath100, false).run(64, &spec).unwrap();
        assert!(eth.comm_fraction > opa.comm_fraction);
    }

    #[test]
    fn every_parallelism_strategy_runs_and_differs() {
        // All four lowerings execute end-to-end, and each non-DP
        // strategy's fabric pattern actually changes the step time —
        // the graphs are not decorative.
        let spec = RunSpec { measure_steps: 5, ..Default::default() };
        let mut results = Vec::new();
        for kind in ParallelismKind::all() {
            let mut t = trainer(FabricKind::EthernetRoce25, true);
            t.workload.parallelism = kind;
            let r = t.run(16, &spec).unwrap();
            assert!(r.images_per_sec > 0.0, "{} produced no throughput", kind.name());
            assert!(r.step_time_mean > 0.0);
            assert!(r.comm_fraction >= 0.0 && r.comm_fraction <= 1.0);
            results.push((kind, r.step_time_mean));
        }
        let dp = results[0].1;
        for (kind, t) in &results[1..] {
            assert_ne!(
                t.to_bits(),
                dp.to_bits(),
                "{} step time identical to DP — lowering not exercised",
                kind.name()
            );
        }
    }

    #[test]
    fn pipeline_shape_mismatch_is_a_loud_error() {
        let mut t = trainer(FabricKind::EthernetRoce25, true);
        t.workload.parallelism = ParallelismKind::Pipeline;
        t.workload.pipeline_stages = 4;
        let spec = RunSpec { measure_steps: 2, ..Default::default() };
        assert!(t.run(6, &spec).is_err(), "6 GPUs over 4 stages must be rejected");
        assert!(t.run(8, &spec).is_ok());
    }

    #[test]
    fn zero_matches_dp_compute_but_changes_comm() {
        // Same model, same compute draws: ZeRO replaces each bucket's
        // allreduce with reduce-scatter + sharded update + all-gather,
        // so exposed communication must differ from DP's.
        let spec = RunSpec { measure_steps: 6, ..Default::default() };
        let dp = trainer(FabricKind::EthernetRoce25, true).run(32, &spec).unwrap();
        let mut t = trainer(FabricKind::EthernetRoce25, true);
        t.workload.parallelism = ParallelismKind::Zero;
        let zero = t.run(32, &spec).unwrap();
        assert_ne!(
            zero.comm_fraction.to_bits(),
            dp.comm_fraction.to_bits(),
            "ZeRO comm profile must differ from DP"
        );
    }

    #[test]
    fn persistent_stragglers_slow_the_step() {
        let spec = RunSpec { measure_steps: 6, ..Default::default() };
        let base = trainer(FabricKind::OmniPath100, true).run(16, &spec).unwrap();
        let mut t = trainer(FabricKind::OmniPath100, true);
        t.tenancy.straggler_frac = 0.25;
        t.tenancy.straggler_factor = 1.5;
        let slow = t.run(16, &spec).unwrap();
        // A synchronous step ends with its slowest rank: one persistent
        // 1.5x rank stretches every step's compute floor.
        assert!(
            slow.step_time_mean > 1.2 * base.step_time_mean,
            "stragglers must stretch the step: {} vs {}",
            slow.step_time_mean,
            base.step_time_mean
        );
    }

    #[test]
    fn straggler_jitter_widens_the_tail() {
        let spec = RunSpec { measure_steps: 12, ..Default::default() };
        let base = trainer(FabricKind::OmniPath100, true).run(16, &spec).unwrap();
        let mut t = trainer(FabricKind::OmniPath100, true);
        t.tenancy.straggler_jitter = 0.15;
        let noisy = t.run(16, &spec).unwrap();
        let tail = |r: &ThroughputResult| r.step_time_p95 / r.step_time_mean;
        assert!(
            tail(&noisy) > tail(&base),
            "extra jitter must widen p95/mean: {} vs {}",
            tail(&noisy),
            tail(&base)
        );
    }
}
