//! The REAL data-parallel training path (end-to-end validation, E7).
//!
//! Each simulated worker runs the **actual AOT-compiled JAX/Pallas
//! `train_step`** on its own shard of the synthetic dataset through PJRT;
//! gradients are **really all-reduced** (f32 arithmetic through the same
//! collective code the timing experiments use, over the simulated fabric,
//! which also yields the virtual communication time); the averaged
//! gradient feeds the AOT `sgd_update`. Loss curves and accuracy come out
//! the other end — if any layer of the stack (Pallas kernel, JAX model,
//! HLO interchange, PJRT runtime, collective arithmetic) were wrong, this
//! would not converge.

use crate::cluster::Placement;
use crate::collectives::{Collective, RealBuffers, RingAllreduce};
use crate::config::{ClusterSpec, FabricSpec, TransportOptions};
use crate::fabric::{Comm, NetSim};
use crate::runtime::engine::{Engine, Executable, Input};
use crate::trainer::data::{SyntheticDataset, CLASSES, IMAGE_ELEMS};
use anyhow::Result;
use std::time::Instant;

pub struct RealTrainer {
    pub engine: Engine,
    train_step: Executable,
    sgd_update: Executable,
    predict: Executable,
    /// Current parameters, one Vec per tensor (manifest order).
    pub params: Vec<Vec<f32>>,
    param_shapes: Vec<Vec<usize>>,
    batch: usize,
}

/// Everything the E2E driver reports.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub workers: usize,
    pub steps: usize,
    /// Mean worker loss per step.
    pub losses: Vec<f64>,
    /// Wall-clock images/second (real compute on this machine).
    pub images_per_sec_wall: f64,
    /// Total simulated fabric time spent in gradient all-reduce.
    pub virtual_comm_time: f64,
    /// Accuracy on a held-out synthetic batch after training.
    pub final_accuracy: f64,
}

impl TrainReport {
    /// Last recorded step loss — a loud error (never a panic) on an
    /// empty loss curve, which `train` forbids but hand-built or
    /// deserialized reports may carry.
    pub fn final_loss(&self) -> Result<f64> {
        self.losses.last().copied().ok_or_else(|| {
            anyhow::anyhow!(
                "training report has no losses ({} steps recorded); \
                 nothing to report as a final loss",
                self.steps
            )
        })
    }
}

impl RealTrainer {
    pub fn new(engine: Engine) -> Result<RealTrainer> {
        let train_step = engine.compile("train_step")?;
        let sgd_update = engine.compile("sgd_update")?;
        let predict = engine.compile("predict")?;
        let manifest = &engine.manifest;
        let dir = engine.dir.clone();
        let params = manifest.load_init_params(&dir)?;
        let param_shapes: Vec<Vec<usize>> =
            manifest.params.iter().map(|p| p.shape.clone()).collect();
        let batch = manifest.batch;
        let image_elems: usize = manifest.image.iter().product();
        anyhow::ensure!(image_elems == IMAGE_ELEMS, "manifest image mismatch");
        anyhow::ensure!(manifest.classes == CLASSES, "manifest classes mismatch");
        Ok(RealTrainer {
            engine,
            train_step,
            sgd_update,
            predict,
            params,
            param_shapes,
            batch,
        })
    }

    fn param_inputs<'a>(&'a self) -> Vec<Input<'a>> {
        self.params
            .iter()
            .zip(&self.param_shapes)
            .map(|(p, s)| Input::F32(p, s))
            .collect()
    }

    /// One worker's (loss, per-tensor gradients).
    fn worker_step(&self, x: &[f32], y: &[i32]) -> Result<(f64, Vec<Vec<f32>>)> {
        let mut inputs = self.param_inputs();
        let img_shape = [
            self.batch,
            self.engine.manifest.image[0],
            self.engine.manifest.image[1],
            self.engine.manifest.image[2],
        ];
        let label_shape = [self.batch];
        inputs.push(Input::F32(x, &img_shape));
        inputs.push(Input::I32(y, &label_shape));
        let mut out = self.train_step.run(&inputs)?;
        let loss = out.remove(0)[0] as f64;
        Ok((loss, out))
    }

    /// Apply averaged gradients via the AOT fused-SGD artifact.
    fn apply(&mut self, grads: &[Vec<f32>], lr: f32) -> Result<()> {
        let mut inputs = self.param_inputs();
        for (g, s) in grads.iter().zip(&self.param_shapes) {
            inputs.push(Input::F32(g, s));
        }
        inputs.push(Input::ScalarF32(lr));
        let new_params = self.sgd_update.run(&inputs)?;
        self.params = new_params;
        Ok(())
    }

    /// Accuracy on a held-out batch.
    pub fn evaluate(&self, dataset: &SyntheticDataset, seed_step: u64) -> Result<f64> {
        let (x, y) = dataset.batch(seed_step, 0, 1, self.batch);
        let mut inputs = self.param_inputs();
        let img_shape = [
            self.batch,
            self.engine.manifest.image[0],
            self.engine.manifest.image[1],
            self.engine.manifest.image[2],
        ];
        inputs.push(Input::F32(&x, &img_shape));
        let logits = &self.predict.run(&inputs)?[0];
        let classes = self.engine.manifest.classes;
        let mut correct = 0usize;
        for (i, &label) in y.iter().enumerate() {
            let row = &logits[i * classes..(i + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if pred == label as usize {
                correct += 1;
            }
        }
        Ok(correct as f64 / y.len() as f64)
    }

    /// Train for `steps` synchronous steps across `workers` data-parallel
    /// workers. Gradient exchange uses a real ring all-reduce whose
    /// communication time is charged to the given fabric.
    pub fn train(
        &mut self,
        workers: usize,
        steps: usize,
        lr: f32,
        fabric: &FabricSpec,
        log_every: Option<usize>,
    ) -> Result<TrainReport> {
        anyhow::ensure!(workers >= 1, "need at least one worker");
        anyhow::ensure!(
            steps >= 1,
            "need at least one training step (a zero-step run has no loss curve)"
        );
        let cluster = ClusterSpec::txgaia();
        let placement = Placement::gpus(&cluster, workers)?;
        let mut net = NetSim::try_new(fabric.clone(), cluster, TransportOptions::default())?;
        let dataset = SyntheticDataset::new(0xDA7A, 0.25);
        let n_tensors = self.params.len();
        let flat_len: usize = self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum();

        let mut losses = Vec::with_capacity(steps);
        let mut virtual_comm = 0.0f64;
        let wall = Instant::now();
        for step in 0..steps {
            // 1. Real compute on every worker's shard.
            let mut worker_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(workers);
            let mut loss_sum = 0.0;
            for w in 0..workers {
                let (x, y) = dataset.batch(step as u64, w as u64, workers as u64, self.batch);
                let (loss, grads) = self.worker_step(&x, &y)?;
                loss_sum += loss;
                worker_grads.push(grads);
            }
            losses.push(loss_sum / workers as f64);

            // 2. Real ring all-reduce of the flattened gradients, timed on
            // the simulated fabric.
            let avg = if workers > 1 {
                let flat: Vec<Vec<f32>> = worker_grads
                    .iter()
                    .map(|gs| {
                        let mut v = Vec::with_capacity(flat_len);
                        for g in gs {
                            v.extend_from_slice(g);
                        }
                        v
                    })
                    .collect();
                net.reset();
                let mut bufs = RealBuffers::new(flat);
                let mut comm = Comm::new(&mut net, &placement);
                virtual_comm += RingAllreduce.allreduce(&mut comm, &mut bufs);
                // Unflatten rank 0's summed buffer, averaging.
                let inv = 1.0 / workers as f32;
                let summed = &bufs.data[0];
                let mut out = Vec::with_capacity(n_tensors);
                let mut off = 0;
                for s in &self.param_shapes {
                    let n: usize = s.iter().product();
                    out.push(summed[off..off + n].iter().map(|v| v * inv).collect());
                    off += n;
                }
                out
            } else {
                worker_grads.pop().unwrap()
            };

            // 3. Real fused-SGD parameter update.
            self.apply(&avg, lr)?;

            if let Some(every) = log_every {
                if step % every == 0 || step + 1 == steps {
                    eprintln!(
                        "step {step:4}  loss {:.4}  (virtual comm {:.3} ms total)",
                        losses[step],
                        virtual_comm * 1e3
                    );
                }
            }
        }
        let elapsed = wall.elapsed().as_secs_f64();
        let final_accuracy = self.evaluate(&dataset, 999_983)?;
        Ok(TrainReport {
            workers,
            steps,
            losses,
            images_per_sec_wall: (workers * steps * self.batch) as f64 / elapsed,
            virtual_comm_time: virtual_comm,
            final_accuracy,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::fabric;
    use crate::config::spec::FabricKind;

    fn engine() -> Option<Engine> {
        crate::runtime::artifacts_dir().map(|d| Engine::load(&d).unwrap())
    }

    // These tests exercise the full three-layer stack and only run when
    // `make artifacts` has produced the AOT outputs.

    #[test]
    fn loss_decreases_over_real_training() {
        let Some(engine) = engine() else { return };
        let mut t = RealTrainer::new(engine).unwrap();
        let report = t
            .train(2, 12, 0.1, &fabric(FabricKind::OmniPath100), None)
            .unwrap();
        let first = report.losses[0];
        let last = *report.losses.last().unwrap();
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        assert!(report.virtual_comm_time > 0.0);
    }

    #[test]
    fn final_loss_is_loud_on_empty_curve() {
        // No engine needed: this is pure report plumbing. A zero-step
        // report used to panic the CLI summary via losses.last().unwrap().
        let empty = TrainReport {
            workers: 2,
            steps: 0,
            losses: vec![],
            images_per_sec_wall: 0.0,
            virtual_comm_time: 0.0,
            final_accuracy: 0.0,
        };
        let err = empty.final_loss().unwrap_err().to_string();
        assert!(err.contains("no losses"), "unhelpful error: {err}");
        let ok = TrainReport { losses: vec![2.0, 1.5], steps: 2, ..empty };
        assert_eq!(ok.final_loss().unwrap(), 1.5);
    }

    #[test]
    fn zero_step_training_is_rejected() {
        let Some(engine) = engine() else { return };
        let mut t = RealTrainer::new(engine).unwrap();
        let err = t
            .train(2, 0, 0.1, &fabric(FabricKind::EthernetRoce25), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("at least one training step"), "{err}");
    }

    #[test]
    fn single_worker_training_works() {
        let Some(engine) = engine() else { return };
        let mut t = RealTrainer::new(engine).unwrap();
        let report = t
            .train(1, 5, 0.1, &fabric(FabricKind::EthernetRoce25), None)
            .unwrap();
        assert_eq!(report.losses.len(), 5);
        assert_eq!(report.virtual_comm_time, 0.0);
    }

    #[test]
    fn gradient_allreduce_equivalent_to_large_batch() {
        // 2 workers with synchronized averaging must track a run whose
        // per-step loss uses the same data — sanity that the distributed
        // math is what SGD expects (losses differ across shards but the
        // parameter trajectory must stay finite and learning).
        let Some(engine) = engine() else { return };
        let mut t = RealTrainer::new(engine).unwrap();
        let report = t
            .train(4, 6, 0.08, &fabric(FabricKind::OmniPath100), None)
            .unwrap();
        assert!(report.losses.iter().all(|l| l.is_finite()));
        for p in &t.params {
            assert!(p.iter().all(|v| v.is_finite()));
        }
    }
}
