//! Multi-stream overlap scheduler: the trainer's communication subsystem.
//!
//! Horovod's coordinator serializes every fused bucket on one
//! communication stream; NCCL splits an all-reduce across several
//! *channels* and Horovod runs negotiation *cycles* that launch multiple
//! collectives in flight. How much of the gradient exchange hides behind
//! backprop depends directly on that concurrency (Awan et al. 2018, Shi
//! et al. 2018) — so the simulator must be able to express it.
//!
//! [`run_step`] schedules the step's fusion buckets over
//! `num_streams` concurrent collective channels:
//!
//! * buckets are assigned to streams **round-robin** in backward
//!   (readiness) order, exactly like NCCL channel assignment;
//! * each stream keeps its own per-rank virtual clocks; a bucket starts on
//!   its stream at `max(gradient_ready, stream_free) +
//!   coordination_overhead` (the shared Horovod negotiation cycle is paid
//!   per collective launch, as in the serialized coordinator);
//! * with one stream the scheduler **is** the serialized coordinator —
//!   the same `Comm::with_start` + `allreduce` loop, bit for bit;
//! * with several streams, each collective's message schedule is captured
//!   once per bucket size with a recording [`Comm`] and *replayed*: at
//!   every scheduling step the next rounds of all streams that are ready
//!   within [`STREAM_MERGE_WINDOW`] of each other are submitted to the
//!   event engine as **one batch with heterogeneous ready times**, so
//!   concurrent buckets genuinely contend for NIC ports and rack up-links
//!   (max-min fair sharing) instead of queueing behind each other;
//! * buckets larger than `chunk_bytes` (when set) are chunk-pipelined:
//!   split into back-to-back sub-collectives on their stream — NCCL's
//!   segmentation trick (see [`crate::collectives::PipelinedRing`]). The
//!   chunks are one logical launch: only the first pays the
//!   coordination cycle, so segmentation costs extra per-round latency
//!   terms only (finer-grained scheduling for future scenarios, e.g.
//!   priority preemption), never extra negotiation.
//!
//! Streams whose next rounds are further apart than the merge window run
//! through the engine sequentially and contend via per-resource
//! `busy_until` carry-over (FIFO drain), which keeps resource time
//! ordering physical when one stream is far ahead of another.

use crate::cluster::Placement;
use crate::collectives::{chunk_ranges, Collective, NullBuffers, BYTES_PER_ELEM};
use crate::fabric::mpi::{apply_round, is_rendezvous, CommOp};
use crate::fabric::sim::FlowReq;
use crate::fabric::{Comm, NetSim};
use std::collections::VecDeque;

/// Streams whose next rounds start within this window (seconds) of each
/// other are merged into one event-engine batch and share bandwidth
/// max-min fairly; wider gaps fall back to FIFO resource carry-over.
pub const STREAM_MERGE_WINDOW: f64 = 2.5e-4;

/// Scheduler knobs (threaded from [`crate::config::TransportOptions`]).
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Concurrent collective channels; 1 = serialized coordinator.
    pub num_streams: usize,
    /// Fixed serial cost per collective launch (Horovod cycle + NCCL
    /// launch), seconds.
    pub coordination_overhead: f64,
    /// Chunk-pipeline buckets above this many bytes; `None` disables.
    pub chunk_bytes: Option<f64>,
}

/// One fusion bucket as the scheduler sees it.
#[derive(Clone, Debug)]
pub struct BucketWork {
    /// Elements all-reduced by this bucket.
    pub elems: usize,
    /// Bytes on the wire (`elems * BYTES_PER_ELEM`, up to rounding).
    pub bytes: f64,
    /// Per-rank time at which this bucket's gradients are available.
    pub ready: Vec<f64>,
}

/// The communication timeline of one training step.
#[derive(Clone, Debug)]
pub struct StepTimeline {
    /// Per-rank completion time of the rank's last collective.
    pub comm_done: Vec<f64>,
    /// Per-collective global busy interval `[max start, max done]` (one
    /// entry per scheduled work item; chunking may produce more items
    /// than input buckets).
    pub intervals: Vec<(f64, f64)>,
}

/// Total communication time not hidden under compute: the measure of the
/// union of the busy intervals clipped to `(threshold, inf)`. Replaces
/// the serialized coordinator's `sum(span)` + clamp estimate, which
/// double-counts once buckets overlap across streams.
pub fn exposed_after(intervals: &[(f64, f64)], threshold: f64) -> f64 {
    let mut iv: Vec<(f64, f64)> = intervals
        .iter()
        .map(|&(s, e)| (s.max(threshold), e))
        .filter(|&(s, e)| e > s)
        .collect();
    iv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (s, e) in iv {
        match cur {
            Some((cs, ce)) if s <= ce => cur = Some((cs, ce.max(e))),
            Some((cs, ce)) => {
                total += ce - cs;
                cur = Some((s, e));
            }
            None => cur = Some((s, e)),
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

/// Split buckets larger than `chunk_bytes` into back-to-back sub-buckets
/// (NCCL-style segmentation). The returned flag marks the first chunk of
/// each bucket: the chunks are one logical collective launch, so only
/// the first pays the coordination cycle — segmentation costs extra
/// per-round latency terms, never extra negotiation. `None` returns the
/// input unchanged (every bucket its own launch).
fn split_chunks(buckets: &[BucketWork], chunk_bytes: Option<f64>) -> Vec<(BucketWork, bool)> {
    let Some(limit) = chunk_bytes else {
        return buckets.iter().map(|b| (b.clone(), true)).collect();
    };
    let mut out = Vec::with_capacity(buckets.len());
    for b in buckets {
        let parts = (b.bytes / limit).ceil().max(1.0) as usize;
        if parts <= 1 || b.elems < 2 {
            out.push((b.clone(), true));
            continue;
        }
        for (i, range) in chunk_ranges(b.elems, parts.min(b.elems)).into_iter().enumerate() {
            out.push((
                BucketWork {
                    elems: range.len(),
                    bytes: range.len() as f64 * BYTES_PER_ELEM,
                    ready: b.ready.clone(),
                },
                i == 0,
            ));
        }
    }
    out
}

/// Schedule one step's buckets over the fabric; returns the timeline.
pub fn run_step(
    net: &mut NetSim,
    placement: &Placement,
    strategy: &dyn Collective,
    buckets: &[BucketWork],
    cfg: &SchedulerConfig,
) -> StepTimeline {
    if cfg.num_streams <= 1 {
        let works = split_chunks(buckets, cfg.chunk_bytes);
        run_serialized(net, placement, strategy, &works, cfg)
    } else {
        run_multi_stream(net, placement, strategy, buckets, cfg)
    }
}

/// The serialized (single-stream) coordinator: each collective starts
/// only after the previous one finished on every rank. This is the exact
/// pre-scheduler trainer loop and the `num_streams = 1` baseline the
/// property tests pin bit-for-bit.
fn run_serialized(
    net: &mut NetSim,
    placement: &Placement,
    strategy: &dyn Collective,
    works: &[(BucketWork, bool)],
    cfg: &SchedulerConfig,
) -> StepTimeline {
    let p = placement.len();
    let mut prev_done: Vec<f64> = vec![0.0; p];
    let mut comm_done: Vec<f64> = vec![0.0; p];
    let mut intervals = Vec::with_capacity(works.len());
    for (work, launch) in works {
        let coord = if *launch { cfg.coordination_overhead } else { 0.0 };
        let start: Vec<f64> = (0..p)
            .map(|r| work.ready[r].max(prev_done[r]) + coord)
            .collect();
        let mut comm = Comm::with_start(net, placement, &start);
        let mut bufs = NullBuffers { elems: work.elems };
        strategy.allreduce(&mut comm, &mut bufs);
        comm_done.copy_from_slice(&comm.t);
        prev_done.copy_from_slice(&comm.t);
        let max_start = start.iter().cloned().fold(0.0, f64::max);
        let max_done = comm_done.iter().cloned().fold(0.0, f64::max);
        intervals.push((max_start, max_done));
    }
    StepTimeline { comm_done, intervals }
}

/// One queued scheduling action on a stream.
#[derive(Clone, Copy, Debug)]
enum Item {
    /// Start work item `w`: fold its ready times into the stream clocks;
    /// `launch` marks a fresh collective launch (pays the coordination
    /// cycle) as opposed to a follow-on chunk of the same launch.
    Begin { w: usize, launch: bool },
    /// Execute op `op` of work item `w`'s recorded schedule.
    Op { w: usize, op: usize },
    /// Work item `w` finished: record its busy interval.
    End(usize),
}

fn run_multi_stream(
    net: &mut NetSim,
    placement: &Placement,
    strategy: &dyn Collective,
    buckets: &[BucketWork],
    cfg: &SchedulerConfig,
) -> StepTimeline {
    let p = placement.len();
    // Streams are assigned per *bucket* (round-robin, like NCCL
    // channels); chunking then expands a bucket into consecutive work
    // items that stay back-to-back on the bucket's stream.
    let s_count = cfg.num_streams.min(buckets.len().max(1));
    let mut works: Vec<BucketWork> = Vec::new();
    let mut launch_of: Vec<bool> = Vec::new();
    let mut stream_of: Vec<usize> = Vec::new();
    for (b, bucket) in buckets.iter().enumerate() {
        for (chunk, launch) in split_chunks(std::slice::from_ref(bucket), cfg.chunk_bytes) {
            works.push(chunk);
            launch_of.push(launch);
            stream_of.push(b % s_count);
        }
    }

    // Capture each distinct bucket size's schedule once.
    let mut patterns: Vec<(usize, Vec<CommOp>)> = Vec::new();
    let mut pattern_of: Vec<usize> = Vec::with_capacity(works.len());
    for work in &works {
        let idx = match patterns.iter().position(|(e, _)| *e == work.elems) {
            Some(i) => i,
            None => {
                let mut rec = Comm::recorder(net, placement);
                let mut bufs = NullBuffers { elems: work.elems };
                strategy.allreduce(&mut rec, &mut bufs);
                patterns.push((work.elems, rec.take_record().expect("recording comm")));
                patterns.len() - 1
            }
        };
        pattern_of.push(idx);
    }

    let mut queues: Vec<VecDeque<Item>> = vec![VecDeque::new(); s_count];
    for (w, _) in works.iter().enumerate() {
        let q = &mut queues[stream_of[w]];
        q.push_back(Item::Begin { w, launch: launch_of[w] });
        for op in 0..patterns[pattern_of[w]].1.len() {
            q.push_back(Item::Op { w, op });
        }
        q.push_back(Item::End(w));
    }

    let mut clocks: Vec<Vec<f64>> = vec![vec![0.0; p]; s_count];
    let mut intervals: Vec<(f64, f64)> = vec![(0.0, 0.0); works.len()];

    loop {
        // Drain the engine-free items (launches, barrier syncs, bucket
        // completion bookkeeping) on every stream.
        for s in 0..s_count {
            while let Some(&item) = queues[s].front() {
                match item {
                    Item::Begin { w, launch } => {
                        let coord = if launch { cfg.coordination_overhead } else { 0.0 };
                        for r in 0..p {
                            clocks[s][r] = works[w].ready[r].max(clocks[s][r]) + coord;
                        }
                        intervals[w].0 = clocks[s].iter().cloned().fold(0.0, f64::max);
                    }
                    Item::End(w) => {
                        intervals[w].1 = clocks[s].iter().cloned().fold(0.0, f64::max);
                    }
                    Item::Op { w, op } => match &patterns[pattern_of[w]].1[op] {
                        CommOp::SyncAll => {
                            let tmax = clocks[s].iter().cloned().fold(0.0, f64::max);
                            for t in clocks[s].iter_mut() {
                                *t = tmax;
                            }
                        }
                        CommOp::Round(msgs) if msgs.is_empty() => {}
                        _ => break,
                    },
                }
                queues[s].pop_front();
            }
        }

        // Candidate engine ops: the head of every stream, with the time
        // its earliest flow could start.
        let mut cands: Vec<(usize, f64)> = Vec::new();
        for s in 0..s_count {
            if let Some(&Item::Op { w, op }) = queues[s].front() {
                let ready = op_ready(&patterns[pattern_of[w]].1[op], &clocks[s], net);
                cands.push((s, ready));
            }
        }
        let Some(t0) = cands
            .iter()
            .map(|&(_, r)| r)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
        else {
            break;
        };

        // Merge the ops of all streams ready within the window into one
        // heterogeneous-ready-time batch.
        let chosen: Vec<usize> = cands
            .iter()
            .filter(|&&(_, r)| r <= t0 + STREAM_MERGE_WINDOW)
            .map(|&(s, _)| s)
            .collect();
        let mut reqs: Vec<FlowReq> = Vec::new();
        // (stream, op, snapshot, first flow index, flow count)
        let mut parts: Vec<(usize, CommOp, Vec<f64>, usize, usize)> = Vec::new();
        for &s in &chosen {
            let Some(&Item::Op { w, op }) = queues[s].front() else {
                unreachable!("candidate stream lost its op");
            };
            let op = patterns[pattern_of[w]].1[op].clone();
            let snapshot = clocks[s].clone();
            let first = reqs.len();
            push_op_flows(&mut reqs, &op, &snapshot, placement, net);
            let n_flows = reqs.len() - first;
            parts.push((s, op, snapshot, first, n_flows));
        }
        let times = net.transfer_batch(&reqs);
        for (s, op, snapshot, first, n_flows) in parts {
            let slice = &times[first..first + n_flows];
            match &op {
                CommOp::Round(msgs) => apply_round(&mut clocks[s], &snapshot, msgs, slice),
                CommOp::P2p(src, dst, _) => {
                    clocks[s][*src] = clocks[s][*src].max(slice[0].send_release);
                    clocks[s][*dst] = clocks[s][*dst].max(slice[0].recv_complete);
                }
                CommOp::Sendrecv(a, b, _) => {
                    let done = slice[0].recv_complete.max(slice[1].recv_complete);
                    clocks[s][*a] = done;
                    clocks[s][*b] = done;
                }
                CommOp::SyncAll => unreachable!("SyncAll is engine-free"),
            }
            queues[s].pop_front();
        }
    }

    let mut comm_done = vec![0.0; p];
    for s in 0..s_count {
        for r in 0..p {
            comm_done[r] = comm_done[r].max(clocks[s][r]);
        }
    }
    StepTimeline { comm_done, intervals }
}

/// Earliest virtual time at which any flow of `op` can start on a stream
/// whose rank clocks are `t`.
fn op_ready(op: &CommOp, t: &[f64], net: &NetSim) -> f64 {
    match op {
        CommOp::Round(msgs) => msgs
            .iter()
            .map(|&(src, _, _)| t[src])
            .fold(f64::INFINITY, f64::min),
        CommOp::P2p(src, dst, bytes) => {
            if is_rendezvous(&net.opts, net.fabric.eager_threshold, *bytes) {
                t[*src].max(t[*dst])
            } else {
                t[*src]
            }
        }
        CommOp::Sendrecv(a, b, _) => t[*a].max(t[*b]),
        CommOp::SyncAll => 0.0,
    }
}

/// Append `op`'s flows (with per-flow ready times mirroring the direct
/// [`Comm`] execution rules) to a merged batch.
fn push_op_flows(
    reqs: &mut Vec<FlowReq>,
    op: &CommOp,
    snapshot: &[f64],
    placement: &Placement,
    net: &NetSim,
) {
    match op {
        CommOp::Round(msgs) => {
            for &(src, dst, bytes) in msgs {
                reqs.push(FlowReq {
                    src: placement.endpoints[src],
                    dst: placement.endpoints[dst],
                    bytes,
                    ready: snapshot[src],
                });
            }
        }
        CommOp::P2p(src, dst, bytes) => {
            let ready = if is_rendezvous(&net.opts, net.fabric.eager_threshold, *bytes) {
                snapshot[*src].max(snapshot[*dst])
            } else {
                snapshot[*src]
            };
            reqs.push(FlowReq {
                src: placement.endpoints[*src],
                dst: placement.endpoints[*dst],
                bytes: *bytes,
                ready,
            });
        }
        CommOp::Sendrecv(a, b, bytes) => {
            let ready = snapshot[*a].max(snapshot[*b]);
            reqs.push(FlowReq {
                src: placement.endpoints[*a],
                dst: placement.endpoints[*b],
                bytes: *bytes,
                ready,
            });
            reqs.push(FlowReq {
                src: placement.endpoints[*b],
                dst: placement.endpoints[*a],
                bytes: *bytes,
                ready,
            });
        }
        CommOp::SyncAll => unreachable!("SyncAll is engine-free"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{Hierarchical, RingAllreduce};
    use crate::config::presets::fabric;
    use crate::config::spec::{ClusterSpec, FabricKind, TransportOptions};

    fn world(gpus: usize, kind: FabricKind) -> (NetSim, Placement) {
        let cluster = ClusterSpec::txgaia();
        let placement = Placement::gpus(&cluster, gpus).unwrap();
        let net = NetSim::new(fabric(kind), cluster, TransportOptions::default());
        (net, placement)
    }

    fn cfg(num_streams: usize) -> SchedulerConfig {
        SchedulerConfig {
            num_streams,
            coordination_overhead: 1.0e-3,
            chunk_bytes: None,
        }
    }

    fn bucket(elems: usize, ready: f64, gpus: usize) -> BucketWork {
        BucketWork {
            elems,
            bytes: elems as f64 * BYTES_PER_ELEM,
            ready: vec![ready; gpus],
        }
    }

    #[test]
    fn serialized_path_matches_direct_comm_loop() {
        // The num_streams = 1 path must be the literal Comm::with_start +
        // allreduce loop, bit for bit.
        let gpus = 8;
        let buckets = vec![bucket(50_000, 0.010, gpus), bucket(30_000, 0.020, gpus)];
        let (mut net, placement) = world(gpus, FabricKind::EthernetRoce25);
        let got = run_step(&mut net, &placement, &RingAllreduce, &buckets, &cfg(1));

        let (mut net2, placement2) = world(gpus, FabricKind::EthernetRoce25);
        let mut prev = vec![0.0f64; gpus];
        let mut want_done = vec![0.0f64; gpus];
        for b in &buckets {
            let start: Vec<f64> = (0..gpus).map(|r| b.ready[r].max(prev[r]) + 1.0e-3).collect();
            let mut comm = Comm::with_start(&mut net2, &placement2, &start);
            RingAllreduce.allreduce(&mut comm, &mut NullBuffers { elems: b.elems });
            want_done.copy_from_slice(&comm.t);
            prev.copy_from_slice(&comm.t);
        }
        assert_eq!(got.comm_done, want_done);
        assert_eq!(got.intervals.len(), 2);
    }

    #[test]
    fn single_bucket_identical_for_any_stream_count() {
        // One bucket occupies one stream: replay must reproduce direct
        // execution exactly, so every num_streams gives the same answer.
        for strategy in [
            Box::new(RingAllreduce) as Box<dyn Collective>,
            Box::new(Hierarchical::default()),
        ] {
            let gpus = 8;
            let buckets = vec![bucket(40_000, 0.005, gpus)];
            let (mut net1, placement1) = world(gpus, FabricKind::EthernetRoce25);
            let one = run_step(&mut net1, &placement1, strategy.as_ref(), &buckets, &cfg(1));
            let (mut net4, placement4) = world(gpus, FabricKind::EthernetRoce25);
            let four = run_step(&mut net4, &placement4, strategy.as_ref(), &buckets, &cfg(4));
            assert_eq!(
                one.comm_done,
                four.comm_done,
                "{} diverges between replay and direct execution",
                strategy.name()
            );
        }
    }

    #[test]
    fn two_streams_no_slower_than_one() {
        // Buckets that queue behind each other on a single stream should
        // finish no later when spread over two.
        let gpus = 16;
        let buckets: Vec<BucketWork> =
            (0..4).map(|i| bucket(2_000_000, 0.002 * i as f64, gpus)).collect();
        let (mut net1, placement1) = world(gpus, FabricKind::EthernetRoce25);
        let one = run_step(&mut net1, &placement1, &RingAllreduce, &buckets, &cfg(1));
        let (mut net2, placement2) = world(gpus, FabricKind::EthernetRoce25);
        let two = run_step(&mut net2, &placement2, &RingAllreduce, &buckets, &cfg(2));
        let end1 = one.comm_done.iter().cloned().fold(0.0, f64::max);
        let end2 = two.comm_done.iter().cloned().fold(0.0, f64::max);
        assert!(end2 <= end1 + 1e-9, "2 streams {end2} slower than 1 stream {end1}");
    }

    #[test]
    fn streams_overlap_queued_buckets() {
        // With a long first bucket and a second bucket ready immediately,
        // two streams start the second bucket ~at its ready time while one
        // stream queues it behind the first.
        let gpus = 16;
        let buckets = vec![bucket(8_000_000, 0.0, gpus), bucket(8_000_000, 0.0, gpus)];
        let (mut net1, placement1) = world(gpus, FabricKind::EthernetRoce25);
        let one = run_step(&mut net1, &placement1, &RingAllreduce, &buckets, &cfg(1));
        let (mut net2, placement2) = world(gpus, FabricKind::EthernetRoce25);
        let two = run_step(&mut net2, &placement2, &RingAllreduce, &buckets, &cfg(2));
        // Serialized: second interval starts after the first ends.
        assert!(one.intervals[1].0 >= one.intervals[0].1);
        // Two streams: the second bucket starts while the first is in
        // flight, and the step's comm finishes earlier.
        assert!(
            two.intervals[1].0 < two.intervals[0].1,
            "streams did not overlap: {:?}",
            two.intervals
        );
        let end1 = one.comm_done.iter().cloned().fold(0.0, f64::max);
        let end2 = two.comm_done.iter().cloned().fold(0.0, f64::max);
        assert!(end2 < end1, "overlap must shorten the tail: {end2} !< {end1}");
    }

    #[test]
    fn exposed_after_merges_and_clips() {
        // Disjoint intervals sum; overlapping ones merge; the threshold
        // clips.
        let iv = [(0.0, 1.0), (0.5, 2.0), (3.0, 4.0)];
        assert!((exposed_after(&iv, 0.0) - 3.0).abs() < 1e-12);
        assert!((exposed_after(&iv, 1.5) - 1.5).abs() < 1e-12);
        assert!((exposed_after(&iv, 10.0) - 0.0).abs() < 1e-12);
        assert_eq!(exposed_after(&[], 0.0), 0.0);
    }

    #[test]
    fn chunking_splits_oversize_buckets() {
        let gpus = 4;
        let buckets = vec![bucket(1000, 0.0, gpus)];
        let split = split_chunks(&buckets, Some(1000.0)); // 4000 B / 1000 B
        assert_eq!(split.len(), 4);
        assert_eq!(split.iter().map(|(b, _)| b.elems).sum::<usize>(), 1000);
        // One logical launch: only the first chunk pays coordination.
        let launches: Vec<bool> = split.iter().map(|&(_, l)| l).collect();
        assert_eq!(launches, vec![true, false, false, false]);
        let noop = split_chunks(&buckets, None);
        assert_eq!(noop.len(), 1);
        assert_eq!(noop[0].0.elems, 1000);
        assert!(noop[0].1);
    }

    #[test]
    fn chunked_step_still_completes_all_traffic() {
        let gpus = 8;
        let buckets = vec![bucket(1_000_000, 0.0, gpus)];
        let (mut net, placement) = world(gpus, FabricKind::EthernetRoce25);
        let mut chunked = cfg(2);
        chunked.chunk_bytes = Some(1_000_000.0); // 4 MB bucket -> 4 chunks
        let t = run_step(&mut net, &placement, &RingAllreduce, &buckets, &chunked);
        assert_eq!(t.intervals.len(), 4);
        assert!(t.comm_done.iter().all(|&d| d > 0.0));
        // All bytes still move: the engine saw 4 sub-allreduces' messages.
        assert!(net.stats.messages > 0);
    }
}
